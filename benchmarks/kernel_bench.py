"""Kernel microbenchmarks: us/call for the Pallas kernels vs jnp refs.

On this CPU container the Pallas numbers are *interpreter* timings
(functional only — the TPU target compiles natively); the jnp-ref rows are
the meaningful CPU timings.  Both are reported so the harness shape is
complete.

Also a CLI (used by the CI bench-smoke step)::

    PYTHONPATH=src python -m benchmarks.kernel_bench --json BENCH_kernel.json
    PYTHONPATH=src python -m benchmarks.kernel_bench --smoke --json out.json

``--smoke`` restricts to the fused-vs-per-layer LUT-network comparison on
the fpga4hep topologies at reduced iteration counts, emitting the
``fused_speedup`` field the perf trajectory tracks.

Perf-regression gate (used by CI so a compiler or kernel regression cannot
merge silently)::

    # compare this run against the committed baseline; exit 1 on regression
    python -m benchmarks.kernel_bench --smoke --json out.json \
        --baseline benchmarks/baselines/BENCH_baseline.json
    # refresh the committed baseline after an intentional perf change
    python -m benchmarks.kernel_bench --update-baseline

Gated quantities: ``fused_speedup`` on fpga4hep model A (with a 25%
interpret-mode-noise tolerance), the compile section's
``slab_reduction_pct`` and ``table_bytes_after`` at level 2 and level 3
(near-deterministic; small tolerances for cross-version float drift),
the level-3 slab row-dedup entry count (sharp) and the ``synth``
section's two-level minimization quantities — neuron coverage sharp,
literal reduction and the worst-case-bound-over-measured-kLUT ratio on
collapse-only floors (the measured estimate must stay below the bound),
and the ``serving`` section's compile-once contract —
``retraces_after_warmup`` / ``compiler_runs_after_warmup`` exactly 0 and
the artifact's table slab byte-exact (sharp), with the engine-vs-uncached
``serving_speedup`` timing ratio on the wide interpret tolerance.  The
``serving_tier`` section (micro-batching queue over the artifact, see
docs/serving.md) gates the same sharp compile-once counters plus
collapse-only floors/ceilings on its closed-loop p99/QPS/occupancy, and
the ``ingress`` section (open-loop Poisson load through a live localhost
HTTP ingress, see docs/ingress.md) gates overload behavior — goodput
held near capacity and rejection-rate nonzero at 3x offered load — the
same way: sharp counters, collapse-only ratios.
``BENCH_*.json`` at the repo root is gitignored, so the committed baseline
lives under ``benchmarks/baselines/``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compile as rcompile
from repro.core.table_infer import network_table_forward
from repro.kernels import ref
from repro.kernels.lut_lookup import lut_lookup_pallas
from repro.kernels.lut_network import (build_mixed_network_slabs,
                                       build_network_slabs,
                                       lut_network_mixed_pallas,
                                       lut_network_pallas)
from repro.kernels.ops import (flash_attention, fused_plan, lut_lookup,
                               masked_matmul)

Row = tuple[str, float, str]

BASELINE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "baselines", "BENCH_baseline.json")


def _bench(fn, *args, iters=20, warmup=3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_rows() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(0)

    # lut_lookup: a 64-neuron LogicNets layer at inference batch 256
    b, n_in, n_out, fi, bw = 256, 64, 64, 3, 2
    codes = jax.random.randint(key, (b, n_in), 0, 2 ** bw, dtype=jnp.int32)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(np.stack([
        np.sort(rng.choice(n_in, fi, replace=False))
        for _ in range(n_out)]).astype(np.int32))
    table = jax.random.randint(key, (n_out, 2 ** (fi * bw)), 0, 2 ** bw,
                               dtype=jnp.int32)
    jref = jax.jit(lambda c: ref.lut_lookup_ref(c, idx, table, bw))
    rows.append(("kernel/lut_lookup_ref_jnp", _bench(jref, codes),
                 f"batch={b} neurons={n_out}"))
    rows.append(("kernel/lut_lookup_pallas_interp",
                 _bench(lambda c: lut_lookup(c, idx, table, bw), codes,
                        iters=3, warmup=1), "interpret-mode timing"))

    # masked_matmul: LogicNet-FFN shape
    m, k, n = 512, 512, 2048
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(key, (k, n), jnp.float32)
    mask = (jax.random.uniform(key, (k, n)) > 0.9).astype(jnp.float32)
    jref = jax.jit(lambda a: ref.masked_matmul_ref(a, w, mask))
    rows.append(("kernel/masked_matmul_ref_jnp", _bench(jref, x),
                 f"{m}x{k}x{n}"))
    rows.append(("kernel/masked_matmul_pallas_interp",
                 _bench(lambda a: masked_matmul(a, w, mask), x, iters=3,
                        warmup=1), "interpret-mode timing"))

    # flash attention: 2k prefill slice
    bq, hq, hkv, s, d = 1, 8, 2, 1024, 64
    q = jax.random.normal(key, (bq, hq, s, d), jnp.bfloat16)
    kk = jax.random.normal(key, (bq, hkv, s, d), jnp.bfloat16)
    v = jax.random.normal(key, (bq, hkv, s, d), jnp.bfloat16)
    jref = jax.jit(lambda a: ref.flash_attention_ref(a, kk, v, causal=True))
    rows.append(("kernel/flash_attention_ref_jnp", _bench(jref, q, iters=5),
                 f"S={s} Hq={hq} GQA"))
    return rows


# ---------------------------------------------------------------------------
# Fused whole-network LUT engine vs the per-layer path
# ---------------------------------------------------------------------------

def _random_stack(widths, fan_in, bw, seed=0):
    rng = np.random.default_rng(seed)
    layers = []
    for n_in, n_out in zip(widths[:-1], widths[1:]):
        fi = min(fan_in, n_in)
        idx = np.stack([np.sort(rng.choice(n_in, fi, replace=False))
                        for _ in range(n_out)]).astype(np.int32)
        tab = rng.integers(0, 2 ** bw, (n_out, 2 ** (fi * bw)),
                           dtype=np.int32)
        layers.append((idx, tab, bw))
    return layers

# Sparse stacks of the paper's own topologies (fpga4hep Table 6.1): the
# fused engine's headline comparison runs on model A's 3-layer stack.
LUT_NETWORK_CASES = {
    # name: (widths, fan_in, bw, batch)
    "fpga4hep_modelA": ((16, 64, 64, 64), 3, 3, 128),
    "jsc_deep": ((16, 64, 64, 64, 64), 3, 2, 128),
}


def _slab_report(layers, opt=None) -> dict:
    """Raw-vs-optimized slab footprint + fused-path eligibility.

    ``opt`` takes pre-optimized triples when the caller already ran the
    compiler (avoids compiling the same stack twice).
    """
    if opt is None:
        opt = rcompile.optimize_triples(layers, level=2)
    # eligibility IS ops.lut_network's actual gate (fused_plan is the
    # single source of truth for the VMEM-budget + f32-exactness decision)
    raw_plan = fused_plan(layers)
    opt_plan = fused_plan(opt)
    return {
        "slab_bytes_raw": raw_plan.slab_bytes,
        "slab_bytes_optimized": opt_plan.slab_bytes,
        "slab_reduction_pct":
            100.0 * (1.0 - opt_plan.slab_bytes / raw_plan.slab_bytes),
        "fused_eligible_raw": raw_plan.fused,
        "fused_eligible_optimized": opt_plan.fused,
    }


def lut_network_rows(smoke: bool = False) -> tuple[list[Row], dict]:
    """Per-layer vs fused whole-network inference on LogicNet stacks.

    Returns (rows, extras); ``extras['fused_speedup']`` is the headline
    per-layer/fused ratio on the fpga4hep model A stack — the number the
    BENCH artifacts track.  Both paths run through Pallas (interpret mode
    off-TPU), jitted, so timings compare execution not tracing.  Each case
    also records raw-vs-``repro.compile``-optimized slab bytes and fused
    eligibility, so the compiler's effect on the fused path is tracked
    over time alongside the speedup.
    """
    iters, warmup = (5, 2) if smoke else (20, 3)
    rows: list[Row] = []
    extras: dict = {"cases": {}}
    for name, (widths, fan_in, bw, batch) in LUT_NETWORK_CASES.items():
        layers = _random_stack(widths, fan_in, bw, seed=len(name))
        slabs = build_network_slabs(layers)
        jl = [(jnp.asarray(i), jnp.asarray(t), b) for i, t, b in layers]
        codes = jnp.asarray(np.random.default_rng(0).integers(
            0, 2 ** bw, (batch, widths[0]), dtype=np.int32))
        interp = jax.default_backend() != "tpu"

        fused = jax.jit(
            lambda c, s=slabs: lut_network_pallas(c, s, interpret=interp))

        def per_layer(c, jl=jl):
            for i, t, b in jl:
                c = lut_lookup_pallas(c, i, t, b, interpret=interp)
            return c
        per = jax.jit(per_layer)

        np.testing.assert_array_equal(np.asarray(fused(codes)),
                                      np.asarray(per(codes)))
        # the smoke-mode speedup feeds the CI regression gate, so take the
        # median of 3 measurement pairs — one noisy-neighbor window on a
        # shared runner then cannot move the gated ratio
        reps = []
        for _ in range(3 if smoke else 1):
            up = _bench(per, codes, iters=iters, warmup=warmup)
            uf = _bench(fused, codes, iters=iters, warmup=warmup)
            reps.append((up / uf, up, uf))
        reps.sort()
        speedup, us_per, us_fused = reps[len(reps) // 2]
        n_layers = len(layers)
        rows.append((f"kernel/lut_network_perlayer[{name}]", us_per,
                     f"batch={batch} layers={n_layers}"))
        rows.append((f"kernel/lut_network_fused[{name}]", us_fused,
                     f"speedup={speedup:.2f}x vs per-layer"))
        # diagnose a sub-1x fused result so the regression gate (and a
        # human reading the JSON) can tell "fused fell back / was
        # ineligible" apart from "fused executed and got slower"
        plan = fused_plan(layers)
        reason = None
        if speedup < 1.0:
            if not plan.fused:
                reason = f"fused ineligible, would fall back: {plan.reason}"
            elif interp:
                reason = ("fused executed but slower under the Pallas "
                          "interpreter (two-level one-hot gather costs "
                          "more per element in interpret mode than the "
                          "per-layer compare/select; TPU timings are "
                          "authoritative)")
            else:
                reason = "fused executed but slower on this backend"
        extras["cases"][name] = {
            "layers": n_layers, "batch": batch, "bw": bw, "fan_in": fan_in,
            "us_per_layer_path": us_per, "us_fused": us_fused,
            "fused_speedup": speedup,
            "slab_bytes": slabs.vmem_bytes(), "packed": slabs.packed,
            # fused_plan carries the slab-vs-VMEM-budget breakdown
            # (slab_bytes, vmem_budget_bytes, headroom_bytes, reason)
            "fused_plan": plan.as_dict(),
            "fused_slower_reason": reason,
            **_slab_report(layers),
        }
        if name == "fpga4hep_modelA":
            extras["fused_speedup"] = speedup
    extras["compile"], ctx = compile_stats_case(smoke=smoke)
    extras["synth"] = synth_case(ctx, smoke=smoke)
    extras["serving"] = serving_case(ctx, smoke=smoke)
    extras["serving_tier"] = serving_tier_case(ctx, smoke=smoke)
    extras["ingress"] = ingress_case(ctx, smoke=smoke)
    extras["autotune"] = autotune_case(ctx, smoke=smoke)
    return rows, extras


def compile_stats_case(smoke: bool = True) -> tuple[dict, dict]:
    """Truth-table compiler on a *generated* fpga4hep model A stack.

    Random tables barely compress (every code is emitted, no structure);
    the compiler's real effect shows on tables generated from an actual
    quantized model, so this is the stack the acceptance numbers and the
    CI compile-stats artifact track: raw vs optimized packed table bytes,
    fused-slab bytes, and the per-pass reduction statistics.  The
    top-level fields are the level-2 (default) run; the ``level3`` section
    adds the cross-layer re-encoding pass (per-feature bus narrowing) with
    its ``features_recoded`` / ``bits_saved`` statistics plus the
    *mixed-width* fused-slab numbers — ``mixed_slab_bytes`` is what the
    fused kernel actually holds in VMEM when it consumes the compiler's
    compact lowering (vs ``slab_bytes_optimized``, the padded uniform
    figure), and ``mixed_fused_speedup`` times that kernel against the
    per-layer path on the same generated stack.

    Returns ``(report, ctx)`` — ``ctx`` hands the generated model, raw
    tables and the level-3 ``OptimizeResult`` to ``serving_case`` so the
    serving section reuses this compile instead of paying for another.
    """
    import jax as _jax
    from repro.configs import fpga4hep
    from repro.core import logicnet as LN

    cfg = fpga4hep.model_a()
    model = LN.init(cfg, _jax.random.PRNGKey(0))
    x = _jax.random.uniform(_jax.random.PRNGKey(1),
                            (256, cfg.in_features), minval=-1, maxval=3)
    _, model = LN.forward(cfg, model, x, train=True)   # settle BN stats
    tables = LN.generate_tables(cfg, model)
    res = rcompile.optimize(tables, level=2, in_features=cfg.in_features)
    triples = [(tt.indices, tt.table, tt.bw_in) for tt in tables]
    opt_triples = [(tt.indices, tt.table, tt.bw_in) for tt in res.tables]
    report = {
        "case": "fpga4hep_modelA_generated",
        "level": 2,
        **_slab_report(triples, opt=opt_triples),
        "stats": res.stats.as_dict(),
        "summary": rcompile.summarize(res.stats),
    }
    res3 = rcompile.optimize(tables, level=3, in_features=cfg.in_features)
    opt3_triples = [(tt.indices, tt.table, tt.bw_in) for tt in res3.tables]
    report["level3"] = {
        "level": 3,
        **_slab_report(triples, opt=opt3_triples),
        "stats": res3.stats.as_dict(),
        "summary": rcompile.summarize(res3.stats),
        **_mixed_fused_report(cfg, tables, res3, smoke=smoke),
    }
    return report, {"cfg": cfg, "tables": tables, "res3": res3}


def _mixed_fused_report(cfg, tables, res3, smoke: bool = True) -> dict:
    """Mixed-width fused slabs + timing on the generated model A stack.

    The quantities the ISSUE-4 acceptance criteria and the regression
    gate track: the compact slab must stay near the netlist's exact
    ``table_bytes()`` (the uniform figure is the padded comparison), and
    the mixed kernel must stay bit-exact and not regress against the
    per-layer path.
    """
    iters, warmup = (5, 2) if smoke else (20, 3)
    interp = jax.default_backend() != "tpu"
    mixed = res3.mixed_tables
    m_plan = fused_plan(mixed)
    slabs = build_mixed_network_slabs(mixed, pack=m_plan.pack)
    nodedup = build_mixed_network_slabs(mixed, pack=m_plan.pack,
                                        dedup=False)
    breakdown = slabs.vmem_breakdown()
    u_plan = fused_plan([(tt.indices, tt.table, tt.bw_in)
                         for tt in res3.tables])

    codes = jnp.asarray(np.random.default_rng(0).integers(
        0, 2 ** cfg.bw, (128, cfg.in_features), dtype=np.int32))
    fused_fn = jax.jit(
        lambda c, s=slabs: lut_network_mixed_pallas(c, s, interpret=interp))
    jl = [(jnp.asarray(tt.indices), jnp.asarray(tt.table), tt.bw_in)
          for tt in tables]

    def per_layer(c, jl=jl):
        for i, t, b in jl:
            c = lut_lookup_pallas(c, i, t, b, interpret=interp)
        return c
    per = jax.jit(per_layer)
    np.testing.assert_array_equal(np.asarray(fused_fn(codes)),
                                  np.asarray(per(codes)))
    # median-of-3 like the headline fused_speedup: the ratio feeds the
    # CI regression gate
    reps = []
    for _ in range(3):
        up = _bench(per, codes, iters=iters, warmup=warmup)
        um = _bench(fused_fn, codes, iters=iters, warmup=warmup)
        reps.append((up / um, up, um))
    reps.sort()
    speedup, us_per, us_mixed = reps[len(reps) // 2]
    return {
        "mixed_slab_bytes": slabs.vmem_bytes(),
        "mixed_table_slab_bytes": breakdown["table_slab_bytes"],
        # slab-sharing (row dedup) delta: identical table rows stored
        # once; the nodedup figure is what the slab cost before sharing
        "dedup_entries_saved": int(slabs.dedup_entries_saved),
        "mixed_table_slab_bytes_nodedup":
            nodedup.vmem_breakdown()["table_slab_bytes"],
        "uniform_slab_bytes": u_plan.slab_bytes,
        "netlist_table_bytes": res3.cnet.table_bytes(),
        "mixed_vmem_breakdown": breakdown,
        "mixed_fused_plan": m_plan.as_dict(),
        "us_per_layer_path": us_per,
        "us_mixed_fused": us_mixed,
        "mixed_fused_speedup": speedup,
    }


def synth_case(ctx, smoke: bool = True) -> dict:
    """Two-level synthesis on the generated model A at level 3.

    The quantities the ISSUE-10 acceptance criteria track: the
    minimizer's literal/term reduction and wall time, the measured
    k-LUT estimate vs the worst-case ``lut_cost`` bound (the bound must
    stay above the measurement — that ratio is the gated headline), and
    bit-exactness of the SOP assign-network Verilog against the
    case-statement emission, the table-forward reference, and the fused
    mixed kernel on sampled reachable input words.
    """
    import re as _re

    from repro.core.lut_cost import netlist_lut_cost, netlist_sop_cost
    from repro.core.verilog import evaluate_verilog, generate_verilog
    from repro.synth import synthesize_netlist

    tables, res3 = ctx["tables"], ctx["res3"]
    cfg = ctx["cfg"]
    nl = res3.netlist
    t0 = time.perf_counter()
    stats = synthesize_netlist(nl)
    synth_seconds = time.perf_counter() - t0

    bound = netlist_lut_cost(nl)
    measured = netlist_sop_cost(nl)
    lb, la = stats["literals_before"], stats["literals_after"]

    files_sop = generate_verilog(nl, sop=True)
    files_case = generate_verilog(nl)
    n_layers = 1 + max(int(m.group(1)) for m in
                       (_re.match(r"LUTLayer(\d+)\.v$", f)
                        for f in files_sop) if m)
    n_words = 16 if smoke else 64
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 2 ** cfg.bw, (n_words, cfg.in_features),
                         dtype=np.int32)
    # reference + both fused lowerings on the same sampled words
    expect = np.asarray(network_table_forward(
        tables, jnp.asarray(codes)))
    level3 = np.asarray(network_table_forward(
        res3.tables, jnp.asarray(codes)))
    interp = jax.default_backend() != "tpu"
    m_plan = fused_plan(res3.mixed_tables)
    slabs = build_mixed_network_slabs(res3.mixed_tables, pack=m_plan.pack)
    fused = np.asarray(lut_network_mixed_pallas(
        jnp.asarray(codes), slabs, interpret=interp))
    np.testing.assert_array_equal(expect, level3)
    bw_out = tables[-1].bw_out
    out_feats = tables[-1].out_features
    for w in range(n_words):
        word = int(sum(int(codes[w, f]) << (cfg.bw * f)
                       for f in range(cfg.in_features)))
        o_sop = evaluate_verilog(files_sop, word, n_layers=n_layers)
        o_case = evaluate_verilog(files_case, word, n_layers=n_layers)
        got = [(o_sop >> (bw_out * j)) & (2 ** bw_out - 1)
               for j in range(out_feats)]
        if o_sop != o_case or got != [int(v) for v in expect[w]] \
                or got != [int(v) for v in fused[w]]:
            raise AssertionError(
                f"SOP Verilog diverged on word {word}: sop={o_sop} "
                f"case={o_case} tables={list(expect[w])} "
                f"fused={list(fused[w])}")
    return {
        "case": "fpga4hep_modelA_generated_level3_synth",
        **{k: stats[k] for k in
           ("neurons", "covered_neurons", "fallback_neurons",
            "terms_before", "terms_after",
            "literals_before", "literals_after")},
        "synth_seconds": synth_seconds,
        "literal_reduction_pct": 100.0 * (1.0 - la / lb) if lb else 0.0,
        "lut_cost_bound": int(bound),
        "est_kluts": int(measured["est_kluts"]),
        "bound_over_measured": (bound / measured["est_kluts"]
                                if measured["est_kluts"] else float(bound)),
        "verilog_words_checked": n_words,
    }


def serving_case(ctx, smoke: bool = True) -> dict:
    """Compile-once serving artifact vs the legacy per-call flag API.

    Steady-state timing of ``repro.engine.CompiledLUTNet`` on the
    generated fpga4hep model A stack at level 3 (the deployment shape: a
    37504 B compiler-exact table slab) against ``ops.lut_network(...,
    optimize_level=3)`` in two regimes: *cached* (the engine memo
    absorbing the legacy flags — what loop callers get for free now) and
    *uncached* (the pre-engine behavior, forced by clearing the memo
    between calls: one compiler run + slab rebuild per call).

    The sharp fields for the CI gate are ``retraces_after_warmup`` and
    ``compiler_runs_after_warmup`` — the compile-once contract says both
    are exactly 0 in steady state, ragged batches included — plus the
    byte-exact ``artifact_table_slab_bytes``; ``serving_speedup`` (engine
    vs uncached per-call) is an interpret-mode timing ratio and gets the
    documented wide noise tolerance.
    """
    from repro import engine as rengine
    from repro.kernels.ops import lut_network

    cfg, tables, res3 = ctx["cfg"], ctx["tables"], ctx["res3"]
    iters, warmup = (5, 2) if smoke else (20, 3)
    batch = 128
    eng = rengine.compile_network(res3, block_b=batch)
    codes = jnp.asarray(np.random.default_rng(0).integers(
        0, 2 ** cfg.bw, (batch, cfg.in_features), dtype=np.int32))
    triples = [(tt.indices, tt.table, tt.bw_in) for tt in tables]

    # bit-exactness first: the artifact vs the per-layer reference
    want = np.asarray(network_table_forward(tables, codes))
    np.testing.assert_array_equal(np.asarray(eng(codes)), want)

    # steady state: after the first traced call, ragged batches included,
    # the artifact must add zero traces and zero compiler runs
    traces0, runs0 = eng.jit_cache_size(), rengine.compile_runs()
    us_engine = _bench(eng, codes, iters=iters, warmup=warmup)
    for b in (1, 37, batch):
        jax.block_until_ready(eng(codes[:b]))
    retraces = eng.jit_cache_size() - traces0
    compiler_runs = rengine.compile_runs() - runs0

    def legacy(c):
        return lut_network(c, triples, optimize_level=3)

    us_cached = _bench(legacy, codes, iters=iters, warmup=warmup)

    def legacy_uncached(c):
        # the pre-engine per-call cost: every call re-runs the compiler
        # and rebuilds the slabs (the memo is what the engine added)
        rengine.cache_clear()
        return lut_network(c, triples, optimize_level=3)

    us_uncached = _bench(legacy_uncached, codes, iters=max(2, iters // 2),
                         warmup=1)

    bd = eng.vmem_breakdown()
    return {
        "case": "fpga4hep_modelA_generated_level3",
        "layout": eng.layout,
        "block_b": eng.block_b,
        "batch": batch,
        "artifact_vmem_bytes": bd["total_bytes"],
        "artifact_table_slab_bytes": bd["table_slab_bytes"],
        "us_engine_call": us_engine,
        "engine_calls_per_sec": 1e6 / us_engine,
        "us_legacy_cached": us_cached,
        "us_legacy_uncached": us_uncached,
        "serving_speedup": us_uncached / us_engine,
        "legacy_cached_overhead": us_cached / us_engine,
        "retraces_after_warmup": retraces,
        "compiler_runs_after_warmup": compiler_runs,
    }


def serving_tier_case(ctx, smoke: bool = True) -> dict:
    """Steady-state micro-batching serving tier on generated model A.

    The request-side half of the deployment story (``repro.serve``): a
    closed pool of concurrent clients drives ragged single-digit-row
    requests through a :class:`~repro.serve.ServingTier` over the same
    level-3 ``CompiledLUTNet`` the ``serving`` section times, and the
    report is the serving numbers an operator cares about — p50/p99
    request latency, QPS, and batch occupancy (real rows / padded kernel
    rows).

    Gate split (same philosophy as every other section):
    ``retraces_after_warmup`` / ``compiler_runs_after_warmup`` are the
    sharp compile-once contract — exactly 0 in steady state, coalescing
    and ragged padding included.  The latency/QPS numbers are host-side +
    interpret-mode timings on a shared runner, so they only get wide
    collapse gates (see ``check_against_baseline``).
    """
    from repro import engine as rengine
    from repro import obs
    from repro import serve

    cfg, res3 = ctx["cfg"], ctx["res3"]
    n_clients, n_per_client = (6, 8) if smoke else (8, 24)
    block_b = 16
    eng = rengine.compile_network(res3, block_b=block_b)

    def _obs_total(name: str) -> float:
        metric = obs.registry().get(name)
        if metric is None:
            return 0.0
        if isinstance(metric, obs.Family):
            return sum(c.value for _, c in metric._series())
        return metric.value

    # compile-once contract, observed from the *process registry* this
    # time: across the whole closed-loop run the engine must issue zero
    # compiler runs and the memo must see zero traffic (the serving path
    # never touches the legacy flag API) — deterministic, gated sharply
    obs0 = {name: _obs_total(name)
            for name in ("engine_compiler_runs_total",
                         "engine_memo_hits_total",
                         "engine_memo_misses_total")}
    tier_cfg = serve.TierConfig(max_batch_rows=2 * block_b,
                                flush_deadline_s=0.002)
    rep = serve.run_closed_loop(eng, config=tier_cfg, n_clients=n_clients,
                                n_per_client=n_per_client, rows_min=1,
                                rows_max=8, bw=cfg.bw, seed=0,
                                check_outputs=True)
    obs_deltas = {f"{name.removeprefix('engine_').removesuffix('_total')}"
                  f"_delta": int(_obs_total(name) - obs0[name])
                  for name in obs0}
    stats = rep.stats
    return {
        "case": "fpga4hep_modelA_generated_level3",
        "layout": eng.layout,
        "block_b": block_b,
        "max_batch_rows": tier_cfg.max_batch_rows,
        "flush_deadline_s": tier_cfg.flush_deadline_s,
        "n_clients": rep.n_clients,
        "n_requests": rep.n_requests,
        "rows": rep.rows,
        "wall_s": rep.wall_s,
        "p50_ms": rep.p50_ms,
        "p90_ms": rep.p90_ms,
        "p99_ms": rep.p99_ms,
        "mean_ms": rep.mean_ms,
        "qps": rep.qps,
        "rows_per_sec": rep.rows_per_sec,
        "batches": stats["batches"],
        "batch_occupancy": stats["batch_occupancy"],
        "mean_batch_rows": stats["mean_batch_rows"],
        "flush_causes": stats["flush_causes"],
        "n_devices": stats["n_devices"],
        "sharded": stats["sharded"],
        "retraces_after_warmup": stats["retraces_after_warmup"],
        "compiler_runs_after_warmup": stats["compiler_runs_after_warmup"],
        # span-derived stage breakdown (queue_wait / assembly / device /
        # total, each {count, mean_ms, p50_ms, p99_ms}) — the "where did
        # the latency go" view from the tier's obs histograms
        "latency_breakdown": rep.breakdown,
        # registry-observed engine counter deltas across the run
        "obs": obs_deltas,
    }


def ingress_case(ctx, smoke: bool = True) -> dict:
    """Open-loop overload behavior through a live localhost HTTP ingress.

    The closed-loop ``serving_tier`` section can only measure equilibrium
    (its clients slow down when the tier does); this section asks the
    production question instead — *what happens when offered load exceeds
    capacity?* — by driving seeded Poisson arrivals
    (:func:`repro.serve.run_open_loop`) through a real
    :class:`~repro.serve.HttpIngress` over localhost at three offered
    loads: below (0.5x), at (1.0x) and above (3.0x) a capacity estimate
    taken from a short closed-loop run on the same artifact.  The tier's
    queue bound is deliberately small so overload has to shed: the
    healthy signature is goodput holding near capacity while the excess
    is rejected with 503s, never a collapse or a wedged queue.

    Gate split: the compile-once counters stay sharp (HTTP decode,
    quotas and coalescing must add zero re-traces / compiler runs), and
    the two overload ratios — ``overload_goodput_ratio`` (goodput at 3x
    over measured capacity: both sides move with the runner, so the
    ratio self-normalizes) and ``overload_rejection_rate`` — only gate
    collapses with wide tolerances.  The below/at-capacity rows are
    reported for reading, not gated.
    """
    from repro import engine as rengine
    from repro import serve

    cfg, res3 = ctx["cfg"], ctx["res3"]
    block_b = 16
    n_requests = 40 if smoke else 120
    eng = rengine.compile_network(res3, block_b=block_b)
    tier_kw = dict(max_batch_rows=2 * block_b, flush_deadline_s=0.002)

    # capacity estimate: what the same artifact+tier sustains closed-loop
    # (timing only — correctness is the load runs' job)
    cap = serve.run_closed_loop(
        eng, config=serve.TierConfig(**tier_kw), n_clients=6,
        n_per_client=max(4, n_requests // 8), rows_min=1, rows_max=8,
        bw=cfg.bw, seed=0, check_outputs=False)
    capacity_rps = cap.qps

    # small queue bound so the overload run must shed instead of
    # buffering the whole burst (32 rows = one max batch of headroom);
    # 5x offered keeps the queue pinned full even when asyncio smears
    # the arrival schedule, so the shed fraction stays well off zero
    tier_cfg = serve.TierConfig(**tier_kw, max_queue_rows=32)
    levels = {}
    with serve.BackgroundIngress(eng, tier_cfg) as ing:
        for name, mult in (("below", 0.5), ("at", 1.0), ("above", 5.0)):
            rep = serve.run_open_loop(
                url=ing.url, offered_rps=mult * capacity_rps,
                n_requests=n_requests, rows_min=1, rows_max=8, bw=cfg.bw,
                seed=0, verify_net=eng)
            levels[name] = {
                "offered_rps": rep.offered_rps,
                "p50_ms": rep.p50_ms,
                "p99_ms": rep.p99_ms,
                "goodput_rps": rep.goodput_rps,
                "rejection_rate": rep.rejection_rate,
                "rejected": rep.rejected,
                "timed_out": rep.timed_out,
                "outcomes": dict(rep.outcomes),
            }
        stats = ing.stats()
    above = levels["above"]
    return {
        "case": "fpga4hep_modelA_generated_level3",
        "layout": eng.layout,
        "block_b": block_b,
        "max_batch_rows": tier_cfg.max_batch_rows,
        "max_queue_rows": tier_cfg.max_queue_rows,
        "n_requests": n_requests,
        "capacity_rps": capacity_rps,
        "levels": levels,
        "overload_goodput_ratio": above["goodput_rps"] / capacity_rps,
        "overload_rejection_rate": above["rejection_rate"],
        "retraces_after_warmup": stats["retraces_after_warmup"],
        "compiler_runs_after_warmup": stats["compiler_runs_after_warmup"],
    }


def autotune_case(ctx, smoke: bool = True) -> dict:
    """Compile-time variant autotuner on generated model A at level 3.

    ``compile_network(..., autotune=True)`` enumerates the eligible plan
    variants (layout x block_b x pack), times each one's jitted forward
    on this backend, and serves the measured winner.  The section records
    the full timing table plus the two contract numbers the gate tracks:

    * ``compiler_runs_after_warmup`` — the search runs on the *already
      compiled* level-3 result handed over from ``compile_stats_case``,
      so it must add exactly 0 truth-table compiler runs (sharp gate);
    * ``speedup_vs_default`` — chosen-variant time over the heuristic
      default's time from the *same* timing table.  >= 1.0 by
      construction (the search minimizes over a set containing the
      default), so the gate is collapse-only: a drop below ~1/(1+tol)
      means the selection logic regressed, not that the runner was slow.

    The chosen/default variant *keys* are recorded for reading but not
    equality-gated — on a noisy shared runner near-tied variants can
    legitimately swap places between runs.
    """
    from repro import engine as rengine

    cfg, res3 = ctx["cfg"], ctx["res3"]
    # smoke sweeps two batch tiles to keep CI quick; full mode takes the
    # kernels' default sweep
    block_bs = (64, 128) if smoke else None
    runs0 = rengine.compile_runs()
    t0 = time.perf_counter()
    eng = rengine.compile_network(res3, block_b=128, autotune=True,
                                  autotune_block_bs=block_bs)
    search_s = time.perf_counter() - t0
    compiler_runs = rengine.compile_runs() - runs0

    plan = eng.plan
    chosen = plan.variant.key
    default = plan.default_key or chosen
    # bit-exactness of the winner against the per-layer reference
    codes = jnp.asarray(np.random.default_rng(0).integers(
        0, 2 ** cfg.bw, (128, cfg.in_features), dtype=np.int32))
    want = np.asarray(network_table_forward(ctx["tables"], codes))
    np.testing.assert_array_equal(np.asarray(eng(codes)), want)

    return {
        "case": "fpga4hep_modelA_generated_level3",
        "source": plan.source,
        "chosen": chosen,
        "default": default,
        "chosen_layout": plan.layout,
        "chosen_block_b": plan.block_b,
        "chosen_pack": plan.pack,
        "n_variants": len(plan.timings_us),
        "batch": plan.batch,
        "timings_us": dict(plan.timings_us),
        "search_seconds": search_s,
        "speedup_vs_default": (plan.timings_us[default]
                               / plan.timings_us[chosen]),
        "compiler_runs_after_warmup": compiler_runs,
    }


# ---------------------------------------------------------------------------
# Perf-regression gate (CI bench-smoke): bench JSON vs committed baseline
# ---------------------------------------------------------------------------

def baseline_from_payload(payload: dict) -> dict:
    """Extract exactly the gated quantities from a bench JSON payload."""
    comp = payload["compile"]
    return {
        "benchmark": "kernel_bench_smoke_baseline",
        "mode": payload.get("mode"),
        "backend": payload.get("backend"),
        "fused_speedup": payload["fused_speedup"],
        "compile": {
            "slab_reduction_pct": comp["slab_reduction_pct"],
            "table_bytes_after": comp["stats"]["table_bytes_after"],
            "level3": {
                "slab_reduction_pct": comp["level3"]["slab_reduction_pct"],
                "table_bytes_after":
                    comp["level3"]["stats"]["table_bytes_after"],
                # round-count independent (telescoping), unlike the
                # features_recoded event count — see CompileStats
                "bits_saved": comp["level3"]["stats"]["bits_saved"],
                # what the fused kernel actually banks in VMEM from the
                # compiler's mixed-width lowering, and its timing vs the
                # per-layer path on the same generated stack
                "mixed_slab_bytes": comp["level3"]["mixed_slab_bytes"],
                "mixed_fused_speedup":
                    comp["level3"]["mixed_fused_speedup"],
                # slab row-dedup: entries elided by content sharing is
                # deterministic for the generated stack (sharp)
                "dedup_entries_saved":
                    comp["level3"]["dedup_entries_saved"],
            },
        },
        # two-level synthesis on the same generated stack: neuron
        # coverage is deterministic (sharp); the literal reduction and
        # the bound/measured ratio are deterministic too but gated with
        # collapse floors so minimizer-heuristic tweaks don't need a
        # baseline refresh unless they genuinely lose ground
        "synth": {
            "covered_neurons": payload["synth"]["covered_neurons"],
            "fallback_neurons": payload["synth"]["fallback_neurons"],
            "literal_reduction_pct":
                payload["synth"]["literal_reduction_pct"],
            "bound_over_measured": payload["synth"]["bound_over_measured"],
        },
        # the compile-once serving contract: retrace/compiler-run counts
        # are sharp (exactly 0), the artifact slab is byte-exact, the
        # calls/sec ratio is interpret-mode timing
        "serving": {
            "retraces_after_warmup":
                payload["serving"]["retraces_after_warmup"],
            "compiler_runs_after_warmup":
                payload["serving"]["compiler_runs_after_warmup"],
            "artifact_table_slab_bytes":
                payload["serving"]["artifact_table_slab_bytes"],
            "serving_speedup": payload["serving"]["serving_speedup"],
        },
        # micro-batching tier: the compile-once counters stay sharp, the
        # latency/QPS/occupancy numbers are host+interpret timings and
        # only gate collapses (wide tolerances)
        "serving_tier": {
            "retraces_after_warmup":
                payload["serving_tier"]["retraces_after_warmup"],
            "compiler_runs_after_warmup":
                payload["serving_tier"]["compiler_runs_after_warmup"],
            "qps": payload["serving_tier"]["qps"],
            "p99_ms": payload["serving_tier"]["p99_ms"],
            "batch_occupancy": payload["serving_tier"]["batch_occupancy"],
            # registry-observed engine counters across the closed-loop
            # run: deterministic (all 0 — the serving path never compiles
            # or touches the legacy memo mid-run), gated by equality
            "obs": dict(payload["serving_tier"]["obs"]),
        },
        # HTTP ingress under open-loop overload: sharp compile-once
        # counters through the full network path, collapse-only floors on
        # the self-normalizing overload ratios
        "ingress": {
            "retraces_after_warmup":
                payload["ingress"]["retraces_after_warmup"],
            "compiler_runs_after_warmup":
                payload["ingress"]["compiler_runs_after_warmup"],
            "overload_goodput_ratio":
                payload["ingress"]["overload_goodput_ratio"],
            "overload_rejection_rate":
                payload["ingress"]["overload_rejection_rate"],
        },
        # compile-time variant autotuner: the search must add zero
        # truth-table compiler runs (sharp), enumerate the same variant
        # count (sharp), and pick a plan no slower than the heuristic
        # default (collapse-only floor; the keys themselves are noisy)
        "autotune": {
            "compiler_runs_after_warmup":
                payload["autotune"]["compiler_runs_after_warmup"],
            "n_variants": payload["autotune"]["n_variants"],
            "speedup_vs_default": payload["autotune"]["speedup_vs_default"],
        },
    }


def check_against_baseline(payload: dict, baseline: dict, *,
                           speedup_tolerance: float = 0.25,
                           bytes_tolerance: float = 0.05,
                           pct_tolerance: float = 2.0,
                           recode_tolerance: float = 0.2,
                           mixed_speedup_tolerance: float = 0.5,
                           serving_speedup_tolerance: float = 0.5,
                           tier_timing_tolerance: float = 0.5,
                           ingress_tolerance: float = 0.75
                           ) -> list[str]:
    """Compare a bench payload against the committed baseline.

    Returns a list of human-readable regression descriptions (empty =
    pass).  ``fused_speedup`` is a timing ratio measured in interpret mode
    on shared runners, so it gets a wide (default 25%) tolerance on top of
    the bench's own median-of-3; the compile quantities are
    near-deterministic (same seeds, same tables) and only get small
    tolerances for cross-version float drift in table generation.
    ``mixed_fused_speedup`` gets a wider tolerance still (default 50%):
    the mixed kernel's per-group unroll makes its interpreter timing the
    noisiest gated ratio, and the deterministic ``mixed_slab_bytes``
    ceiling is the real regression signal for that path — the timing
    floor only catches collapses, not drift.  The ``serving`` section
    splits the same way: ``retraces_after_warmup`` /
    ``compiler_runs_after_warmup`` and the artifact slab bytes are
    byte-exact contract fields gated sharply (equality / small ceiling),
    while ``serving_speedup`` (artifact vs uncached per-call flags) is an
    interpret-mode ratio with the same wide 50% floor.
    """
    failures: list[str] = []

    # protocol guard: a full-mode or TPU run is not comparable with the
    # smoke/cpu baseline — refuse rather than gate apples against oranges
    for key in ("mode", "backend"):
        b, p = baseline.get(key), payload.get(key)
        if b is not None and p is not None and b != p:
            failures.append(
                f"{key} mismatch: this run has {key}={p!r} but the "
                f"baseline was recorded with {key}={b!r} — rerun with "
                "matching settings or refresh via --update-baseline")
    if failures:
        return failures

    def gate(label, got, base, tol, *, ceiling=False, fmt="{:.2f}x",
             note="tolerance"):
        """One multiplicative floor/ceiling check; base=None (a quantity
        the committed baseline predates) skips, keeping old baselines
        comparable."""
        if base is None:
            return
        got, base = float(got), float(base)
        bound = base * (1.0 + tol if ceiling else 1.0 - tol)
        if (got > bound) if ceiling else (got < bound):
            failures.append(
                f"{label} {fmt.format(got)} {'>' if ceiling else '<'} "
                f"{fmt.format(bound)} {'ceiling' if ceiling else 'floor'} "
                f"(baseline {fmt.format(base)} "
                f"{'plus' if ceiling else 'minus'} {tol:.0%} {note})")

    gate("fused_speedup", payload["fused_speedup"],
         baseline["fused_speedup"], speedup_tolerance,
         note="interpret-mode tolerance, fpga4hep model A")

    # (label, baseline section, payload section) — the payload nests the
    # per-level scalars one level deeper ("stats") than the flat baseline
    levels = [("level-2", baseline["compile"], payload["compile"]),
              ("level-3", baseline["compile"]["level3"],
               payload["compile"]["level3"])]
    for label, base, got in levels:
        # slab_reduction_pct's tolerance is additive (percentage points on
        # an already-relative quantity), so it stays outside gate()
        b = float(base["slab_reduction_pct"])
        p = float(got["slab_reduction_pct"])
        if p < b - pct_tolerance:
            failures.append(
                f"compile {label} slab_reduction_pct {p:.1f}% < "
                f"{b - pct_tolerance:.1f}% floor (baseline {b:.1f}% minus "
                f"{pct_tolerance} pp tolerance)")
        gate(f"compile {label} table_bytes_after",
             got["stats"]["table_bytes_after"], base["table_bytes_after"],
             bytes_tolerance, ceiling=True, fmt="{:.0f}")
    l3_base = baseline["compile"]["level3"]
    l3_got = payload["compile"]["level3"]
    # the re-encoding pass must keep narrowing buses; bits_saved telescopes
    # across fixpoint rounds so round-count refactors cannot move it
    # (magnitude regressions also surface via table_bytes_after above)
    if l3_base.get("bits_saved") is not None:
        gate("compile level-3 bits_saved", l3_got["stats"]["bits_saved"],
             l3_base["bits_saved"], recode_tolerance, fmt="{:.0f}")
    # mixed-width fused path: the compact slab must not creep back toward
    # the padded uniform figure (near-deterministic, small tolerance), and
    # the mixed kernel must not regress vs the per-layer path (timing
    # ratio, wide tolerance — see docstring); both skip on pre-mixed
    # baselines
    if l3_base.get("mixed_slab_bytes") is not None:
        gate("compile level-3 mixed_slab_bytes", l3_got["mixed_slab_bytes"],
             l3_base["mixed_slab_bytes"], bytes_tolerance, ceiling=True,
             fmt="{:.0f}")
    if l3_base.get("mixed_fused_speedup") is not None:
        gate("mixed_fused_speedup", l3_got["mixed_fused_speedup"],
             l3_base["mixed_fused_speedup"], mixed_speedup_tolerance,
             note="interpret-mode tolerance, generated fpga4hep model A "
                  "at level 3")
    # slab row-dedup: the entry count shared by content is deterministic
    # for the generated stack — a drop means the builder stopped sharing
    if l3_base.get("dedup_entries_saved") is not None:
        if (int(l3_got["dedup_entries_saved"])
                != int(l3_base["dedup_entries_saved"])):
            failures.append(
                f"compile level-3 dedup_entries_saved "
                f"{int(l3_got['dedup_entries_saved'])} != baseline "
                f"{int(l3_base['dedup_entries_saved'])} (sharp: slab "
                "row-dedup is deterministic on the generated stack)")
    # synth section (two-level minimization over reachable on-sets):
    # coverage counts are sharp; the reduction quantities are
    # deterministic but get collapse-only floors so a minimizer
    # heuristic change only fails the gate when it truly loses ground.
    # Skips entirely on a pre-synth baseline.
    sy_base = baseline.get("synth")
    if sy_base is not None:
        sy_got = payload["synth"]
        for fld in ("covered_neurons", "fallback_neurons"):
            if int(sy_got[fld]) != int(sy_base[fld]):
                failures.append(
                    f"synth {fld} {int(sy_got[fld])} != baseline "
                    f"{int(sy_base[fld])} (sharp: the minimization "
                    "budget must keep covering the same generated "
                    "neurons)")
        b = float(sy_base["literal_reduction_pct"])
        p = float(sy_got["literal_reduction_pct"])
        if p < b - pct_tolerance:
            failures.append(
                f"synth literal_reduction_pct {p:.1f}% < "
                f"{b - pct_tolerance:.1f}% floor (baseline {b:.1f}% minus "
                f"{pct_tolerance} pp tolerance)")
        gate("synth bound_over_measured", sy_got["bound_over_measured"],
             sy_base["bound_over_measured"], bytes_tolerance,
             note="collapse floor (worst-case lut_cost bound over the "
                  "measured k-LUT estimate; > 1 means synthesis beats "
                  "the bound)")
        if float(sy_got["bound_over_measured"]) <= 1.0:
            failures.append(
                f"synth bound_over_measured "
                f"{float(sy_got['bound_over_measured']):.2f} <= 1.0: the "
                "measured k-LUT estimate must beat the worst-case "
                "lut_cost bound on the generated stack")
    # serving section: the compile-once contract (sharp counters + a
    # byte-exact slab ceiling) and the timing ratio; skips entirely on a
    # pre-engine baseline
    s_base = baseline.get("serving")
    if s_base is not None:
        s_got = payload["serving"]
        for fld in ("retraces_after_warmup", "compiler_runs_after_warmup"):
            if int(s_got[fld]) != int(s_base[fld]):
                failures.append(
                    f"serving {fld} {int(s_got[fld])} != baseline "
                    f"{int(s_base[fld])} (sharp: the compile-once serving "
                    "contract allows no steady-state re-trace/re-compile)")
        gate("serving artifact_table_slab_bytes",
             s_got["artifact_table_slab_bytes"],
             s_base["artifact_table_slab_bytes"], bytes_tolerance,
             ceiling=True, fmt="{:.0f}")
        gate("serving_speedup", s_got["serving_speedup"],
             s_base["serving_speedup"], serving_speedup_tolerance,
             note="interpret-mode tolerance, CompiledLUTNet vs uncached "
                  "per-call flags on generated fpga4hep model A")
    # serving_tier section (micro-batching queue over the artifact): the
    # compile-once counters are the same sharp contract; QPS/p99/occupancy
    # are closed-loop host timings through an asyncio queue on a shared
    # runner — the noisiest numbers in the file — so they only gate
    # collapses (QPS halved, p99 doubled, occupancy halved), not drift;
    # skips entirely on a pre-tier baseline
    t_base = baseline.get("serving_tier")
    if t_base is not None:
        t_got = payload["serving_tier"]
        for fld in ("retraces_after_warmup", "compiler_runs_after_warmup"):
            if int(t_got[fld]) != int(t_base[fld]):
                failures.append(
                    f"serving_tier {fld} {int(t_got[fld])} != baseline "
                    f"{int(t_base[fld])} (sharp: the micro-batching tier "
                    "must keep the compile-once steady state — coalescing "
                    "and ragged padding included)")
        gate("serving_tier qps", t_got["qps"], t_base["qps"],
             tier_timing_tolerance, fmt="{:.1f}",
             note="closed-loop host-timing tolerance")
        gate("serving_tier p99_ms", t_got["p99_ms"], t_base["p99_ms"],
             tier_timing_tolerance / (1.0 - tier_timing_tolerance),
             ceiling=True, fmt="{:.2f}",
             note="closed-loop host-timing tolerance")
        gate("serving_tier batch_occupancy", t_got["batch_occupancy"],
             t_base["batch_occupancy"], tier_timing_tolerance,
             fmt="{:.2f}", note="coalescing-effectiveness floor")
        # registry-observed counter deltas: deterministic, equality-gated
        # (skips on a pre-obs baseline)
        o_base = t_base.get("obs")
        if o_base is not None:
            o_got = t_got.get("obs", {})
            for fld, want in sorted(o_base.items()):
                if int(o_got.get(fld, -1)) != int(want):
                    failures.append(
                        f"serving_tier obs.{fld} "
                        f"{int(o_got.get(fld, -1))} != baseline "
                        f"{int(want)} (sharp: registry-observed engine "
                        "counters are deterministic across the closed-loop "
                        "run)")
    # ingress section (open-loop HTTP overload): sharp compile-once
    # counters through the full network path; the overload ratios are
    # open-loop host timings against a per-run capacity estimate — both
    # sides move with the runner, so the ratios self-normalize, but they
    # still only gate collapses (goodput falling away under overload, or
    # the server ceasing to shed at 3x capacity); skips entirely on a
    # pre-ingress baseline
    i_base = baseline.get("ingress")
    if i_base is not None:
        i_got = payload["ingress"]
        for fld in ("retraces_after_warmup", "compiler_runs_after_warmup"):
            if int(i_got[fld]) != int(i_base[fld]):
                failures.append(
                    f"ingress {fld} {int(i_got[fld])} != baseline "
                    f"{int(i_base[fld])} (sharp: HTTP decode, quotas and "
                    "coalescing must keep the compile-once steady state)")
        gate("ingress overload_goodput_ratio",
             i_got["overload_goodput_ratio"],
             i_base["overload_goodput_ratio"], ingress_tolerance,
             note="open-loop host-timing tolerance (goodput at 3x offered "
                  "load over measured capacity)")
        gate("ingress overload_rejection_rate",
             i_got["overload_rejection_rate"],
             i_base["overload_rejection_rate"], ingress_tolerance,
             note="overload shedding floor (the server must keep "
                  "rejecting, not buffer or wedge, past capacity)")
    # autotune section (compile-time variant search): the search reuses
    # the already-compiled optimize result, so the compiler-run delta is
    # sharp; the variant count is deterministic for a fixed sweep (sharp);
    # speedup_vs_default is chosen-over-default from one timing table —
    # >= 1.0 by construction, so only a collapse (selection logic picking
    # a measurably slower plan) can trip the floor.  The chosen/default
    # keys are deliberately not equality-gated: near-tied variants swap
    # places run to run on shared runners.  Skips entirely on a
    # pre-autotune baseline.
    a_base = baseline.get("autotune")
    if a_base is not None:
        a_got = payload["autotune"]
        if (int(a_got["compiler_runs_after_warmup"])
                != int(a_base["compiler_runs_after_warmup"])):
            failures.append(
                f"autotune compiler_runs_after_warmup "
                f"{int(a_got['compiler_runs_after_warmup'])} != baseline "
                f"{int(a_base['compiler_runs_after_warmup'])} (sharp: the "
                "variant search must reuse the compiled result, never "
                "re-run the truth-table compiler)")
        if int(a_got["n_variants"]) != int(a_base["n_variants"]):
            failures.append(
                f"autotune n_variants {int(a_got['n_variants'])} != "
                f"baseline {int(a_base['n_variants'])} (sharp: the "
                "enumerated variant space is deterministic for a fixed "
                "sweep — a drop means eligible variants went missing)")
        gate("autotune speedup_vs_default", a_got["speedup_vs_default"],
             a_base["speedup_vs_default"], mixed_speedup_tolerance,
             note="selection floor (chosen variant vs heuristic default "
                  "from the same timing table; >= 1.0 by construction)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="fused-vs-per-layer comparison only, few iters")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="compare against this committed baseline JSON and "
                    "exit 1 on a perf/compile regression (the CI gate)")
    ap.add_argument("--update-baseline", nargs="?", const=BASELINE_PATH,
                    default=None, metavar="PATH",
                    help="run the smoke bench and (re)write the committed "
                    f"baseline (default: {BASELINE_PATH})")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="also dump the repro.obs metrics snapshot "
                    "(compile-pass timings, engine/tier counters) as JSON")
    ap.add_argument("--no-run-record", action="store_true",
                    help="skip writing the content-addressed run record "
                    "under benchmarks/runs/ (see run_record.py)")
    args = ap.parse_args()
    if args.update_baseline:
        args.smoke = True  # baselines are recorded in the mode CI runs

    if args.json:  # fail fast on an unwritable path, not after the bench
        with open(args.json, "a"):
            pass

    rows: list[Row] = [] if args.smoke else kernel_rows()
    net_rows, extras = lut_network_rows(smoke=args.smoke)
    rows += net_rows

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"# fused_speedup={extras.get('fused_speedup', float('nan')):.2f}x "
          f"(fpga4hep model A, {'smoke' if args.smoke else 'full'})")
    comp = extras.get("compile", {})
    if comp:
        print(f"# compile[{comp['case']}]: {comp['summary']}")
        print(f"# compile slab bytes: {comp['slab_bytes_raw']} -> "
              f"{comp['slab_bytes_optimized']} "
              f"(-{comp['slab_reduction_pct']:.1f}%)")
        print(f"# compile level3: {comp['level3']['summary']}")
        l3 = comp["level3"]
        print(f"# mixed fused slab: {l3['mixed_slab_bytes']} B "
              f"(table {l3['mixed_table_slab_bytes']} B, netlist-exact "
              f"{l3['netlist_table_bytes']} B; uniform "
              f"{l3['uniform_slab_bytes']} B), "
              f"speedup={l3['mixed_fused_speedup']:.2f}x vs per-layer")
        print(f"# mixed slab row-dedup: "
              f"{l3['mixed_table_slab_bytes_nodedup']} -> "
              f"{l3['mixed_table_slab_bytes']} B table slab "
              f"({l3['dedup_entries_saved']} entries shared)")
    sy = extras.get("synth", {})
    if sy:
        print(f"# synth[{sy['case']}]: "
              f"{sy['covered_neurons']}/{sy['neurons']} neurons covered "
              f"({sy['fallback_neurons']} fallback) in "
              f"{sy['synth_seconds']:.2f}s; literals "
              f"{sy['literals_before']} -> {sy['literals_after']} "
              f"(-{sy['literal_reduction_pct']:.1f}%), terms "
              f"{sy['terms_before']} -> {sy['terms_after']}; measured "
              f"{sy['est_kluts']} kLUTs vs bound {sy['lut_cost_bound']} "
              f"({sy['bound_over_measured']:.2f}x); SOP Verilog "
              f"bit-exact on {sy['verilog_words_checked']} words")
    srv = extras.get("serving", {})
    if srv:
        print(f"# serving[{srv['case']}]: {srv['engine_calls_per_sec']:.0f} "
              f"calls/s ({srv['us_engine_call']:.0f} us/call, layout "
              f"{srv['layout']}, table slab "
              f"{srv['artifact_table_slab_bytes']} B); "
              f"{srv['serving_speedup']:.0f}x vs uncached per-call flags "
              f"({srv['us_legacy_uncached']:.0f} us), "
              f"{srv['legacy_cached_overhead']:.2f}x overhead via memoized "
              f"legacy flags; retraces={srv['retraces_after_warmup']} "
              f"compiler_runs={srv['compiler_runs_after_warmup']} "
              "after warmup")
    tier = extras.get("serving_tier", {})
    if tier:
        print(f"# serving_tier[{tier['case']}]: p50={tier['p50_ms']:.1f}ms "
              f"p99={tier['p99_ms']:.1f}ms qps={tier['qps']:.0f} "
              f"({tier['rows_per_sec']:.0f} rows/s, "
              f"{tier['n_clients']} closed-loop clients); "
              f"occupancy={tier['batch_occupancy']:.2f} over "
              f"{tier['batches']} batches "
              f"(mean {tier['mean_batch_rows']:.1f} rows), "
              f"{tier['n_devices']} device(s); "
              f"retraces={tier['retraces_after_warmup']} "
              f"compiler_runs={tier['compiler_runs_after_warmup']} "
              "after warmup")
        bd = tier.get("latency_breakdown", {})
        legs = " ".join(
            f"{stage}={bd[stage]['mean_ms']:.2f}ms"
            for stage in ("queue_wait", "assembly", "device")
            if bd.get(stage, {}).get("count"))
        if legs:
            print(f"# serving_tier latency breakdown (means): {legs}")
    ing = extras.get("ingress", {})
    if ing:
        print(f"# ingress[{ing['case']}]: capacity~{ing['capacity_rps']:.0f} "
              f"rps closed-loop; open-loop via HTTP:")
        for name, lv in ing["levels"].items():
            print(f"#   {name:>5} ({lv['offered_rps']:.0f} rps offered): "
                  f"p50={lv['p50_ms']:.1f}ms p99={lv['p99_ms']:.1f}ms "
                  f"goodput={lv['goodput_rps']:.0f} rps "
                  f"rejection_rate={lv['rejection_rate']:.2f} "
                  f"outcomes={lv['outcomes']}")
        print(f"# ingress overload: goodput_ratio="
              f"{ing['overload_goodput_ratio']:.2f} rejection_rate="
              f"{ing['overload_rejection_rate']:.2f}; "
              f"retraces={ing['retraces_after_warmup']} "
              f"compiler_runs={ing['compiler_runs_after_warmup']} "
              "after warmup")
    at = extras.get("autotune", {})
    if at:
        print(f"# autotune[{at['case']}]: chose {at['chosen']} "
              f"({at['timings_us'][at['chosen']]:.0f} us/call) over "
              f"default {at['default']} "
              f"({at['timings_us'][at['default']]:.0f} us/call), "
              f"{at['speedup_vs_default']:.2f}x, {at['n_variants']} "
              f"variants timed at batch={at['batch']} in "
              f"{at['search_seconds']:.1f}s; "
              f"compiler_runs={at['compiler_runs_after_warmup']} "
              "during search")

    payload = {
        "benchmark": "kernel_bench",
        "mode": "smoke" if args.smoke else "full",
        "backend": jax.default_backend(),
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
        **extras,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")

    from repro import obs
    if args.metrics_json:
        obs.registry().dump_json(args.metrics_json)
        print(f"# wrote metrics snapshot {args.metrics_json}")

    if not args.no_run_record:
        try:
            from benchmarks.run_record import write_run_record
        except ImportError:        # run as a bare script, not -m
            from run_record import write_run_record
        spec = {"benchmark": "kernel_bench",
                "mode": payload["mode"], "backend": payload["backend"]}
        rec = write_run_record(spec, payload,
                               metrics=obs.registry().snapshot())
        print(f"# wrote run record {rec}")

    if args.update_baseline:
        base_dir = os.path.dirname(args.update_baseline)
        if base_dir:
            os.makedirs(base_dir, exist_ok=True)
        with open(args.update_baseline, "w") as f:
            json.dump(baseline_from_payload(payload), f, indent=2)
            f.write("\n")
        print(f"# wrote baseline {args.update_baseline}")

    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        failures = check_against_baseline(payload, baseline)
        if failures:
            for msg in failures:
                print(f"# REGRESSION: {msg}")
            sys.exit(1)
        print(f"# baseline check passed vs {args.baseline}")


if __name__ == "__main__":
    main()
