"""Kernel microbenchmarks: us/call for the Pallas kernels vs jnp refs.

On this CPU container the Pallas numbers are *interpreter* timings
(functional only — the TPU target compiles natively); the jnp-ref rows are
the meaningful CPU timings.  Both are reported so the harness shape is
complete.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import flash_attention, lut_lookup, masked_matmul

Row = tuple[str, float, str]


def _bench(fn, *args, iters=20, warmup=3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_rows() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(0)

    # lut_lookup: a 64-neuron LogicNets layer at inference batch 256
    b, n_in, n_out, fi, bw = 256, 64, 64, 3, 2
    codes = jax.random.randint(key, (b, n_in), 0, 2 ** bw, dtype=jnp.int32)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(np.stack([
        np.sort(rng.choice(n_in, fi, replace=False))
        for _ in range(n_out)]).astype(np.int32))
    table = jax.random.randint(key, (n_out, 2 ** (fi * bw)), 0, 2 ** bw,
                               dtype=jnp.int32)
    jref = jax.jit(lambda c: ref.lut_lookup_ref(c, idx, table, bw))
    rows.append(("kernel/lut_lookup_ref_jnp", _bench(jref, codes),
                 f"batch={b} neurons={n_out}"))
    rows.append(("kernel/lut_lookup_pallas_interp",
                 _bench(lambda c: lut_lookup(c, idx, table, bw), codes,
                        iters=3, warmup=1), "interpret-mode timing"))

    # masked_matmul: LogicNet-FFN shape
    m, k, n = 512, 512, 2048
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(key, (k, n), jnp.float32)
    mask = (jax.random.uniform(key, (k, n)) > 0.9).astype(jnp.float32)
    jref = jax.jit(lambda a: ref.masked_matmul_ref(a, w, mask))
    rows.append(("kernel/masked_matmul_ref_jnp", _bench(jref, x),
                 f"{m}x{k}x{n}"))
    rows.append(("kernel/masked_matmul_pallas_interp",
                 _bench(lambda a: masked_matmul(a, w, mask), x, iters=3,
                        warmup=1), "interpret-mode timing"))

    # flash attention: 2k prefill slice
    bq, hq, hkv, s, d = 1, 8, 2, 1024, 64
    q = jax.random.normal(key, (bq, hq, s, d), jnp.bfloat16)
    kk = jax.random.normal(key, (bq, hkv, s, d), jnp.bfloat16)
    v = jax.random.normal(key, (bq, hkv, s, d), jnp.bfloat16)
    jref = jax.jit(lambda a: ref.flash_attention_ref(a, kk, v, causal=True))
    rows.append(("kernel/flash_attention_ref_jnp", _bench(jref, q, iters=5),
                 f"S={s} Hq={hq} GQA"))
    return rows
