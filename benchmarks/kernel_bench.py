"""Kernel microbenchmarks: us/call for the Pallas kernels vs jnp refs.

On this CPU container the Pallas numbers are *interpreter* timings
(functional only — the TPU target compiles natively); the jnp-ref rows are
the meaningful CPU timings.  Both are reported so the harness shape is
complete.

Also a CLI (used by the CI bench-smoke step)::

    PYTHONPATH=src python -m benchmarks.kernel_bench --json BENCH_kernel.json
    PYTHONPATH=src python -m benchmarks.kernel_bench --smoke --json out.json

``--smoke`` restricts to the fused-vs-per-layer LUT-network comparison on
the fpga4hep topologies at reduced iteration counts, emitting the
``fused_speedup`` field the perf trajectory tracks.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compile as rcompile
from repro.kernels import ref
from repro.kernels.lut_lookup import lut_lookup_pallas
from repro.kernels.lut_network import (build_network_slabs,
                                       estimate_slab_bytes,
                                       lut_network_pallas)
from repro.kernels.ops import (FUSED_VMEM_BUDGET_BYTES, flash_attention,
                               lut_lookup, masked_matmul)

Row = tuple[str, float, str]


def _bench(fn, *args, iters=20, warmup=3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_rows() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(0)

    # lut_lookup: a 64-neuron LogicNets layer at inference batch 256
    b, n_in, n_out, fi, bw = 256, 64, 64, 3, 2
    codes = jax.random.randint(key, (b, n_in), 0, 2 ** bw, dtype=jnp.int32)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(np.stack([
        np.sort(rng.choice(n_in, fi, replace=False))
        for _ in range(n_out)]).astype(np.int32))
    table = jax.random.randint(key, (n_out, 2 ** (fi * bw)), 0, 2 ** bw,
                               dtype=jnp.int32)
    jref = jax.jit(lambda c: ref.lut_lookup_ref(c, idx, table, bw))
    rows.append(("kernel/lut_lookup_ref_jnp", _bench(jref, codes),
                 f"batch={b} neurons={n_out}"))
    rows.append(("kernel/lut_lookup_pallas_interp",
                 _bench(lambda c: lut_lookup(c, idx, table, bw), codes,
                        iters=3, warmup=1), "interpret-mode timing"))

    # masked_matmul: LogicNet-FFN shape
    m, k, n = 512, 512, 2048
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(key, (k, n), jnp.float32)
    mask = (jax.random.uniform(key, (k, n)) > 0.9).astype(jnp.float32)
    jref = jax.jit(lambda a: ref.masked_matmul_ref(a, w, mask))
    rows.append(("kernel/masked_matmul_ref_jnp", _bench(jref, x),
                 f"{m}x{k}x{n}"))
    rows.append(("kernel/masked_matmul_pallas_interp",
                 _bench(lambda a: masked_matmul(a, w, mask), x, iters=3,
                        warmup=1), "interpret-mode timing"))

    # flash attention: 2k prefill slice
    bq, hq, hkv, s, d = 1, 8, 2, 1024, 64
    q = jax.random.normal(key, (bq, hq, s, d), jnp.bfloat16)
    kk = jax.random.normal(key, (bq, hkv, s, d), jnp.bfloat16)
    v = jax.random.normal(key, (bq, hkv, s, d), jnp.bfloat16)
    jref = jax.jit(lambda a: ref.flash_attention_ref(a, kk, v, causal=True))
    rows.append(("kernel/flash_attention_ref_jnp", _bench(jref, q, iters=5),
                 f"S={s} Hq={hq} GQA"))
    return rows


# ---------------------------------------------------------------------------
# Fused whole-network LUT engine vs the per-layer path
# ---------------------------------------------------------------------------

def _random_stack(widths, fan_in, bw, seed=0):
    rng = np.random.default_rng(seed)
    layers = []
    for n_in, n_out in zip(widths[:-1], widths[1:]):
        fi = min(fan_in, n_in)
        idx = np.stack([np.sort(rng.choice(n_in, fi, replace=False))
                        for _ in range(n_out)]).astype(np.int32)
        tab = rng.integers(0, 2 ** bw, (n_out, 2 ** (fi * bw)),
                           dtype=np.int32)
        layers.append((idx, tab, bw))
    return layers

# Sparse stacks of the paper's own topologies (fpga4hep Table 6.1): the
# fused engine's headline comparison runs on model A's 3-layer stack.
LUT_NETWORK_CASES = {
    # name: (widths, fan_in, bw, batch)
    "fpga4hep_modelA": ((16, 64, 64, 64), 3, 3, 128),
    "jsc_deep": ((16, 64, 64, 64, 64), 3, 2, 128),
}


def _slab_report(layers, opt=None) -> dict:
    """Raw-vs-optimized slab footprint + fused-path eligibility.

    ``opt`` takes pre-optimized triples when the caller already ran the
    compiler (avoids compiling the same stack twice).
    """
    if opt is None:
        opt = rcompile.optimize_triples(layers, level=2)
    raw_bytes, _, raw_f32 = estimate_slab_bytes(layers)
    opt_bytes, _, opt_f32 = estimate_slab_bytes(opt)
    # eligibility mirrors ops.lut_network's actual gate: slabs under the
    # VMEM budget AND codes exact in the kernel's f32 one-hot gathers
    return {
        "slab_bytes_raw": raw_bytes,
        "slab_bytes_optimized": opt_bytes,
        "slab_reduction_pct": 100.0 * (1.0 - opt_bytes / raw_bytes),
        "fused_eligible_raw": (raw_f32
                               and raw_bytes <= FUSED_VMEM_BUDGET_BYTES),
        "fused_eligible_optimized": (opt_f32
                                     and opt_bytes
                                     <= FUSED_VMEM_BUDGET_BYTES),
    }


def lut_network_rows(smoke: bool = False) -> tuple[list[Row], dict]:
    """Per-layer vs fused whole-network inference on LogicNet stacks.

    Returns (rows, extras); ``extras['fused_speedup']`` is the headline
    per-layer/fused ratio on the fpga4hep model A stack — the number the
    BENCH artifacts track.  Both paths run through Pallas (interpret mode
    off-TPU), jitted, so timings compare execution not tracing.  Each case
    also records raw-vs-``repro.compile``-optimized slab bytes and fused
    eligibility, so the compiler's effect on the fused path is tracked
    over time alongside the speedup.
    """
    iters, warmup = (5, 2) if smoke else (20, 3)
    rows: list[Row] = []
    extras: dict = {"cases": {}}
    for name, (widths, fan_in, bw, batch) in LUT_NETWORK_CASES.items():
        layers = _random_stack(widths, fan_in, bw, seed=len(name))
        slabs = build_network_slabs(layers)
        jl = [(jnp.asarray(i), jnp.asarray(t), b) for i, t, b in layers]
        codes = jnp.asarray(np.random.default_rng(0).integers(
            0, 2 ** bw, (batch, widths[0]), dtype=np.int32))
        interp = jax.default_backend() != "tpu"

        fused = jax.jit(
            lambda c, s=slabs: lut_network_pallas(c, s, interpret=interp))

        def per_layer(c, jl=jl):
            for i, t, b in jl:
                c = lut_lookup_pallas(c, i, t, b, interpret=interp)
            return c
        per = jax.jit(per_layer)

        np.testing.assert_array_equal(np.asarray(fused(codes)),
                                      np.asarray(per(codes)))
        us_per = _bench(per, codes, iters=iters, warmup=warmup)
        us_fused = _bench(fused, codes, iters=iters, warmup=warmup)
        speedup = us_per / us_fused
        n_layers = len(layers)
        rows.append((f"kernel/lut_network_perlayer[{name}]", us_per,
                     f"batch={batch} layers={n_layers}"))
        rows.append((f"kernel/lut_network_fused[{name}]", us_fused,
                     f"speedup={speedup:.2f}x vs per-layer"))
        extras["cases"][name] = {
            "layers": n_layers, "batch": batch, "bw": bw, "fan_in": fan_in,
            "us_per_layer_path": us_per, "us_fused": us_fused,
            "fused_speedup": speedup,
            "slab_bytes": slabs.vmem_bytes(), "packed": slabs.packed,
            **_slab_report(layers),
        }
        if name == "fpga4hep_modelA":
            extras["fused_speedup"] = speedup
    extras["compile"] = compile_stats_case()
    return rows, extras


def compile_stats_case() -> dict:
    """Truth-table compiler on a *generated* fpga4hep model A stack.

    Random tables barely compress (every code is emitted, no structure);
    the compiler's real effect shows on tables generated from an actual
    quantized model, so this is the stack the acceptance numbers and the
    CI compile-stats artifact track: raw vs optimized packed table bytes,
    fused-slab bytes, and the per-pass reduction statistics.
    """
    import jax as _jax
    from repro.configs import fpga4hep
    from repro.core import logicnet as LN

    cfg = fpga4hep.model_a()
    model = LN.init(cfg, _jax.random.PRNGKey(0))
    x = _jax.random.uniform(_jax.random.PRNGKey(1),
                            (256, cfg.in_features), minval=-1, maxval=3)
    _, model = LN.forward(cfg, model, x, train=True)   # settle BN stats
    tables = LN.generate_tables(cfg, model)
    res = rcompile.optimize(tables, level=2, in_features=cfg.in_features)
    triples = [(tt.indices, tt.table, tt.bw_in) for tt in tables]
    opt_triples = [(tt.indices, tt.table, tt.bw_in) for tt in res.tables]
    report = {
        "case": "fpga4hep_modelA_generated",
        "level": 2,
        **_slab_report(triples, opt=opt_triples),
        "stats": res.stats.as_dict(),
        "summary": rcompile.summarize(res.stats),
    }
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write results to this path")
    ap.add_argument("--smoke", action="store_true",
                    help="fused-vs-per-layer comparison only, few iters")
    args = ap.parse_args()

    if args.json:  # fail fast on an unwritable path, not after the bench
        with open(args.json, "a"):
            pass

    rows: list[Row] = [] if args.smoke else kernel_rows()
    net_rows, extras = lut_network_rows(smoke=args.smoke)
    rows += net_rows

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(f"# fused_speedup={extras.get('fused_speedup', float('nan')):.2f}x "
          f"(fpga4hep model A, {'smoke' if args.smoke else 'full'})")
    comp = extras.get("compile", {})
    if comp:
        print(f"# compile[{comp['case']}]: {comp['summary']}")
        print(f"# compile slab bytes: {comp['slab_bytes_raw']} -> "
              f"{comp['slab_bytes_optimized']} "
              f"(-{comp['slab_reduction_pct']:.1f}%)")

    if args.json:
        payload = {
            "benchmark": "kernel_bench",
            "mode": "smoke" if args.smoke else "full",
            "backend": jax.default_backend(),
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows],
            **extras,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
