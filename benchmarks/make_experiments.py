"""Assemble EXPERIMENTS.md: replace the <!-- --> markers with tables
generated from the dry-run artifacts.  Idempotent (markers are kept as
section anchors, content between marker and next blank-marker boundary is
regenerated)."""

from __future__ import annotations

import re

from benchmarks import perf_report, roofline

SUGGEST = {
    ("train", "memory"): ("activation traffic dominates: larger fused "
                          "blocks (TPU backend fuses far better than the "
                          "CPU pipeline measured here), remat='dots' to "
                          "stop recomputing matmuls, bf16 master grads"),
    ("train", "collective"): ("gradient sync: constrain grads to the "
                              "sharded param layout (reduce-scatter, not "
                              "all-reduce) and overlap with backward"),
    ("train", "compute"): ("MXU-bound: raise per-chip batch or drop remat"),
    ("prefill", "memory"): ("KV/activation streaming: bigger attention "
                            "chunks amortize q-block rewrites; keep logits "
                            "last-position-only (done)"),
    ("prefill", "collective"): ("all-gather of FSDP weights per layer: "
                                "prefetch next layer's gather during "
                                "current compute"),
    ("prefill", "compute"): ("compute-bound: good place to be at 32k"),
    ("decode", "memory"): ("cache traffic: dynamic_update_slice cache "
                           "write (variant 'dus') instead of whole-cache "
                           "blend; int8 KV is the next lever"),
    ("decode", "collective"): ("replicated small-kv attention all-reduces: "
                               "shard cache on sequence for batch-1 cells"),
    ("decode", "compute"): ("compute-bound decode is rare; check "
                            "speculative decoding"),
}

# expect: 'down' (dominant term predicted to fall), 'neutral' (predicted
# within ~10%), 'regression' (predicted to get worse — recorded on purpose)
HILLCLIMBS = [
    {
        "arch": "qwen3-moe-235b-a22b", "shape": "train_4k",
        "title": "HC1 — qwen3-moe-235b x train_4k (flagship scale; "
                 "paper-era GShard dispatch is the waste)",
        "variants": ["moe_sorted", "moe_sorted_gradrs", "dots",
                     "moe_sorted_local", "moe_sorted_local_dots"],
        "expect": {"moe_sorted": "down", "moe_sorted_gradrs": "down",
                   "dots": "down", "moe_sorted_local": "down",
                   "moe_sorted_local_dots": "down"},
        "metric": {"dots": "compute_s"},
        "hypotheses": [
            ("moe_sorted",
             "H1: dense one-hot dispatch+combine einsums cost "
             "~2·(E·C)/(3k·d_ff) ≈ 0.56x of expert FLOPs per MoE layer "
             "(E·C=10240, k=8, d_ff=1536) plus the (G,S,E,C) tensor "
             "traffic; sort-based ragged dispatch removes both. Predict "
             "compute −25–35%, memory −15–30%.  **Measured: REFUTED — "
             "compute −11%, but memory 4.7x and collectives 9.6x worse.** "
             "Root cause (debugged forward, not reverted): the GLOBAL "
             "argsort over 1M (token,k) pairs forces XLA to reshard the "
             "entire token stream across the mesh; sorting is not "
             "shard-local.  Lesson -> H1b."),
            ("moe_sorted_local",
             "H1b: keep the dense path's 1024-token groups (resident on "
             "their data shard) and sort *within* groups — collective "
             "pattern identical to dense, one-hot einsums gone. Predict "
             "compute −15–30% vs baseline with memory/collectives ~flat. "
             "**Measured: REFUTED again** — per-type breakdown localizes "
             "it: GSPMD lowers the in-group scatter-add into "
             "partial-scatter + **all-reduce of the whole expert slab** "
             "(all-reduce 5x, slab all-to-all 21x baseline).  Lesson: "
             "under *automatic* partitioning, one-hot einsum dispatch is "
             "the right choice because einsums partition cleanly; ragged "
             "dispatch needs shard_map with explicit all_to_all (manual "
             "collectives), which we record as the next step rather than "
             "ship a regression.  The paper-era dense dispatch baseline "
             "stands."),
            ("moe_sorted_gradrs",
             "H2: constraining grads to the sharded param layout should "
             "turn a 2x-wire all-reduce into reduce-scatter. Predict "
             "collective −40–55%.  **Measured: REFUTED (no-op)** — the "
             "partitioner already reduce-scatters FSDP param grads; the "
             "surviving all-reduces are the TP activation-grad syncs, "
             "which are structural to tensor parallelism (sequence "
             "parallelism is the known next lever; future work)."),
            ("dots",
             "H3: full remat recomputes the whole forward in backward "
             "(~8·N·D vs 6·N·D); checkpoint_dots keeps matmul outputs. "
             "Predict compute −15–25%, peak memory up.  Measured: compute "
             "−23% **confirmed**, useful 0.42→0.55 — but the saved "
             "activations re-read in backward push the *memory* term up "
             "63%, and this cell is memory-bound: full remat is the "
             "better end-to-end policy here (recompute is cheaper than "
             "traffic).  Split verdict, recorded."),
            ("moe_sorted_local_dots", "H1b + H3 combined."),
        ],
    },
    {
        "arch": "gemma3-27b", "shape": "train_4k",
        "title": "HC2 — gemma3-27b x train_4k (most collective-bound "
                 "baseline)",
        "variants": ["gradrs", "gradrs_dots", "noremat", "tp_only"],
        "expect": {"gradrs": "down", "gradrs_dots": "down",
                   "noremat": "down", "tp_only": "regression"},
        "metric": {"noremat": "compute_s", "gradrs_dots": "compute_s"},
        "hypotheses": [
            ("gradrs", "H4: reduce-scatter argument as H2 on a dense 27B "
                       "model. **Measured: REFUTED (no-op), same root "
                       "cause as H2** — XLA already optimal on param "
                       "grads; dominant all-reduce is TP activation-grad "
                       "sync (~28 layers x B·S·d/16)."),
            ("gradrs_dots", "H5: checkpoint_dots; predict compute −15–25% "
                            "at higher memory traffic (saved activations "
                            "re-read in backward)."),
            ("noremat",
             "H14: this cell peaks at 2.4 GiB/chip under full remat — "
             "13+ GiB of HBM headroom means recomputation buys nothing. "
             "Predict remat=none cuts the compute term 20–25% (backward "
             "no longer replays forward) and lifts useful-compute toward "
             "0.9.  Measured: compute −19%, useful 0.74→0.91, collective "
             "−12% — **confirmed** on the backend-portable metrics.  The "
             "'bytes accessed' term *rises* because the XLA-CPU pipeline "
             "counts every saved-activation read at fusion granularity it "
             "does not have — flagged as a measurement artifact (the TPU "
             "backend fuses these); on real hardware no-remat with "
             "headroom is the standard MFU win."),
            ("tp_only", "H6 (planned refutation): pure TP replicates "
                        "weights+optimizer over the data axis — predicted "
                        "to blow past 16 GB/chip peak; recorded to show "
                        "why fsdp_tp is the default policy.  Measured: "
                        "peak 2.4 -> 37.7 GiB/chip, terms ~flat: "
                        "**confirmed (regression as predicted)** — "
                        "fsdp_tp stays the default."),
        ],
    },
    {
        "arch": "qwen3-1.7b", "shape": "train_4k",
        "title": "HC3 — qwen3-1.7b x train_4k + LogicNet-FFN (the paper's "
                 "technique cell)",
        "variants": ["logicnet_ffn", "logicnet_ffn_shardmask",
                     "logicnet_ffn_noremat", "noremat"],
        "expect": {"logicnet_ffn": "neutral",
                   "logicnet_ffn_shardmask": "neutral",
                   "logicnet_ffn_noremat": "down", "noremat": "down"},
        "metric": {"logicnet_ffn_noremat": "compute_s",
                   "noremat": "compute_s"},
        "hypotheses": [
            ("logicnet_ffn",
             "H7: the paper's per-neuron fan-in masks price *LUTs*, not "
             "MXU FLOPs — the masked matmul is a dense matmul with a "
             "free elementwise mask; activation fake-quant is cheap VPU "
             "work. Predict roofline terms within ~10% of the dense "
             "baseline: the technique is roofline-neutral at LM scale "
             "while enabling truth-table conversion of narrow heads.  "
             "Measured: terms confirmed neutral, BUT peak memory 0.3 -> "
             "16.1 GiB/chip — the masks replicated (they matched the "
             "'small tensors replicate' default rule).  Lesson -> H7b."),
            ("logicnet_ffn_shardmask",
             "H7b: shard masks exactly like the weights they gate "
             "(P(fsdp, tp)). Predict peak memory back to ~baseline with "
             "terms unchanged."),
            ("logicnet_ffn_noremat",
             "H9: H14's no-remat argument on the technique cell (peak "
             "0.37 GiB/chip — massive headroom). Predict compute −15–25% "
             "with useful toward 0.7+.  Measured: compute −19%, useful "
             "0.58→0.72 — **confirmed**; combined with the shard-mask "
             "fix this is the production LogicNet-FFN configuration."),
            ("noremat",
             "H14 control on the dense cell: same no-remat win without "
             "the technique (compute −19%, useful 0.59→0.72) — the "
             "paper's sparsity+QAT remains roofline-neutral relative to "
             "this optimized dense baseline as well."),
        ],
    },
    {
        "arch": "qwen3-1.7b", "shape": "decode_32k",
        "title": "HC4 (bonus) — qwen3-1.7b x decode_32k (memory-bound "
                 "decode)",
        "variants": ["dus", "dus_seqshard"],
        "expect": {"dus": "down", "dus_seqshard": "down"},
        "metric": {"dus": "memory_s", "dus_seqshard": "peak_bytes"},
        "hypotheses": [
            ("dus",
             "H10: the baseline one-hot cache blend reads+writes the "
             "whole 32k KV cache every token (~3x cache bytes incl. the "
             "attention read); dynamic_update_slice writes one token. "
             "Predict memory term −50–70%, leaving the attention "
             "cache-read as the floor."),
            ("dus_seqshard",
             "H12: kv_heads=8 < TP degree 16 replicated the cache "
             "(baseline peak 56 GiB/chip — would NOT fit 16 GB v5e HBM: "
             "the baseline is compile-able but not deployable). Sharding "
             "the cache sequence dim over the model axis is always "
             "divisible; decode attention becomes partial-softmax + "
             "all-reduce. Objective is *feasibility*: predict peak "
             "~/16 on the cache share with roughly term-neutral traffic "
             "(the partial-softmax combine adds some). This is the "
             "deployable decode config."),
        ],
    },
    {
        "arch": "qwen3-moe-235b-a22b", "shape": "decode_32k",
        "title": "HC4b — qwen3-moe-235b x decode_32k (same fixes at "
                 "scale)",
        "variants": ["dus", "dus_seqshard"],
        "expect": {"dus": "down", "dus_seqshard": "down"},
        "metric": {"dus": "memory_s", "dus_seqshard": "peak_bytes"},
        "hypotheses": [("dus", "H11: as H10."),
                       ("dus_seqshard", "H13: as H12 (baseline peak "
                                        "97.5 GiB/chip -> fits after).")],
    },
]


def perf_log() -> str:
    out = []
    for hc in HILLCLIMBS:
        rows = perf_report.compare(hc["arch"], hc["shape"], hc["variants"])
        out.append(f"### {hc['title']}\n")
        for name, text in hc["hypotheses"]:
            out.append(f"* **{name}** — {text}")
        out.append("")
        out.append(perf_report.markdown(rows))
        # verdicts against pre-registered expectations
        if rows:
            base = rows[0]
            for r in rows[1:]:
                dom = base["dominant"]
                delta = (r[f"{dom}_s"] - base[f"{dom}_s"]) \
                    / max(base[f"{dom}_s"], 1e-12) * 100
                peak_b = (base.get("peak_bytes") or 0) / 2 ** 30
                peak_v = (r.get("peak_bytes") or 0) / 2 ** 30
                expect = hc.get("expect", {}).get(r["variant"], "down")
                metric = hc.get("metric", {}).get(r["variant"])
                if metric:  # verdict keyed on a specific term
                    mv = r.get(metric) or 0
                    mb = base.get(metric) or 0
                    mdelta = (mv - mb) / max(mb, 1e-12) * 100
                else:
                    mdelta = delta
                if expect == "down":
                    verdict = ("**confirmed**" if mdelta < -5 else
                               ("**refuted**" if mdelta > 5 else "neutral"))
                    if metric:
                        verdict += f" ({metric} {mdelta:+.1f}%)"
                elif expect == "neutral":
                    verdict = ("**confirmed (neutral as predicted)**"
                               if abs(delta) <= 12 else "**refuted**")
                else:  # regression expected
                    verdict = ("**confirmed (regression as predicted)**"
                               if peak_v > peak_b * 2 or delta > 5
                               else "**refuted**")
                out.append(
                    f"* measured `{r['variant']}`: baseline-dominant "
                    f"({dom}) {delta:+.1f}%, peak "
                    f"{peak_b:.1f}→{peak_v:.1f} GiB/chip, roofline frac "
                    f"x{r['roofline_fraction'] / max(base['roofline_fraction'], 1e-12):.2f}"
                    f" -> {verdict}")
        out.append("")
    return "\n".join(out)


def perf_summary() -> str:
    """Scored per metric: the CPU-measured memory term is an upper bound
    (see §Roofline caveats), so the portable score axes are the compute
    term / useful-compute ratio and deployability (peak HBM)."""
    lines = ["| hillclimb cell | compute s: base → best (variant) "
             "| useful: base → best | peak GiB: base → best (variant) |",
             "|" + "---|" * 3]
    for hc in HILLCLIMBS:
        rows = perf_report.compare(hc["arch"], hc["shape"], hc["variants"])
        if not rows:
            continue
        base = rows[0]
        # exclude planned regressions from "best"
        cand = [r for r in rows
                if hc.get("expect", {}).get(r["variant"]) != "regression"]
        bc = min(cand, key=lambda r: r["compute_s"])
        bu = max(cand, key=lambda r: r["useful_ratio"])
        bp = min(cand, key=lambda r: (r.get("peak_bytes") or 1e18))
        lines.append(
            f"| {hc['arch']} x {hc['shape']} "
            f"| {base['compute_s']:.3g} → {bc['compute_s']:.3g} "
            f"({bc['variant']}, "
            f"{(bc['compute_s']/base['compute_s']-1)*100:+.0f}%) "
            f"| {base['useful_ratio']:.2f} → {bu['useful_ratio']:.2f} "
            f"| {(base.get('peak_bytes') or 0)/2**30:.1f} → "
            f"{(bp.get('peak_bytes') or 0)/2**30:.1f} ({bp['variant']}) |")
    return "\n".join(lines)


def roofline_notes() -> str:
    rows = [r for r in roofline.full_table(variant="baseline")
            if r.get("status") == "ok" and r.get("mesh") == "16x16"]
    out = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        sug = SUGGEST.get((r["kind"], r["dominant"]), "")
        out.append(f"* **{r['arch']} × {r['shape']}** — bound by "
                   f"**{r['dominant']}** "
                   f"(MODEL_FLOPS={r['model_flops_global']:.2e}, "
                   f"useful={r['useful_ratio']:.2f}); to move it: {sug}.")
    return "\n".join(out)


MARKERS = {
    "DRYRUN_TABLE_16x16": lambda: roofline.dryrun_markdown(mesh="16x16"),
    "DRYRUN_TABLE_2x16x16": lambda: roofline.dryrun_markdown(
        mesh="2x16x16"),
    "ROOFLINE_TABLE": lambda: roofline.markdown_table(
        [r for r in roofline.full_table(variant="baseline")
         if r.get("mesh") == "16x16" or r.get("status") != "ok"]),
    "ROOFLINE_NOTES": roofline_notes,
    "PERF_LOG": perf_log,
    "PERF_SUMMARY": perf_summary,
}


def main() -> None:
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    for name, fn in MARKERS.items():
        marker = f"<!-- {name} -->"
        begin = f"<!-- BEGIN {name} -->"
        end = f"<!-- END {name} -->"
        block = f"{begin}\n{fn()}\n{end}"
        if begin in text:
            text = re.sub(re.escape(begin) + r".*?" + re.escape(end),
                          block, text, flags=re.S)
        else:
            text = text.replace(marker, block)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md assembled")


if __name__ == "__main__":
    main()
