"""One benchmark per paper table (deliverable d).

Each function returns a list of CSV rows (name, us_per_call, derived).
Training rows use synthetic stand-ins for FPGA4HEP/MNIST (offline
container, DESIGN.md §6): LUT-cost columns are exact; accuracy columns
validate *trends* (bit-width up -> acc up; iterative >= a-priori; skips
free), not absolute paper numbers.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import fpga4hep, mnist as mnist_cfg
from repro.core import logicnet as LN
from repro.core import lut_cost as LC
from repro.core.train import auc_roc_ovr, train_logicnet
from repro.core.truth_table import (generate_sparse_linear_table,
                                    minimized_lut_estimate)
from repro.core import layers as L
from repro.core.quantize import QuantizerCfg
from repro.data import jet_substructure_data, mnist_like_data

Row = tuple[str, float, str]


def _timed(fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    return out, (time.perf_counter() - t0) * 1e6


# ---------------------------------------------------------------------------

def table_2_1() -> list[Row]:
    """Static mapping cost to 6:1 LUTs (exact reproduction)."""
    rows = []
    expect = {6: 1, 7: 3, 8: 5, 9: 11, 10: 21, 11: 43}
    for f, n in expect.items():
        r, us = _timed(LC.static_mapping_row, f)
        ok = r.n_6luts == n
        rows.append((f"table2.1/fanin{f}", us,
                     f"n6luts={r.n_6luts} expected={n} "
                     f"util={r.pct_utilized:.2f}% exact={ok}"))
    return rows


def table_5_1() -> list[Row]:
    """Truth-table generation size/time vs fan-in bits (paper: 15-20b)."""
    rows = []
    for bits in (8, 12, 16):
        fan_in, bw = bits // 2, 2
        cfg = L.SparseLinearCfg(in_features=max(fan_in * 2, 16),
                                out_features=1, fan_in=fan_in, bw_in=bw)
        layer = L.sparse_linear_init(cfg, jax.random.PRNGKey(0))
        (tt), us = _timed(generate_sparse_linear_table, cfg, layer,
                          QuantizerCfg(bw))
        from repro.core.netlist import build_netlist
        from repro.core.verilog import generate_verilog
        nl = build_netlist([tt], cfg.in_features)
        files = generate_verilog(nl)
        vsize = sum(len(t) for t in files.values()) / 1e6
        rows.append((f"table5.1/{bits}bit", us,
                     f"verilog_mb={vsize:.3f} entries={tt.n_entries}"))
    return rows


def table_5_2(budget: int = 300) -> list[Row]:
    """Analytical LUT cost vs post-'synthesis' estimate.

    Vivado is unavailable offline; the minimization proxy (constant bits,
    duplicate neurons, dead inputs) is a *lower* bound on what synthesis
    finds, reported in the paper's (analytical, synthesized, reduction)
    format.
    """
    x, y = jet_substructure_data(4000, seed=1)
    rows = []
    for name in ("C", "E"):
        cfg = fpga4hep.MODELS[name]()
        res = train_logicnet(cfg, x[:3500], y[:3500], x[3500:], y[3500:],
                             method="apriori", steps=budget)
        tables = LN.generate_tables(cfg, res.model)
        analytical = sum(cfg.luts()[:len(tables)])
        t0 = time.perf_counter()
        minimized = sum(minimized_lut_estimate(t) for t in tables)
        us = (time.perf_counter() - t0) * 1e6
        red = analytical / max(minimized, 1)
        rows.append((f"table5.2/model{name}", us,
                     f"analytical={analytical} minimized={minimized} "
                     f"reduction={red:.2f}x"))
    return rows


def table_6_1() -> list[Row]:
    """Model descriptions A-E: per-layer analytical LUTs (exact columns)."""
    expected = {
        "A": [2112, 2112, 2112], "B": [4224, 2112, 1056],
        "C": [128, 64, 64], "D": [2688, 1344, 1344, 3400],
        "E": [640, 640, 640, 200],
    }
    rows = []
    for name, fn in fpga4hep.MODELS.items():
        cfg = fn()
        luts, us = _timed(cfg.luts)
        want = expected[name]
        got = luts[:len(want)]
        rows.append((f"table6.1/model{name}", us,
                     f"luts={got} expected={want} exact={got == want}"))
    return rows


def table_6_2(budget: int = 300) -> list[Row]:
    """JSC classification: AUC-ROC + total LUTs per model (A-E)."""
    x, y = jet_substructure_data(6000, seed=0)
    xt, yt, xv, yv = x[:5000], y[:5000], x[5000:], y[5000:]
    rows = []
    for name, fn in fpga4hep.MODELS.items():
        cfg = fn()
        t0 = time.perf_counter()
        res = train_logicnet(cfg, xt, yt, xv, yv, method="apriori",
                             steps=budget)
        us = (time.perf_counter() - t0) * 1e6 / budget
        aucs = auc_roc_ovr(cfg, res.model, xv, yv)
        avg = float(np.nanmean(list(aucs.values()))) * 100
        rows.append((f"table6.2/model{name}", us,
                     f"avg_auc={avg:.2f} acc={res.accuracy:.3f} "
                     f"luts={cfg.total_luts()}"))
    return rows


def table_6_3(budget: int = 300) -> list[Row]:
    """A-priori fixed sparsity vs iterative pruning (JSC)."""
    x, y = jet_substructure_data(6000, seed=2)
    xt, yt, xv, yv = x[:5000], y[:5000], x[5000:], y[5000:]
    rows = []
    for name in ("C", "E"):
        cfg = fpga4hep.MODELS[name]()
        accs = {}
        for method in ("apriori", "iterative"):
            # thesis: iterative pruning "takes about 10x longer to train";
            # 2x here keeps the comparison honest on a small budget.
            res = train_logicnet(cfg, xt, yt, xv, yv, method=method,
                                 steps=budget * (2 if method == "iterative"
                                                 else 1), seed=3)
            aucs = auc_roc_ovr(cfg, res.model, xv, yv)
            accs[method] = float(np.nanmean(list(aucs.values()))) * 100
        rows.append((f"table6.3/model{name}", 0.0,
                     f"apriori={accs['apriori']:.2f} "
                     f"iterative={accs['iterative']:.2f}"))
    return rows


def _mnist_data(n_train=4000, n_test=800):
    x, y = mnist_like_data(n_train + n_test, seed=0)
    x = x.reshape(len(x), -1)
    # Center: pixels are in [0,1]; a 1-bit QuantHardTanh input quantizer
    # thresholds at 0, so uncentered images would quantize to a constant.
    x = (x - x.mean()) / (x.std() + 1e-6)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]


def table_7_1(budget: int = 250) -> list[Row]:
    """MNIST MLP width/depth sweep: LUTs vs accuracy."""
    xt, yt, xv, yv = _mnist_data()
    rows = []
    for hidden, bw, fan_in in [((512,), 2, 6), ((1024,), 2, 5),
                               ((512, 512), 2, 6),
                               ((1024, 1024), 2, 5),
                               ((512, 512, 512), 2, 6)]:
        cfg = mnist_cfg.mlp(hidden, bw, fan_in)
        res = train_logicnet(cfg, xt, yt, xv, yv, method="apriori",
                             steps=budget, lr=5e-3)
        tag = "x".join(map(str, hidden))
        rows.append((f"table7.1/{tag}_bw{bw}_x{fan_in}", 0.0,
                     f"acc={res.accuracy:.4f} luts={cfg.total_luts()}"))
    return rows


def fig_7_2_bitwidth(budget: int = 250) -> list[Row]:
    """Accuracy vs bit-width (Fig 7.2/6.8): bw 1->2 helps, 2->3 less."""
    xt, yt, xv, yv = _mnist_data()
    rows = []
    for bw in (1, 2, 3):
        cfg = mnist_cfg.mlp((512, 512), bw, 5)
        res = train_logicnet(cfg, xt, yt, xv, yv, method="apriori",
                             steps=budget, lr=5e-3)
        rows.append((f"fig7.2/bw{bw}", 0.0,
                     f"acc={res.accuracy:.4f} luts={cfg.total_luts()}"))
    return rows


def table_7_2(budget: int = 250) -> list[Row]:
    """Pruning methods on MNIST: a-priori vs momentum vs iterative."""
    xt, yt, xv, yv = _mnist_data()
    cfg = mnist_cfg.mlp((512, 512), 2, 6)
    rows = []
    for method in ("apriori", "momentum", "iterative"):
        res = train_logicnet(cfg, xt, yt, xv, yv, method=method,
                             steps=budget * (2 if method == "iterative"
                                             else 1), lr=5e-3, seed=5)
        rows.append((f"table7.2/{method}", 0.0,
                     f"acc={res.accuracy:.4f}"))
    return rows


def table_7_3(budget: int = 250) -> list[Row]:
    """Skip connections: accuracy up, sparse-layer LUT cost unchanged."""
    xt, yt, xv, yv = _mnist_data()
    rows = []
    for n_skip, skips in [(0, ()), (1, ((0, 2),)), (2, ((0, 2), (1, 3)))]:
        cfg = mnist_cfg.mlp((256, 256, 256), 2, 6, skips=skips)
        res = train_logicnet(cfg, xt, yt, xv, yv, method="apriori",
                             steps=budget, lr=5e-3, seed=7)
        sparse_luts = sum(cfg.luts()[:3])
        rows.append((f"table7.3/skip{n_skip}", 0.0,
                     f"acc={res.accuracy:.4f} sparse_luts={sparse_luts}"))
    return rows


def table_7_4(budget: int = 200) -> list[Row]:
    """Convolution ablation (FP / FP_DW / FP_X_DW / QUANT_X_DW) on the
    SparseConv stack: quantization costs the most accuracy (§7)."""
    from repro.core.layers import (SparseConvCfg, sparse_conv_apply,
                                   sparse_conv_init)
    x, y = mnist_like_data(2400, seed=1)
    xt, yt, xv, yv = x[:2000], y[:2000], x[2000:], y[2000:]

    def make_forward(variant):
        cc = SparseConvCfg(in_channels=1, out_channels=16, kernel_size=3,
                           stride=2,
                           x_k=9 if variant in ("FP", "FP_DW") else 5,
                           x_s=16 if variant in ("FP", "FP_DW") else 5,
                           bw_in=8 if variant != "QUANT_X_DW" else 2,
                           bw_mid=8 if variant != "QUANT_X_DW" else 2,
                           first_layer=True)
        return cc

    rows = []
    for variant in ("FP_DW", "FP_X_DW", "QUANT_X_DW"):
        cc = make_forward(variant)
        key = jax.random.PRNGKey(11)
        conv = sparse_conv_init(cc, key)
        head_cfg = LN.LogicNetCfg(16 * 13 * 13, 10, hidden=(128,),
                                  fan_in=6, bw=2, final_dense=True,
                                  bw_fc=2)
        head = LN.init(head_cfg, jax.random.PRNGKey(12))

        from repro.optim.adamw import (AdamWCfg, adamw_update,
                                       init_opt_state)
        params = {"conv": conv["params"],
                  "head": [l["params"] for l in head]}
        opt = init_opt_state(params)
        ocfg = AdamWCfg(lr=5e-3, clip_norm=1.0)
        conv_masks = {"dw": conv["mask_dw"], "pw": conv["mask_pw"]}
        head_masks = [l.get("mask") for l in head]
        state = {"conv_bn": conv["bn_state"],
                 "head_bn": [l.get("bn_state") for l in head]}

        @jax.jit
        def step(params, opt, state, xb, yb):
            def loss(params):
                cl = {"params": params["conv"], "mask_dw": conv_masks["dw"],
                      "mask_pw": conv_masks["pw"],
                      "bn_state": state["conv_bn"]}
                h, cl2 = sparse_conv_apply(cc, cl, xb, train=True)
                h = h.reshape(h.shape[0], -1)
                mdl = [
                    {"params": p,
                     **({"mask": m} if m is not None else {}),
                     "bn_state": s}
                    for p, m, s in zip(params["head"], head_masks,
                                       state["head_bn"])]
                nll, mdl2 = LN.loss_fn(head_cfg, mdl, h, yb, train=True)
                return nll, (cl2["bn_state"],
                             [l["bn_state"] for l in mdl2])

            (nll, (cbn, hbn)), g = jax.value_and_grad(loss, has_aux=True)(
                params)
            new_p, new_o = adamw_update(ocfg, params, g, opt)
            return new_p, new_o, {"conv_bn": cbn, "head_bn": hbn}, nll

        rng = np.random.default_rng(0)
        for i in range(budget):
            idx = rng.integers(0, len(xt), 128)
            params, opt, state, nll = step(params, opt, state,
                                           jnp.asarray(xt[idx]),
                                           jnp.asarray(yt[idx]))

        @jax.jit
        def predict(params, state, xb):
            cl = {"params": params["conv"], "mask_dw": conv_masks["dw"],
                  "mask_pw": conv_masks["pw"], "bn_state": state["conv_bn"]}
            h, _ = sparse_conv_apply(cc, cl, xb, train=False)
            h = h.reshape(h.shape[0], -1)
            mdl = [
                {"params": p, **({"mask": m} if m is not None else {}),
                 "bn_state": s}
                for p, m, s in zip(params["head"], head_masks,
                                   state["head_bn"])]
            logits, _ = LN.forward(head_cfg, mdl, h, train=False)
            return logits

        logits = predict(params, state, jnp.asarray(xv))
        acc = float((jnp.argmax(logits, -1) == jnp.asarray(yv)).mean())
        rows.append((f"table7.4/{variant}", 0.0, f"acc={acc:.4f}"))
    return rows


def all_tables(quick: bool = False) -> list[Row]:
    b = 120 if quick else 300
    bm = 100 if quick else 250
    parts = [
        ("table2.1", table_2_1, {}),
        ("table5.1", table_5_1, {}),
        ("table5.2", table_5_2, {"budget": b}),
        ("table6.1", table_6_1, {}),
        ("table6.2", table_6_2, {"budget": b}),
        ("table6.3", table_6_3, {"budget": b}),
        ("table7.1", table_7_1, {"budget": bm}),
        ("fig7.2", fig_7_2_bitwidth, {"budget": bm}),
        ("table7.2", table_7_2, {"budget": bm}),
        ("table7.3", table_7_3, {"budget": bm}),
        ("table7.4", table_7_4, {"budget": 80 if quick else 200}),
    ]
    rows: list[Row] = []
    for name, fn, kw in parts:
        try:
            rows += fn(**kw)
        except Exception as e:  # isolate: one table must not sink the run
            rows.append((f"{name}/ERROR", 0.0, repr(e)))
    return rows
