"""§Perf before/after comparisons between baseline and variant artifacts."""

from __future__ import annotations

from benchmarks.roofline import analyze, load_cells


def _cell(cells, arch, shape, mesh="16x16", variant=None):
    key = f"{arch}__{shape}__{mesh}"
    if variant and variant != "baseline":
        key += f"__{variant}"
    base = cells.get(key)
    if base is None or base.get("status") != "ok":
        return None
    u2rec = None
    for suffix in ("__u2", "__u3"):
        alt = cells.get(key + suffix)
        if alt and alt.get("status") == "ok":
            u2rec = alt
    return analyze(base, u2rec)


def compare(arch: str, shape: str, variants: list[str],
            out_dir: str = "experiments/dryrun") -> list[dict]:
    """Rows: baseline first, then each variant with deltas vs baseline."""
    cells = load_cells(out_dir)
    base = _cell(cells, arch, shape)
    rows = []
    if base is None:
        return rows
    base["delta_dom"] = "—"
    rows.append(base)
    for v in variants:
        r = _cell(cells, arch, shape, variant=v)
        if r is None:
            continue
        dom = base["dominant"]
        key = f"{dom}_s"
        r["delta_dom"] = (f"{(r[key] - base[key]) / base[key] * 100:+.1f}%"
                          f" on baseline-dominant ({dom})")
        rows.append(r)
    return rows


def markdown(rows: list[dict]) -> str:
    if not rows:
        return "_(artifacts missing)_"
    hdr = ("| variant | compute s | memory s | coll s | dominant | "
           "useful | roofline frac | Δ dominant term |")
    lines = [hdr, "|" + "---|" * 8]
    for r in rows:
        lines.append(
            f"| {r['variant']} | {r['compute_s']:.3e} | {r['memory_s']:.3e}"
            f" | {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.4f} "
            f"| {r.get('delta_dom', '')} |")
    return "\n".join(lines)
