"""Roofline analysis from dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), applies
the two-point while-loop cost fit, and reports per (arch x shape x mesh):

    compute term    = HLO_FLOPs / peak_FLOPs            [s, per chip]
    memory term     = HLO_bytes / HBM_bw                [s, per chip]
    collective term = collective_bytes / link_bw        [s, per chip]

plus the dominant term, MODEL_FLOPS = {6,2}*N*D, the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs, and the roofline fraction (ideal compute time /
bottleneck time).

Fit: XLA cost_analysis counts while-loop bodies once.  With layer-scan
bodies widened to u copies, every metric is linear in u:
m(u) = fixed + u*c, so   true = m(1) + (L - 1) * (m(u2) - m(1)) / (u2 - 1)
with L the layer-scan trip count.  (Attention KV-chunk loops are fully
unrolled at lowering time, so they are inside c already.)

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (3D-torus links are counted via the wire-factor applied
in launch/hlo_stats.py).
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

COST_KEYS = ("flops", "bytes accessed", "transcendentals")


def load_cells(out_dir: str = "experiments/dryrun") -> dict[str, dict]:
    cells: dict[str, dict] = {}
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        key = os.path.basename(path)[:-5]
        cells[key] = rec
    return cells


def _fit(base: dict, u2rec: dict | None) -> dict:
    """Two-point correction of cost/collective metrics."""
    L = base.get("scan_length", 1)
    u2 = (u2rec or {}).get("scan_unroll", None)
    out = {"corrected": u2 is not None, "scan_length": L}

    def corr(m1: float, m2: float | None) -> float:
        if m2 is None or u2 in (None, 1):
            return m1
        c = max((m2 - m1) / (u2 - 1), 0.0)
        return m1 + (L - 1) * c

    cost = {}
    for k in COST_KEYS:
        m1 = (base.get("cost") or {}).get(k)
        m2 = (u2rec or {}).get("cost", {}).get(k) if u2rec else None
        if m1 is not None:
            cost[k] = corr(m1, m2)
    out["cost"] = cost
    coll = {}
    for k, v in (base.get("collectives") or {}).items():
        if k.startswith("n_"):
            coll[k] = v
            continue
        v2 = (u2rec or {}).get("collectives", {}).get(k) if u2rec else None
        coll[k] = corr(v, v2)
    out["collectives"] = coll
    return out


def analyze(base: dict, u2rec: dict | None) -> dict:
    fit = _fit(base, u2rec)
    flops = fit["cost"].get("flops", 0.0)
    mem_bytes = fit["cost"].get("bytes accessed", 0.0)
    coll_bytes = fit["collectives"].get("total", 0.0)
    chips = base.get("chips", 256)

    compute_s = flops / PEAK_FLOPS
    memory_s = mem_bytes / HBM_BW
    collective_s = coll_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    n_active = base.get("active_params", base.get("params", 0))
    d_tokens = (base["global_batch"] * base["seq_len"]
                if base["kind"] in ("train", "prefill")
                else base["global_batch"])
    mult = 6 if base["kind"] == "train" else 2
    model_flops_global = mult * n_active * d_tokens
    model_flops_chip = model_flops_global / chips
    useful_ratio = model_flops_chip / flops if flops else 0.0
    ideal_s = model_flops_chip / PEAK_FLOPS
    bound_s = max(terms.values())
    roofline_fraction = ideal_s / bound_s if bound_s else 0.0
    # Bandwidth fraction: minimal traffic (read every argument byte once —
    # params/opt-state/caches) over the measured memory term.  The honest
    # score for memory-bound cells (decode especially).
    arg_bytes = (base.get("memory") or {}).get("argument_bytes") or 0
    bw_fraction = (arg_bytes / HBM_BW) / memory_s if memory_s else 0.0

    return {
        "arch": base["arch"], "shape": base["shape"], "mesh": base["mesh"],
        "variant": base.get("variant", "baseline"),
        "kind": base["kind"], "chips": chips,
        "corrected": fit["corrected"],
        "flops_chip": flops, "bytes_chip": mem_bytes,
        "coll_bytes_chip": coll_bytes,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_global": model_flops_global,
        "useful_ratio": useful_ratio,
        "roofline_fraction": roofline_fraction,
        "bw_fraction": bw_fraction,
        "peak_bytes": (base.get("memory") or {}).get("peak_bytes"),
        "collectives": fit["collectives"],
    }


def full_table(out_dir: str = "experiments/dryrun",
               variant: str | None = None) -> list[dict]:
    cells = load_cells(out_dir)
    rows = []
    for key, rec in cells.items():
        if key.endswith("__u2") or key.endswith("__u3"):
            continue
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"],
                         "variant": rec.get("variant", "baseline"),
                         "status": "skipped",
                         "skip_reason": rec.get("skip_reason")})
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                         "mesh": rec.get("mesh"), "status": "FAILED"})
            continue
        if variant is not None and rec.get("variant") != variant:
            continue
        u2rec = None
        for suffix in ("__u2", "__u3"):
            alt = cells.get(key + suffix)
            if alt and alt.get("status") == "ok":
                u2rec = alt
        row = analyze(rec, u2rec)
        row["status"] = "ok"
        rows.append(row)
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | variant | compute s | memory s | "
           "coll s | dominant | useful | roofline frac | bw frac |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r.get("arch") or "",
                                         r.get("shape") or "",
                                         r.get("mesh") or "")):
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"{r.get('variant','-')} | — | — | — | skipped "
                         f"| — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r.get('arch')} | {r.get('shape')} | "
                         f"{r.get('mesh')} | - | — | — | — | FAILED | — "
                         f"| — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['variant']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {r.get('bw_fraction', 0):.3f} |")
    return "\n".join(lines)


def dryrun_markdown(out_dir: str = "experiments/dryrun",
                    mesh: str | None = None,
                    variant: str = "baseline") -> str:
    """§Dry-run table: status, per-chip peak bytes, raw HLO flops,
    collective mix, compile time — straight from the artifacts."""
    cells = load_cells(out_dir)
    hdr = ("| arch | shape | mesh | status | peak GiB/chip | HLO flops "
           "(raw) | coll GiB (raw) | top collective | compile s |")
    lines = [hdr, "|" + "---|" * 9]
    for key in sorted(cells):
        if key.endswith(("__u2", "__u3")):
            continue
        r = cells[key]
        if r.get("variant", "baseline") != variant:
            continue
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped ({r['skip_reason'][:48]}…) | — | — | — "
                         f"| — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r.get('arch')} | {r.get('shape')} | "
                         f"{r.get('mesh')} | FAILED | — | — | — | — | — |")
            continue
        peak = (r.get("memory") or {}).get("peak_bytes") or 0
        coll = r.get("collectives", {})
        mix = {k: v for k, v in coll.items()
               if not k.startswith("n_") and k != "total"}
        top = max(mix, key=mix.get) if mix else "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {peak / 2**30:.2f} | {r['cost'].get('flops', 0):.2e} "
            f"| {coll.get('total', 0) / 2**30:.2f} | {top} "
            f"| {r.get('compile_s', 0)} |")
    return "\n".join(lines)


def main() -> None:
    rows = full_table()
    print(markdown_table(rows))
    with open("experiments/roofline.json", "w") as f:
        json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
