"""Benchmark harness entry point.

Prints ``name,us_per_call,derived`` CSV: one block per paper table
(benchmarks/paper_tables.py), the kernel microbenchmarks, and — when
dry-run artifacts exist — the roofline summary (benchmarks/roofline.py).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table6]
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced training budgets")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_tables

    rows = []
    rows += paper_tables.all_tables(quick=args.quick)
    rows += kernel_bench.kernel_rows()
    rows += kernel_bench.lut_network_rows(smoke=args.quick)[0]

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        if args.only and args.only not in name:
            continue
        print(f"{name},{us:.1f},{derived}")

    # Roofline summary from dry-run artifacts, if present.
    try:
        from benchmarks import roofline
        cells = roofline.full_table()
        ok = [r for r in cells if r.get("status") == "ok"]
        if ok:
            print(f"# roofline: {len(ok)} cells analyzed "
                  f"(see experiments/roofline.json)")
            for r in ok:
                print(f"roofline/{r['arch']}__{r['shape']}__{r['mesh']}"
                      f"__{r['variant']},0.0,"
                      f"dominant={r['dominant']} "
                      f"compute_s={r['compute_s']:.3e} "
                      f"memory_s={r['memory_s']:.3e} "
                      f"coll_s={r['collective_s']:.3e} "
                      f"useful={r['useful_ratio']:.2f} "
                      f"frac={r['roofline_fraction']:.4f}")
    except Exception as e:  # artifacts absent: fine
        print(f"# roofline: skipped ({e!r})", file=sys.stderr)


if __name__ == "__main__":
    main()
