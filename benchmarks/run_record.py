"""Immutable bench run ledger: ``benchmarks/runs/<hash>.json``.

Every ``kernel_bench`` invocation appends one self-describing record to
``benchmarks/runs/`` so a perf number can always be traced back to the
exact configuration and code revision that produced it:

* ``spec`` — the run configuration (benchmark name, mode, backend) and
  its ``spec_hash`` (sha256 of the canonical JSON), so records of the
  *same* experiment are groupable across time while any config change
  yields a new hash — the run's meaning is pinned, never silently
  redefined;
* ``git_rev`` — the commit the bench ran at (None outside a checkout);
* ``payload`` — the full bench JSON (the same content ``--json`` writes);
* ``metrics`` — the ``repro.obs`` registry snapshot at exit, so the
  compile-pass timings and engine counters behind the numbers ride along.

The filename is the sha256 of the whole record (content-addressed):
re-running the identical bench at the identical revision with identical
numbers is a no-op, while any difference — timings included — lands a new
file.  Records are never rewritten; ``benchmarks/runs/*.json`` is
gitignored (the committed ledger is the baseline under
``benchmarks/baselines/``), and CI uploads the fresh record as an
artifact of each bench-smoke run.
"""

from __future__ import annotations

import hashlib
import json
import os
import subprocess
import time

RUNS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "runs")
SCHEMA_VERSION = 1


def canonical_json(obj) -> str:
    """Deterministic JSON: sorted keys, no whitespace (hash input)."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def spec_hash(spec: dict) -> str:
    """sha256 of the canonical spec — the run's identity."""
    return hashlib.sha256(canonical_json(spec).encode()).hexdigest()


def git_rev() -> str | None:
    """The checkout's HEAD commit, or None when not in a git repo."""
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
    except (OSError, subprocess.SubprocessError):
        return None
    rev = proc.stdout.strip()
    return rev if proc.returncode == 0 and rev else None


def build_record(spec: dict, payload: dict, metrics: dict | None = None,
                 *, rev: str | None = None,
                 timestamp: float | None = None) -> dict:
    """Assemble a run record (pure; no filesystem access)."""
    return {
        "schema_version": SCHEMA_VERSION,
        "spec": dict(spec),
        "spec_hash": spec_hash(spec),
        "git_rev": git_rev() if rev is None else rev,
        "timestamp": time.time() if timestamp is None else timestamp,
        "payload": payload,
        "metrics": metrics or {},
    }


def record_hash(record: dict) -> str:
    """Content address of a full record (the filename stem)."""
    return hashlib.sha256(canonical_json(record).encode()).hexdigest()


def write_run_record(spec: dict, payload: dict,
                     metrics: dict | None = None, *,
                     out_dir: str | None = None,
                     rev: str | None = None,
                     timestamp: float | None = None) -> str:
    """Write one content-addressed record; returns its path.

    An existing file under the same hash has byte-identical content by
    construction, so it is left untouched (records are immutable).
    """
    record = build_record(spec, payload, metrics, rev=rev,
                          timestamp=timestamp)
    out_dir = out_dir or RUNS_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{record_hash(record)[:16]}.json")
    if not os.path.exists(path):
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    return path


__all__ = ["RUNS_DIR", "SCHEMA_VERSION", "build_record", "canonical_json",
           "git_rev", "record_hash", "spec_hash", "write_run_record"]
