"""Quickstart: the LogicNets flow end-to-end in under a minute.

Train a tiny sparse-quantized net on the jet-substructure stand-in,
convert every neuron to a truth table, verify the tables match the
quantized network bit-exactly, compile a serving artifact (one compiler
run, one slab build, one jit — then save/load round-trip), and emit
Verilog.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

from repro import engine
from repro.configs import fpga4hep
from repro.core import logicnet as LN
from repro.core.quantize import codes as quant_codes
from repro.core.train import train_logicnet
from repro.data import jet_substructure_data


def main() -> None:
    # 1. Data + topology (paper Table 6.1 model C: (64,32,32), BW=2, X=3).
    x, y = jet_substructure_data(4000, seed=0)
    cfg = fpga4hep.model_c()
    print(f"model C: per-layer LUTs {cfg.luts()}  total {cfg.total_luts()}")

    # 2. Train with a-priori fixed sparsity.
    res = train_logicnet(cfg, x[:3500], y[:3500], x[3500:], y[3500:],
                         method="apriori", steps=300)
    print(f"test accuracy: {res.accuracy:.3f}")

    # 3. Convert NEQs -> truth tables; functional verification.  The table
    # path runs through the fused whole-network Pallas engine (one kernel
    # for the entire sparse stack — the TPU shape of the FPGA pipeline).
    tables = LN.generate_tables(cfg, res.model)
    f_codes, t_codes = LN.verify_tables(cfg, res.model, tables,
                                        x[3500:3600], fused=True)
    exact = bool((np.asarray(f_codes) == np.asarray(t_codes)).all())
    print(f"truth-table functional verification (fused kernel): "
          f"{'EXACT MATCH' if exact else 'MISMATCH'}")
    assert exact

    # 4. Compile the serving artifact: the compiler + slab build + jit run
    # once, then every call serves from VMEM-resident slabs (the
    # deployment path; the fused= / optimize_level= flags above are thin
    # compatibility wrappers over this same engine).
    net = engine.compile_network(tables, optimize_level=3,
                                 in_features=cfg.in_features)
    print(f"compiled artifact: layout={net.layout} "
          f"table slab {net.vmem_breakdown()['table_slab_bytes']} B "
          f"(raw {net.stats.table_bytes_before} B)")
    in_codes = quant_codes(cfg.layer_cfgs()[0].in_quant, x[3500:3600])
    assert bool((np.asarray(net(in_codes)) == np.asarray(t_codes)).all())

    # 5. Save/load round-trip: deployment loads the .npz straight into the
    # exact slabs — no compiler on the serving host.
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "logicnet_c.npz")
        net.save(path)
        reloaded = engine.load(path)
        exact = bool((np.asarray(reloaded(in_codes))
                      == np.asarray(t_codes)).all())
        print(f"artifact round-trip ({os.path.getsize(path)} B npz): "
              f"{'EXACT MATCH' if exact else 'MISMATCH'}")
    assert exact

    # 6. Emit Verilog (Listings 5.2-5.6 structure).
    files = LN.to_verilog(cfg, res.model)
    print(f"generated {len(files)} Verilog modules "
          f"({sum(map(len, files.values())) / 1e3:.1f} kB)")
    print("\n".join(files["LogicNetModule.v"].splitlines()[:4]))


if __name__ == "__main__":
    main()
