"""Batched serving driver: continuous-batching decode loop (deliverable b).

A minimal production-shaped server core: a request queue, a fixed-width
decode batch with slot recycling (a finished request's slot is refilled
from the queue next step), per-slot KV caches/positions, greedy sampling.
This is the same decode_step the dry-run lowers for the decode_32k /
long_500k cells.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-1.7b \
        --requests 12 --slots 4 --max-new 24
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.steps import make_decode_step, make_train_state
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if cfg.enc_dec or cfg.vision_tokens:
        raise SystemExit("demo server supports decoder-only archs")
    params = make_train_state(cfg, jax.random.PRNGKey(0))["params"]
    decode = jax.jit(make_decode_step(cfg))

    rng = np.random.default_rng(0)
    queue = [{"id": i,
              "prompt": rng.integers(1, cfg.vocab,
                                     rng.integers(4, 12)).tolist()}
             for i in range(args.requests)]
    done: list[dict] = []

    cache = M.init_cache(cfg, args.slots, args.cache_len)
    pos = jnp.zeros((args.slots,), jnp.int32)
    cur_tok = jnp.zeros((args.slots, 1), jnp.int32)
    slots: list[dict | None] = [None] * args.slots

    def admit():
        nonlocal pos, cur_tok
        for s in range(args.slots):
            if slots[s] is None and queue:
                req = queue.pop(0)
                slots[s] = {"id": req["id"], "prompt": req["prompt"],
                            "fed": 0, "out": []}
                pos = pos.at[s].set(0)
                cur_tok = cur_tok.at[s, 0].set(req["prompt"][0])
                slots[s]["fed"] = 1

    admit()
    t0 = time.perf_counter()
    steps = 0
    while any(s is not None for s in slots):
        logits, cache = decode(params, cache, cur_tok, pos)
        next_ids = np.asarray(jnp.argmax(logits, axis=-1))
        pos = pos + 1
        steps += 1
        for s in range(args.slots):
            req = slots[s]
            if req is None:
                continue
            if req["fed"] < len(req["prompt"]):      # still prefilling
                cur_tok = cur_tok.at[s, 0].set(req["prompt"][req["fed"]])
                req["fed"] += 1
                continue
            req["out"].append(int(next_ids[s]))
            cur_tok = cur_tok.at[s, 0].set(int(next_ids[s]))
            if (len(req["out"]) >= args.max_new
                    or int(pos[s]) >= args.cache_len - 1):
                done.append(req)
                slots[s] = None                      # recycle the slot
        admit()
    dt = time.perf_counter() - t0

    total_new = sum(len(r["out"]) for r in done)
    print(f"served {len(done)} requests, {total_new} tokens in "
          f"{steps} decode steps ({dt:.1f}s, "
          f"{1e3 * dt / max(steps, 1):.0f} ms/step, "
          f"batch occupancy {total_new / max(steps * args.slots, 1):.2f})")
    for r in done[:3]:
        print(f"  req {r['id']}: prompt {len(r['prompt'])} toks -> "
              f"{r['out'][:8]}...")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
