"""Full paper pipeline on the FPGA4HEP task (thesis ch. 6).

Select a Table 6.1 model (A-E) and a sparsity method, train, report
per-class AUC-ROC, functionally verify the truth tables, compare the
analytical LUT cost with the logic-minimization proxy (Table 5.2), and
write the Verilog netlist to --out.

    PYTHONPATH=src python examples/train_jsc_logicnet.py \
        --model E --method iterative --steps 600 --out /tmp/logicnet_e
"""

import argparse
import os

import numpy as np

from repro import engine
from repro.configs import fpga4hep
from repro.core import logicnet as LN
from repro.core.train import auc_roc_ovr, train_logicnet
from repro.core.truth_table import minimized_lut_estimate
from repro.data import jet_substructure_data

CLASSES = ["g", "q", "W", "Z", "t"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="C", choices=list("ABCDE"))
    ap.add_argument("--method", default="apriori",
                    choices=["apriori", "iterative", "momentum"])
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--out", default=None)
    ap.add_argument("--pipeline-registers", action="store_true")
    ap.add_argument("--optimize-level", type=int, default=2,
                    help="truth-table compiler level (0 disables; see "
                         "repro.compile)")
    args = ap.parse_args()

    cfg = fpga4hep.MODELS[args.model]()
    print(f"model {args.model}: HL={cfg.hidden} BW={cfg.bw} X={cfg.fan_in} "
          f"LUTs={cfg.luts()} (total {cfg.total_luts()})")

    x, y = jet_substructure_data(8000, seed=0)
    xt, yt, xv, yv = x[:7000], y[:7000], x[7000:], y[7000:]
    res = train_logicnet(cfg, xt, yt, xv, yv, method=args.method,
                         steps=args.steps)
    aucs = auc_roc_ovr(cfg, res.model, xv, yv)
    for c, name in enumerate(CLASSES):
        print(f"  AUC-ROC[{name}] = {aucs[c] * 100:.2f}")
    print(f"  avg AUC-ROC = "
          f"{np.nanmean(list(aucs.values())) * 100:.2f}   "
          f"accuracy = {res.accuracy:.3f}")

    tables = LN.generate_tables(cfg, res.model)
    f_codes, t_codes = LN.verify_tables(cfg, res.model, tables, xv[:200])
    assert (np.asarray(f_codes) == np.asarray(t_codes)).all(), \
        "truth-table verification failed"
    print("truth-table functional verification: EXACT")

    analytical = sum(cfg.luts()[:len(tables)])
    minimized = sum(minimized_lut_estimate(t) for t in tables)
    print(f"analytical LUTs {analytical} vs minimization proxy "
          f"{minimized} ({analytical / max(minimized, 1):.2f}x reduction; "
          "Vivado synthesis lands lower still, Table 5.2)")

    opt = None
    if args.optimize_level:
        from repro import compile as rcompile
        opt = rcompile.optimize(tables, args.optimize_level,
                                in_features=cfg.in_features)
        print(f"truth-table compiler: {rcompile.summarize(opt.stats)}")
        # verify the already-optimized tables directly — one compile,
        # reused for the serving artifact and Verilog emission below
        f_codes, t_codes = LN.verify_tables(cfg, res.model, opt.tables,
                                            xv[:200])
        assert (np.asarray(f_codes) == np.asarray(t_codes)).all(), \
            "optimized-table verification failed"
        print("optimized-table functional verification: EXACT")

    # TPU serving artifact: compile once (reusing the OptimizeResult when
    # the compiler already ran), serve from VMEM-resident slabs forever —
    # the deployment sibling of the Verilog netlist below
    net = engine.compile_network(opt if opt is not None else tables,
                                 in_features=cfg.in_features)
    bd = net.vmem_breakdown()
    print(f"serving artifact: layout={net.layout} "
          f"table slab {bd['table_slab_bytes']} B "
          f"(total {bd['total_bytes']} B VMEM)")
    from repro.core.quantize import codes as quant_codes
    in_codes = quant_codes(cfg.layer_cfgs()[0].in_quant, xv[:200])
    assert (np.asarray(net(in_codes)) == np.asarray(t_codes)).all(), \
        "serving artifact verification failed"

    if args.out:
        from repro.core import verilog as V
        files = (V.generate_verilog(opt.netlist,
                                    pipeline=args.pipeline_registers)
                 if opt is not None else
                 LN.to_verilog(cfg, res.model,
                               pipeline=args.pipeline_registers))
        os.makedirs(args.out, exist_ok=True)
        for name, text in files.items():
            with open(os.path.join(args.out, name), "w") as f:
                f.write(text)
        apath = os.path.join(args.out, f"logicnet_{args.model}.npz")
        net.save(apath)
        print(f"wrote {len(files)} Verilog files + serving artifact "
              f"{os.path.basename(apath)} to {args.out} "
              f"(engine.load(...) serves it without the compiler)")


if __name__ == "__main__":
    main()
