"""End-to-end LM training driver (deliverable b).

Runs the full production stack on any --arch from the registry: config ->
model -> AdamW -> deterministic host-sharded data -> fault-tolerant
TrainLoop (async checkpoints, NaN guard, restart).  On this CPU container
use --size smoke (default) or --size 100m; on a real fleet the same driver
runs the full configs under launch/mesh.py shardings.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-1.7b \
        --size 100m --steps 60 --logicnet-ffn

--logicnet-ffn swaps every FFN for the paper's sparse-quantized
LogicNet-FFN (per-neuron fan-in masks + activation QAT) — the technique
integrated at LM scale.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import TokenStream
from repro.launch.steps import make_train_state, make_train_step
from repro.models.config import LogicNetFFNCfg
from repro.optim.adamw import AdamWCfg, cosine_schedule
from repro.runtime import TrainLoop, TrainLoopCfg


def size_100m(cfg):
    """~100M-param variant of the family (CPU-trainable for a demo run)."""
    return dataclasses.replace(
        cfg, n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=1536, vocab=8192, attn_chunk=256, remat="none")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--size", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--logicnet-ffn", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.size == "100m":
        cfg = size_100m(cfg)
    if args.logicnet_ffn:
        cfg = dataclasses.replace(
            cfg, logicnet_ffn=LogicNetFFNCfg(fan_in=32, bw=4, max_val=4.0))
    n_params = cfg.param_count()
    print(f"arch={cfg.arch_id} params~{n_params / 1e6:.1f}M "
          f"logicnet_ffn={cfg.logicnet_ffn is not None}")

    opt = AdamWCfg(lr=args.lr, weight_decay=0.01,
                   schedule=cosine_schedule(warmup=20, total=args.steps))
    raw_step = jax.jit(make_train_step(cfg, opt))
    state = make_train_state(cfg, jax.random.PRNGKey(0))

    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0,
                         n_hosts=jax.process_count(),
                         host=jax.process_index())

    def batches(step):
        b = stream.batch(step)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "labels": jnp.asarray(b["labels"])}
        if cfg.vision_tokens > 0:
            out["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.enc_dec:
            out["frames"] = jnp.zeros(
                (args.batch, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        return out

    t_hist = []

    def step_fn(state, batch):
        t0 = time.perf_counter()
        new_state, loss = raw_step(state, batch)
        jax.block_until_ready(loss)
        t_hist.append(time.perf_counter() - t0)
        return new_state, loss

    loop = TrainLoop(TrainLoopCfg(ckpt_dir=args.ckpt_dir, ckpt_every=20,
                                  async_save=True), step_fn, state)
    if args.resume:
        loop.try_restore()
    loop.run(batches, args.steps)

    first = loop.metrics[0][1]
    last = sum(l for _, l in loop.metrics[-5:]) / min(5, len(loop.metrics))
    print(f"loss {first:.3f} -> {last:.3f} over {len(loop.metrics)} steps "
          f"({1e3 * sum(t_hist[2:]) / max(len(t_hist) - 2, 1):.0f} "
          f"ms/step after warmup)")
    assert last < first, "training did not reduce loss"


if __name__ == "__main__":
    main()
