"""JAX/Pallas reproduction of 'Exposing Hardware Building Blocks to
Machine Learning Frameworks' — LogicNets as hardware building blocks on
TPU (see ROADMAP.md for the quickstart)."""
