"""Checkpointing: mesh-agnostic save/restore with keep-k and async writes."""

from repro.checkpoint.ckpt import (  # noqa: F401
    save_checkpoint, restore_checkpoint, latest_step, CheckpointManager,
    save_arrays, load_arrays,
)
