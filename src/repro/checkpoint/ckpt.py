"""Checkpoint save/restore.

Design points for fleet-scale runs:

* **Mesh-agnostic**: arrays are saved as host numpy (fully addressable
  values); restore takes an optional ``sharding_fn(path, shape) ->
  Sharding`` so the same checkpoint restores onto a *different* mesh —
  the elastic-scaling path (runtime/).
* **Atomic**: writes go to a ``.tmp`` sibling then rename; a crashed
  writer never corrupts the latest-step pointer.
* **Keep-k** garbage collection.
* **Async**: `CheckpointManager(async_save=True)` snapshots to host then
  writes on a daemon thread, keeping the train loop compute-bound.

Format: one ``.npz`` per step for arrays + a json manifest for the pytree
structure (flattened path -> array key).  No external deps.
"""

from __future__ import annotations

import json
import os
import re
import threading
from typing import Any, Callable

import numpy as np

import jax
from jax.tree_util import tree_flatten_with_path, keystr, tree_unflatten


def _flatten(tree: Any) -> tuple[dict[str, np.ndarray], Any]:
    leaves, treedef = tree_flatten_with_path(tree)
    arrays = {}
    manifest = []
    for i, (path, leaf) in enumerate(leaves):
        key = f"a{i}"
        arrays[key] = np.asarray(leaf)
        manifest.append({"path": keystr(path), "key": key})
    return arrays, (manifest, treedef)


def _atomic_savez(path: str, manifest: list, keyed: dict[str, np.ndarray],
                  extra: dict[str, str] | None = None) -> str:
    """Write one manifest-carrying ``.npz`` atomically (tmp then rename)."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, manifest=json.dumps(manifest), **(extra or {}), **keyed)
    os.replace(tmp, path)
    return path


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    arrays, (manifest, _) = _flatten(tree)
    return _atomic_savez(os.path.join(directory, f"step_{step:08d}.npz"),
                         manifest, arrays)


def save_arrays(path: str, arrays: dict[str, np.ndarray],
                meta: dict | None = None) -> str:
    """Named-array + JSON-metadata ``.npz`` in the manifest format.

    The single-file sibling of ``save_checkpoint`` (same manifest
    machinery, same atomic write): array names live in the manifest, the
    optional ``meta`` dict rides along as a JSON record.  Used by the
    serving engine's ``CompiledLUTNet.save`` artifact.
    """
    keyed = {}
    manifest = []
    for i, (name, arr) in enumerate(arrays.items()):
        key = f"a{i}"
        keyed[key] = np.asarray(arr)
        manifest.append({"path": name, "key": key})
    return _atomic_savez(path, manifest, keyed,
                         extra={"meta": json.dumps(meta or {})})


def load_arrays(path: str) -> tuple[dict[str, np.ndarray], dict]:
    """Inverse of ``save_arrays``: ``(name -> array, meta dict)``."""
    with np.load(path, allow_pickle=False) as z:
        if "manifest" not in z:
            raise ValueError(
                f"{path} is not a manifest-format npz (no 'manifest' "
                "entry; was it written by plain np.savez?)")
        manifest = json.loads(str(z["manifest"]))
        meta = json.loads(str(z["meta"])) if "meta" in z else {}
        arrays = {m["path"]: z[m["key"]] for m in manifest}
    return arrays, meta


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any,
                       sharding_fn: Callable | None = None) -> Any:
    """Restore into the structure of ``like`` (values replaced).

    ``sharding_fn(path_str, array) -> jax.sharding.Sharding | None`` lets
    the caller re-shard onto the current mesh (elastic restore).
    """
    path = os.path.join(directory, f"step_{step:08d}.npz")
    with np.load(path, allow_pickle=False) as z:
        manifest = json.loads(str(z["manifest"]))
        by_path = {m["path"]: z[m["key"]] for m in manifest}
    leaves, treedef = tree_flatten_with_path(like)
    out = []
    for p, leaf in leaves:
        ps = keystr(p)
        if ps not in by_path:
            raise KeyError(f"checkpoint missing leaf {ps}")
        arr = by_path[ps]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {ps}: "
                             f"{arr.shape} vs {leaf.shape}")
        if sharding_fn is not None:
            sh = sharding_fn(ps, arr)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
                continue
        out.append(jax.numpy.asarray(arr, dtype=getattr(leaf, "dtype",
                                                        None)))
    return tree_unflatten(treedef, out)


class CheckpointManager:
    """Keep-k checkpointing with optional async writes."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree: Any) -> None:
        # Snapshot to host memory synchronously (cheap), write async.
        arrays = jax.tree.map(np.asarray, tree)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays), daemon=True)
            self._thread.start()
        else:
            self._write(step, arrays)

    def _write(self, step: int, arrays: Any) -> None:
        save_checkpoint(self.directory, step, arrays)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(m.group(1)) for f in os.listdir(self.directory)
                       if (m := re.match(r"step_(\d+)\.npz$", f)))
        for s in steps[:-self.keep]:
            os.remove(os.path.join(self.directory, f"step_{s:08d}.npz"))

    def restore_latest(self, like: Any,
                       sharding_fn: Callable | None = None
                       ) -> tuple[int, Any] | None:
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None
        return step, restore_checkpoint(self.directory, step, like,
                                        sharding_fn)
