"""Truth-table compiler: netlist optimization passes for LogicNets.

The generated tables are exact but maximally redundant — every neuron
stores all ``2^(fan_in*bw_in)`` entries even for input codes the previous
layer can never emit.  This package is the logic-synthesis step the paper
delegates to Vivado, done at the netlist level so *both* deployment targets
benefit: smaller packed slabs for the fused Pallas kernel (more stacks fit
the VMEM budget) and fewer/narrower case-statement modules in the emitted
Verilog.

    from repro import compile as rcompile
    res = rcompile.optimize(tables, level=2)
    res.tables        # uniform LayerTruthTables (the per-layer path)
    res.mixed_tables  # compact MixedLayerTables (the fused mixed-width
                      # Pallas path: per-(neuron, element) shifts, exact
                      # 2^(sum of input widths)-entry tables — VMEM costs
                      # exactly what the compiler proved)
    res.netlist       # per-neuron Netlist with don't-care masks (Verilog)
    res.stats         # per-pass reduction statistics

Passes: reachable-code analysis + don't-care canonicalization, neuron CSE,
dead-input pruning, cross-layer code re-encoding (level 3: a bus feature
carrying k < 2^bw distinct codes is narrowed to ceil(log2 k) bits with
coordinated producer/consumer rewrites), constant folding / dead-neuron
elimination.  See pipeline.py for the level ladder.

``optimize(..., synth=True)`` (or ``level=4``) appends two-level logic
synthesis: ``repro.synth`` minimizes each surviving neuron into an SOP
cover attached to ``res.netlist`` for assign-network Verilog emission
and measured (rather than worst-case-bounded) LUT costing.
"""

from repro.compile.ir import CLayer, CNet, CNeuron, forward_codes
from repro.compile.pipeline import (CompileStats, OptimizeResult, PassStats,
                                    optimize, optimize_mixed_tables,
                                    optimize_tables, optimize_triples,
                                    raw_stats, summarize,
                                    tables_from_triples)
from repro.compile.reencode import reencode
from repro.core.truth_table import MixedLayerTables

__all__ = [
    "CLayer", "CNet", "CNeuron", "forward_codes",
    "CompileStats", "MixedLayerTables", "OptimizeResult", "PassStats",
    "optimize", "optimize_mixed_tables", "optimize_tables",
    "optimize_triples", "raw_stats", "reencode", "summarize",
    "tables_from_triples",
]
