"""Compiler IR: a netlist with per-neuron variable fan-in.

The generation-side IRs are rigid: ``LayerTruthTable`` forces one uniform
``(out_features, fan_in)`` shape per layer (what the Pallas kernels want) and
``Netlist`` is bus-addressed bits (what the Verilog generator wants).  The
optimization passes need something in between — neurons whose fan-in and
table *shrink independently* as don't-cares are folded, inputs pruned and
duplicates merged.  ``CNet`` is that form: a list of layers, each a list of
``CNeuron``s holding feature-level fan-in indices and a dense truth table of
exactly ``2^(fan_in * bw_in)`` entries.

Lowering goes both ways:

  * ``CNet.to_tables()``  -> uniform ``LayerTruthTable`` list for the
    table-forward / Pallas paths.  Neurons below the layer's max fan-in are
    padded with a duplicate of their first input and the table tiled, so the
    packed-entry convention (element k at bits [bw*k, bw*(k+1))) still
    holds and padded digits are ignored by construction.
  * ``CNet.to_netlist()`` -> exact per-neuron ``Netlist`` for Verilog; no
    padding, each neuron keeps its own (possibly pruned) width, and the
    per-entry reachability masks ride along for don't-care-aware emission.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.netlist import Netlist, NeuronHBB
from repro.core.truth_table import LayerTruthTable


@dataclasses.dataclass
class CNeuron:
    """One LUT neuron: feature indices into the previous layer + dense table.

    ``reachable`` is a per-entry boolean mask filled in by the reachability
    pass (None means "assume every entry reachable").  Entries with
    ``reachable == False`` are don't-cares: their table values are
    canonicalized copies of reachable entries and any rewrite that preserves
    behaviour on reachable entries is legal.
    """

    indices: np.ndarray               # (fan_in,) int32, features of prev bus
    table: np.ndarray                 # (2^(fan_in*bw_in),) int32 out codes
    reachable: np.ndarray | None = None   # (2^(fan_in*bw_in),) bool

    @property
    def fan_in(self) -> int:
        return int(self.indices.shape[0])

    @property
    def n_entries(self) -> int:
        return int(self.table.shape[0])


@dataclasses.dataclass
class CLayer:
    neurons: list[CNeuron]
    bw_in: int
    bw_out: int

    @property
    def out_features(self) -> int:
        return len(self.neurons)

    def max_fan_in(self) -> int:
        return max((n.fan_in for n in self.neurons), default=0)


@dataclasses.dataclass
class CNet:
    in_features: int
    layers: list[CLayer]

    # -- construction -------------------------------------------------------

    @staticmethod
    def from_tables(tables: list[LayerTruthTable],
                    in_features: int | None = None) -> "CNet":
        if not tables:
            raise ValueError("need at least one layer of truth tables")
        if in_features is None:
            in_features = int(np.max(tables[0].indices)) + 1
        layers = []
        width = in_features
        for li, tt in enumerate(tables):
            if int(np.max(tt.indices, initial=0)) >= width:
                raise ValueError(
                    f"layer {li} indexes feature "
                    f"{int(np.max(tt.indices))} of a {width}-wide bus")
            if li > 0 and tt.bw_in != tables[li - 1].bw_out:
                raise ValueError(
                    f"layer {li} bw_in={tt.bw_in} != upstream "
                    f"bw_out={tables[li - 1].bw_out}")
            if tt.n_entries != 1 << (tt.fan_in * tt.bw_in):
                raise ValueError(
                    f"layer {li}: {tt.n_entries} entries for "
                    f"fan_in={tt.fan_in} at bw_in={tt.bw_in}")
            neurons = [CNeuron(np.array(tt.indices[j], dtype=np.int32),
                               np.array(tt.table[j], dtype=np.int32))
                       for j in range(tt.out_features)]
            layers.append(CLayer(neurons, tt.bw_in, tt.bw_out))
            width = tt.out_features
        return CNet(in_features, layers)

    @staticmethod
    def from_netlist(nl: Netlist) -> "CNet":
        """Lift a bus-addressed ``Netlist`` back to feature indices.

        Requires the per-layer ``layer_bw_in`` metadata that
        ``build_netlist`` records; hand-built netlists without it cannot be
        optimized (the bit->feature grouping would be ambiguous).
        """
        if nl.layer_bw_in is None:
            raise ValueError(
                "Netlist lacks layer_bw_in metadata (build it with "
                "netlist.build_netlist, or optimize the LayerTruthTable "
                "list instead)")
        layers = []
        for li, hbbs in enumerate(nl.layers):
            bw = nl.layer_bw_in[li]
            bw_out = hbbs[0].out_bits if hbbs else 0
            neurons = []
            for h in hbbs:
                bits = np.asarray(h.input_bits)
                groups = (bits.reshape(-1, bw)
                          if len(bits) % bw == 0 else None)
                feats = (None if groups is None
                         else (groups[:, 0] // bw).astype(np.int32))
                if groups is None or (
                        groups != bw * (groups[:, :1] // bw)
                        + np.arange(bw)).any():
                    raise ValueError(
                        f"L{li}N{h.neuron}: input bits are not whole "
                        f"{bw}-bit feature groups")
                neurons.append(CNeuron(feats,
                                       np.array(h.table, dtype=np.int32)))
            layers.append(CLayer(neurons, bw, bw_out))
        return CNet(nl.in_bits // nl.layer_bw_in[0], layers)

    # -- lowering -----------------------------------------------------------

    def to_tables(self) -> list[LayerTruthTable]:
        """Uniform per-layer tables (the Pallas / table-forward contract)."""
        tables = []
        for layer in self.layers:
            fi = max(layer.max_fan_in(), 1)
            n_entries = 1 << (fi * layer.bw_in)
            o = layer.out_features
            idx = np.zeros((o, fi), dtype=np.int32)
            tab = np.empty((o, n_entries), dtype=np.int32)
            for j, n in enumerate(layer.neurons):
                pad = n.indices[0] if n.fan_in else np.int32(0)
                idx[j, :n.fan_in] = n.indices
                idx[j, n.fan_in:] = pad
                # trailing padded elements are the high digits of the packed
                # entry, so tiling repeats the true table and the padded
                # digits are ignored — bit-exact by construction
                tab[j] = np.tile(n.table, n_entries // n.n_entries)
            tables.append(LayerTruthTable(tab, idx, layer.bw_in,
                                          layer.bw_out))
        return tables

    def to_netlist(self) -> Netlist:
        """Exact per-neuron netlist (the Verilog contract), masks attached."""
        layers = []
        for li, layer in enumerate(self.layers):
            hbbs = []
            for j, n in enumerate(layer.neurons):
                bits = [layer.bw_in * int(f) + b for f in n.indices
                        for b in range(layer.bw_in)]
                hbbs.append(NeuronHBB(li, j, bits, layer.bw_out,
                                      n.table.copy(),
                                      reachable=(None if n.reachable is None
                                                 else n.reachable.copy())))
            layers.append(hbbs)
        in_bits = self.layers[0].bw_in * self.in_features
        out_bits = self.layers[-1].bw_out * self.layers[-1].out_features
        return Netlist(in_bits, out_bits, layers,
                       layer_bw_in=[lay.bw_in for lay in self.layers])

    # -- accounting ---------------------------------------------------------

    @property
    def n_neurons(self) -> int:
        return sum(lay.out_features for lay in self.layers)

    @property
    def n_table_entries(self) -> int:
        return sum(n.n_entries for lay in self.layers for n in lay.neurons)

    def table_bytes(self) -> int:
        """Per-neuron packed storage (codes at the minimal int width)."""
        from repro.core.lut_cost import code_width

        return sum(code_width(lay.bw_out)
                   * sum(n.n_entries for n in lay.neurons)
                   for lay in self.layers)

    def lut_cost(self) -> int:
        """Analytical 6-LUT cost, identical to
        ``lut_cost.netlist_lut_cost(self.to_netlist())`` but with no
        netlist materialization (no table copies)."""
        from repro.core.lut_cost import lut_cost

        return sum(lut_cost(max(n.fan_in * lay.bw_in, 1), lay.bw_out)
                   for lay in self.layers for n in lay.neurons)

    def validate(self) -> None:
        width = self.in_features
        for li, lay in enumerate(self.layers):
            for n in lay.neurons:
                if n.fan_in and int(n.indices.max()) >= width:
                    raise ValueError(f"layer {li}: index out of range")
                if n.n_entries != 1 << (n.fan_in * lay.bw_in):
                    raise ValueError(f"layer {li}: table size mismatch")
                if n.reachable is not None and (
                        n.reachable.shape != n.table.shape):
                    raise ValueError(f"layer {li}: reachable mask mismatch")
            if li + 1 < len(self.layers) and (
                    lay.bw_out != self.layers[li + 1].bw_in):
                raise ValueError(f"layer {li}: bw_out/bw_in mismatch")
            width = lay.out_features


def forward_codes(net: CNet, in_codes: np.ndarray) -> np.ndarray:
    """Plain-numpy reference forward over the variable-fan-in IR.

    Independent of the lowering paths on purpose: the tests use it to pin
    ``to_tables`` padding and the jnp/Pallas consumers to the same oracle.
    """
    c = np.asarray(in_codes)
    for lay in net.layers:
        out = np.empty((c.shape[0], lay.out_features), dtype=np.int64)
        for j, n in enumerate(lay.neurons):
            entry = np.zeros(c.shape[0], dtype=np.int64)
            for k, f in enumerate(n.indices):
                entry |= c[:, int(f)].astype(np.int64) << (lay.bw_in * k)
            out[:, j] = n.table[entry]
        c = out
    return c
