"""Compiler IR: a netlist with per-neuron variable fan-in.

The generation-side IRs are rigid: ``LayerTruthTable`` forces one uniform
``(out_features, fan_in)`` shape per layer (what the Pallas kernels want) and
``Netlist`` is bus-addressed bits (what the Verilog generator wants).  The
optimization passes need something in between — neurons whose fan-in and
table *shrink independently* as don't-cares are folded, inputs pruned and
duplicates merged.  ``CNet`` is that form: a list of layers, each a list of
``CNeuron``s holding feature-level fan-in indices and a dense truth table
over the bits its inputs actually carry.

Bus widths are **per feature**, not per layer: the cross-layer re-encoding
pass (reencode.py) narrows a feature that only ever carries k < 2^bw
distinct codes down to ``ceil(log2 k)`` bits.  The single source of truth
is the *producing* neuron's ``out_width`` (``None`` = the layer's uniform
``bw_out``); every consumer derives its element widths from the producer
via ``CNet.input_widths``, so index rewires, CSE and DCE never have to
patch width tables.  A neuron's packed entry places element k at bit
offset ``sum(widths of elements 0..k-1)`` (LSB first), and its table holds
exactly ``2^(sum of element widths)`` entries.

Lowering goes both ways:

  * ``CNet.to_tables()``  -> uniform ``LayerTruthTable`` list for the
    table-forward / Pallas paths.  Per layer every feature is padded up to
    the widest input feature (so the kernels' uniform ``bw_in`` shift-pack
    still applies) and neurons below the layer's max fan-in are padded
    with a duplicate of their first input; padded digits and the entries
    of widened elements are unreachable by construction.
  * ``CNet.to_mixed_tables()`` -> compact ``MixedLayerTables`` list for the
    fused mixed-width Pallas path (``kernels.lut_network``).  Nothing is
    padded: each neuron keeps its exact per-element widths as a
    per-(neuron, element) shift/width pair and its table stays the compact
    ``2^(sum of element widths)`` entries the passes produced — the fused
    kernel banks exactly the bytes the compiler proved.
  * ``CNet.to_netlist()`` -> exact per-neuron ``Netlist`` for Verilog; no
    padding, each neuron keeps its own (possibly pruned) fan-in width and
    its own (possibly re-encoded, compact) output width — emitted wires
    shrink with the encoding — and the per-entry reachability masks ride
    along for don't-care-aware emission.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.netlist import Netlist, NeuronHBB
from repro.core.truth_table import LayerTruthTable, MixedLayerTables

# Entry sweeps are chunked so 20+-bit fan-ins never materialize the full
# (entries, fan_in) digit matrices at once — the shared budget for every
# whole-table sweep (to_tables expansion, reachability, re-encoding).
ENTRY_CHUNK = 1 << 16


def entry_widths_offsets(widths: np.ndarray) -> np.ndarray:
    """LSB-first bit offsets of each element of a packed entry."""
    w = np.asarray(widths, dtype=np.int64)
    return np.concatenate([np.zeros(1, np.int64), np.cumsum(w)[:-1]])


def entry_digits(entry_ids: np.ndarray, widths: np.ndarray) -> np.ndarray:
    """(E,) packed entries -> (E, fan_in) per-element codes, LSB-first.

    Element k occupies bits [offset_k, offset_k + widths[k]) of the entry,
    where offset_k is the cumulative width of the preceding elements — the
    mixed-width generalization of the uniform ``bw_in * k`` convention.
    """
    w = np.asarray(widths, dtype=np.int64)
    offs = entry_widths_offsets(w)
    return ((entry_ids[:, None] >> offs[None, :])
            & ((np.int64(1) << w) - 1)[None, :])


@dataclasses.dataclass
class CNeuron:
    """One LUT neuron: feature indices into the previous layer + dense table.

    ``reachable`` is a per-entry boolean mask filled in by the reachability
    pass (None means "assume every entry reachable").  Entries with
    ``reachable == False`` are don't-cares: their table values are
    canonicalized copies of reachable entries and any rewrite that preserves
    behaviour on reachable entries is legal.

    ``out_width`` is the bit-width of the codes this neuron emits — set by
    the re-encoding pass when the neuron's reachable output set fits fewer
    bits than the layer's uniform ``bw_out`` (``None``).  Consumers derive
    their element widths from it (``CNet.input_widths``), so the table of a
    neuron reading re-encoded features is dense over the *compact* widths.
    """

    indices: np.ndarray               # (fan_in,) int32, features of prev bus
    table: np.ndarray                 # (2^(sum elem widths),) int32 codes
    reachable: np.ndarray | None = None   # (n_entries,) bool
    out_width: int | None = None          # None -> layer uniform bw_out

    @property
    def fan_in(self) -> int:
        return int(self.indices.shape[0])

    @property
    def n_entries(self) -> int:
        return int(self.table.shape[0])


@dataclasses.dataclass
class CLayer:
    """One layer; ``bw_in``/``bw_out`` are the *uniform* (container) widths.

    After re-encoding they are upper bounds: the exact per-feature widths
    live on the producing neurons (``CNeuron.out_width``) and are derived
    via ``CNet.input_widths``.
    """

    neurons: list[CNeuron]
    bw_in: int
    bw_out: int

    @property
    def out_features(self) -> int:
        return len(self.neurons)

    def max_fan_in(self) -> int:
        return max((n.fan_in for n in self.neurons), default=0)

    def out_width_of(self, j: int) -> int:
        n = self.neurons[j]
        return self.bw_out if n.out_width is None else n.out_width


@dataclasses.dataclass
class CNet:
    in_features: int
    layers: list[CLayer]

    # -- construction -------------------------------------------------------

    @staticmethod
    def from_tables(tables: list[LayerTruthTable],
                    in_features: int | None = None) -> "CNet":
        if not tables:
            raise ValueError("need at least one layer of truth tables")
        if in_features is None:
            in_features = int(np.max(tables[0].indices)) + 1
        layers = []
        width = in_features
        for li, tt in enumerate(tables):
            if int(np.max(tt.indices, initial=0)) >= width:
                raise ValueError(
                    f"layer {li} indexes feature "
                    f"{int(np.max(tt.indices))} of a {width}-wide bus")
            if li > 0 and tt.bw_in != tables[li - 1].bw_out:
                raise ValueError(
                    f"layer {li} bw_in={tt.bw_in} != upstream "
                    f"bw_out={tables[li - 1].bw_out}")
            if tt.n_entries != 1 << (tt.fan_in * tt.bw_in):
                raise ValueError(
                    f"layer {li}: {tt.n_entries} entries for "
                    f"fan_in={tt.fan_in} at bw_in={tt.bw_in}")
            neurons = [CNeuron(np.array(tt.indices[j], dtype=np.int32),
                               np.array(tt.table[j], dtype=np.int32))
                       for j in range(tt.out_features)]
            layers.append(CLayer(neurons, tt.bw_in, tt.bw_out))
            width = tt.out_features
        return CNet(in_features, layers)

    @staticmethod
    def from_netlist(nl: Netlist) -> "CNet":
        """Lift a bus-addressed ``Netlist`` back to feature indices.

        Requires the per-layer width metadata that ``build_netlist`` and
        ``to_netlist`` record (``layer_in_widths`` for mixed-width buses,
        ``layer_bw_in`` for uniform ones); hand-built netlists without it
        cannot be optimized (the bit->feature grouping would be ambiguous).
        """
        if nl.layer_bw_in is None and nl.layer_in_widths is None:
            raise ValueError(
                "Netlist lacks layer_bw_in metadata (build it with "
                "netlist.build_netlist, or optimize the LayerTruthTable "
                "list instead)")
        layers = []
        in_features = None
        for li, hbbs in enumerate(nl.layers):
            if nl.layer_in_widths is not None:
                widths = np.asarray(nl.layer_in_widths[li], dtype=np.int64)
            else:
                bw = nl.layer_bw_in[li]
                bus_bits = (nl.in_bits if li == 0 else
                            sum(h.out_bits for h in nl.layers[li - 1]))
                widths = np.full(bus_bits // bw, bw, dtype=np.int64)
            if li == 0:
                in_features = len(widths)
            offs = entry_widths_offsets(widths)
            # bit position -> feature whose group starts there
            start2feat = {int(o): f for f, o in enumerate(offs)}
            bw_in = int(widths.max(initial=1))
            bw_out = max((h.out_bits for h in hbbs), default=0)
            neurons = []
            for h in hbbs:
                bits = [int(b) for b in h.input_bits]
                feats = []
                pos = 0
                while pos < len(bits):
                    f = start2feat.get(bits[pos])
                    w = None if f is None else int(widths[f])
                    if (f is None or bits[pos:pos + w]
                            != [int(offs[f]) + b for b in range(w)]):
                        raise ValueError(
                            f"L{li}N{h.neuron}: input bits are not whole "
                            f"feature groups of the {len(widths)}-feature "
                            "bus")
                    feats.append(f)
                    pos += w
                neurons.append(CNeuron(
                    np.array(feats, dtype=np.int32),
                    np.array(h.table, dtype=np.int32),
                    out_width=(None if h.out_bits == bw_out
                               else h.out_bits)))
            layers.append(CLayer(neurons, bw_in, bw_out))
        return CNet(in_features, layers)

    # -- per-feature bus widths ---------------------------------------------

    def input_widths(self, li: int) -> np.ndarray:
        """Per-feature code widths of layer ``li``'s input bus.

        Layer 0 reads the network input (uniform — the input quantizer is
        the caller's contract and is never re-encoded); every other layer
        reads the previous layer's per-neuron output widths.
        """
        if li == 0:
            return np.full(self.in_features, self.layers[0].bw_in,
                           dtype=np.int64)
        prev = self.layers[li - 1]
        return np.array([prev.out_width_of(j)
                         for j in range(prev.out_features)], dtype=np.int64)

    def elem_widths(self, li: int, n: CNeuron) -> np.ndarray:
        """Per-element input code widths of one neuron of layer ``li``."""
        widths = self.input_widths(li)
        return widths[n.indices] if n.fan_in else np.zeros(0, np.int64)

    # -- lowering -----------------------------------------------------------

    def to_tables(self) -> list[LayerTruthTable]:
        """Uniform per-layer tables (the Pallas / table-forward contract).

        Mixed-width layers are padded to a common element width — the bus's
        widest feature — per layer: each neuron's table is re-indexed from
        its compact mixed-width entries to the uniform packing the kernels'
        ``bw_in * k`` shift expects.  Expanded digit values >= 2^w of a
        w-bit feature can never arrive (the lowered producer still emits
        codes < 2^w), so the expansion is bit-exact by construction; when a
        re-encoding pass lowered the *widest* feature of a bus the whole
        layer's uniform tables shrink accordingly.
        """
        tables = []
        n_layers = len(self.layers)
        in_w = [self.input_widths(li) for li in range(n_layers)]
        u_in = [max(int(w.max(initial=1)), 1) for w in in_w]
        for li, layer in enumerate(self.layers):
            u = u_in[li]
            u_out = u_in[li + 1] if li + 1 < n_layers else layer.bw_out
            fi = max(layer.max_fan_in(), 1)
            n_entries = 1 << (fi * u)
            o = layer.out_features
            idx = np.zeros((o, fi), dtype=np.int32)
            tab = np.empty((o, n_entries), dtype=np.int32)
            uniform_w = np.full(fi, u, np.int64)
            for j, n in enumerate(layer.neurons):
                pad = n.indices[0] if n.fan_in else np.int32(0)
                idx[j, :n.fan_in] = n.indices
                idx[j, n.fan_in:] = pad
                ew = in_w[li][n.indices] if n.fan_in else np.zeros(0,
                                                                   np.int64)
                if (ew == u).all():
                    # trailing padded elements are the high digits of the
                    # packed entry, so tiling repeats the true table and the
                    # padded digits are ignored — bit-exact by construction
                    tab[j] = np.tile(n.table, n_entries // n.n_entries)
                    continue
                # mixed widths: map each uniform-width entry back to the
                # neuron's compact entry (digits of widened elements wrap
                # into the compact range; those entries are unreachable)
                for start in range(0, n_entries, ENTRY_CHUNK):
                    ids = np.arange(start, min(start + ENTRY_CHUNK,
                                               n_entries), dtype=np.int64)
                    digits = entry_digits(ids, uniform_w)
                    compact = np.zeros_like(ids)
                    off = 0
                    for k in range(n.fan_in):
                        w = int(ew[k])
                        compact |= (digits[:, k] & ((1 << w) - 1)) << off
                        off += w
                    tab[j, ids] = n.table[compact]
            tables.append(LayerTruthTable(tab, idx, u, u_out))
        return tables

    def to_mixed_tables(self) -> list[MixedLayerTables]:
        """Compact mixed-width tables (the fused mixed-width Pallas path).

        The zero-padding lowering: each neuron's table is handed over
        exactly as the passes left it — ``2^(sum of its element widths)``
        entries, dense over the compact per-element widths — together with
        a per-(neuron, element) shift/width pair that generalizes the
        kernels' uniform ``bw_in * k`` shift-pack.  Neurons below the
        layer's max fan-in repeat their first index with element width 0
        (masked to a zero contribution in the kernel), so the only padded
        storage is the tiny index/shift/width metadata, never table
        entries.  ``build_mixed_network_slabs`` row-stacks the result so
        the fused kernel's VMEM cost equals the netlist's exact
        ``table_bytes()`` accounting.
        """
        out = []
        for li, layer in enumerate(self.layers):
            widths = self.input_widths(li)
            fi = max(layer.max_fan_in(), 1)
            o = layer.out_features
            idx = np.zeros((o, fi), dtype=np.int32)
            shifts = np.zeros((o, fi), dtype=np.int32)
            elem_w = np.zeros((o, fi), dtype=np.int32)
            entry_bits = np.zeros(o, dtype=np.int32)
            tables = []
            for j, n in enumerate(layer.neurons):
                pad = n.indices[0] if n.fan_in else np.int32(0)
                idx[j, :n.fan_in] = n.indices
                idx[j, n.fan_in:] = pad
                ew = (widths[n.indices] if n.fan_in
                      else np.zeros(0, np.int64))
                offs = entry_widths_offsets(ew)
                shifts[j, :n.fan_in] = offs
                shifts[j, n.fan_in:] = int(ew.sum())
                elem_w[j, :n.fan_in] = ew
                entry_bits[j] = int(ew.sum())
                tables.append(n.table.astype(np.int32, copy=True))
            out.append(MixedLayerTables(idx, shifts, elem_w, entry_bits,
                                        tuple(tables)))
        return out

    def to_netlist(self) -> Netlist:
        """Exact per-neuron netlist (the Verilog contract), masks attached.

        Per-feature widths carry through: feature f of layer ``li``'s input
        bus occupies bits [offset_f, offset_f + width_f) where offset_f is
        the cumulative width of features 0..f-1, and each neuron's
        ``out_bits`` is its own (possibly re-encoded) output width — so
        emitted wires shrink to the compact encodings.
        """
        layers = []
        layer_in_widths = []
        for li, layer in enumerate(self.layers):
            widths = self.input_widths(li)
            offs = entry_widths_offsets(widths)
            layer_in_widths.append([int(w) for w in widths])
            hbbs = []
            for j, n in enumerate(layer.neurons):
                bits = [int(offs[f]) + b for f in n.indices
                        for b in range(int(widths[f]))]
                hbbs.append(NeuronHBB(li, j, bits, layer.out_width_of(j),
                                      n.table.copy(),
                                      reachable=(None if n.reachable is None
                                                 else n.reachable.copy())))
            layers.append(hbbs)
        in_bits = int(self.input_widths(0).sum())
        last = self.layers[-1]
        out_bits = sum(last.out_width_of(j)
                       for j in range(last.out_features))
        return Netlist(in_bits, out_bits, layers,
                       layer_bw_in=[lay.bw_in for lay in self.layers],
                       layer_in_widths=layer_in_widths)

    # -- accounting ---------------------------------------------------------

    @property
    def n_neurons(self) -> int:
        return sum(lay.out_features for lay in self.layers)

    @property
    def n_table_entries(self) -> int:
        return sum(n.n_entries for lay in self.layers for n in lay.neurons)

    def table_bytes(self) -> int:
        """Per-neuron packed storage (codes at the minimal int width)."""
        from repro.core.lut_cost import code_width

        return sum(code_width(lay.out_width_of(j)) * n.n_entries
                   for lay in self.layers
                   for j, n in enumerate(lay.neurons))

    def lut_cost(self) -> int:
        """Analytical 6-LUT cost, identical to
        ``lut_cost.netlist_lut_cost(self.to_netlist())`` but with no
        netlist materialization (no table copies)."""
        from repro.core.lut_cost import lut_cost

        total = 0
        for li, lay in enumerate(self.layers):
            widths = self.input_widths(li)
            for j, n in enumerate(lay.neurons):
                in_bits = int(widths[n.indices].sum()) if n.fan_in else 0
                total += lut_cost(max(in_bits, 1), lay.out_width_of(j))
        return total

    def validate(self) -> None:
        width = self.in_features
        for li, lay in enumerate(self.layers):
            widths = self.input_widths(li)
            for n in lay.neurons:
                if n.fan_in and int(n.indices.max()) >= width:
                    raise ValueError(f"layer {li}: index out of range")
                ebits = int(widths[n.indices].sum()) if n.fan_in else 0
                if n.n_entries != 1 << ebits:
                    raise ValueError(f"layer {li}: table size mismatch")
                if n.out_width is not None and not (
                        1 <= n.out_width <= lay.bw_out):
                    raise ValueError(f"layer {li}: out_width out of range")
                if n.reachable is not None and (
                        n.reachable.shape != n.table.shape):
                    raise ValueError(f"layer {li}: reachable mask mismatch")
            if li + 1 < len(self.layers) and (
                    lay.bw_out != self.layers[li + 1].bw_in):
                raise ValueError(f"layer {li}: bw_out/bw_in mismatch")
            width = lay.out_features


def forward_codes(net: CNet, in_codes: np.ndarray) -> np.ndarray:
    """Plain-numpy reference forward over the variable-fan-in IR.

    Independent of the lowering paths on purpose: the tests use it to pin
    ``to_tables`` padding and the jnp/Pallas consumers to the same oracle.
    """
    c = np.asarray(in_codes)
    for li, lay in enumerate(net.layers):
        widths = net.input_widths(li)
        out = np.empty((c.shape[0], lay.out_features), dtype=np.int64)
        for j, n in enumerate(lay.neurons):
            entry = np.zeros(c.shape[0], dtype=np.int64)
            off = 0
            for f in n.indices:
                entry |= c[:, int(f)].astype(np.int64) << off
                off += int(widths[int(f)])
            out[:, j] = n.table[entry]
        c = out
    return c
