"""Passes 2-4: neuron CSE, dead-input pruning, constant-fold / DCE.

All passes mutate the ``CNet`` in place and return a small stats dict; all
are behaviour-preserving on reachable inputs (the contract the pipeline's
property tests enforce end-to-end).  They assume the reachability pass ran
first in the same round — canonicalized tables are what make whole-table
equality checks sound (see reachability.py).
"""

from __future__ import annotations

import numpy as np

from repro.compile.ir import CNet, CNeuron


def _remap_consumers(net: CNet, layer: int, remap: np.ndarray) -> None:
    """Rewrite layer ``layer + 1``'s feature indices through ``remap``."""
    if layer + 1 < len(net.layers):
        for n in net.layers[layer + 1].neurons:
            n.indices = remap[n.indices].astype(np.int32)


# ---------------------------------------------------------------------------
# Pass 2: common-subexpression elimination (neuron dedup)
# ---------------------------------------------------------------------------

def cse(net: CNet) -> dict:
    """Rewire consumers of identical (fan-in signature, table) neurons.

    Duplicates are *not* deleted here — consumers are simply redirected to
    the first representative, which leaves the duplicate unconsumed for the
    DCE pass to collect.  The final layer is the network's output bus, so
    its neurons are never merged (arity and order are the output contract).
    Re-encoded neurons carry their own output width, so the width is part
    of the merge key: redirecting a consumer must not change the encoding
    of the feature it reads.
    """
    merged = 0
    for li in range(len(net.layers) - 1):
        lay = net.layers[li]
        seen: dict[bytes, int] = {}
        remap = np.arange(lay.out_features, dtype=np.int32)
        merged_here = 0
        for j, n in enumerate(lay.neurons):
            key = (n.indices.tobytes() + b"|" + n.table.tobytes()
                   + b"|" + str(lay.out_width_of(j)).encode())
            if key in seen:
                remap[j] = seen[key]
                merged_here += 1
            else:
                seen[key] = j
        if merged_here:
            _remap_consumers(net, li, remap)
        merged += merged_here
    return {"merged": merged}


# ---------------------------------------------------------------------------
# Pass 3: dead-input pruning
# ---------------------------------------------------------------------------

def _reachable_feat_codes(net: CNet) -> list[list[np.ndarray]]:
    """Per layer, the reachable code set of each *input* feature."""
    per_layer = []
    feat_codes = [np.arange(1 << net.layers[0].bw_in, dtype=np.int64)
                  for _ in range(net.in_features)]
    for lay in net.layers:
        per_layer.append(feat_codes)
        feat_codes = [np.unique(n.table if n.reachable is None
                                else n.table[n.reachable])
                      for n in lay.neurons]
    return per_layer


def _try_prune_element(n: CNeuron, k: int, elem_widths: np.ndarray,
                       reach: np.ndarray) -> bool:
    """Remove element k if the table is independent of it across ``reach``.

    The table is viewed as an array over digits (element 0 is the packed
    entry's LSB group, i.e. the *last* reshape axis; axis extents follow
    the per-element widths); independence need only hold across the
    element's reachable codes — canonicalization already made every
    unreachable digit value a copy of a reachable one.
    """
    fan_in = n.fan_in
    shape = tuple(1 << int(w) for w in elem_widths[::-1])
    t = n.table.reshape(shape)
    ax = fan_in - 1 - k
    codes = [int(c) for c in reach]
    ref = np.take(t, codes[0], axis=ax)
    for c in codes[1:]:
        if not np.array_equal(np.take(t, c, axis=ax), ref):
            return False
    n.table = np.ascontiguousarray(ref).reshape(-1)
    n.indices = np.delete(n.indices, k)
    if n.reachable is not None:
        r = n.reachable.reshape(shape)
        n.reachable = np.ascontiguousarray(
            np.take(r, codes[0], axis=ax)).reshape(-1)
    return True


def prune_dead_inputs(net: CNet) -> dict:
    """Drop fan-in elements with no influence on the (reachable) output.

    Each pruned element shrinks the neuron's table by ``2^bw_in`` (2x per
    pruned input bit).  Covers constant-input folding for free: an element
    whose feature carries a single reachable code is always independent.
    Neurons keep at least one element so every lowering target stays
    well-formed (a fully-pruned neuron is just a constant 2^bw-entry table
    that DCE or the consumers' own pruning will handle).
    """
    pruned = 0
    folded = 0
    feat_codes_per_layer = _reachable_feat_codes(net)
    for li, (lay, feat_codes) in enumerate(zip(net.layers,
                                               feat_codes_per_layer)):
        widths = net.input_widths(li)
        for n in lay.neurons:
            changed = True
            while changed and n.fan_in > 1:
                changed = False
                for k in range(n.fan_in):
                    reach = feat_codes[int(n.indices[k])]
                    if n.fan_in > 1 and _try_prune_element(
                            n, k, widths[n.indices], reach):
                        pruned += 1
                        changed = True
                        break
            # a single remaining element whose reachable codes all map to
            # one value means the neuron is a constant: materialize it as
            # a literal table wired to feature 0 (some wire is required by
            # every lowering target), releasing its producer to DCE
            if n.fan_in == 1:
                reach = feat_codes[int(n.indices[0])]
                vals = {int(n.table[int(c)]) for c in reach}
                if len(vals) == 1:
                    v = vals.pop()
                    w0 = int(widths[0])
                    already = (int(n.indices[0]) == 0
                               and n.n_entries == 1 << w0
                               and bool((n.table == v).all()))
                    if not already:
                        folded += 1
                        n.indices = np.zeros(1, dtype=np.int32)
                        n.table = np.full(1 << w0, v, dtype=np.int32)
                        n.reachable = np.ones(1 << w0, dtype=bool)
    return {"pruned_elements": pruned, "folded_constants": folded}


# ---------------------------------------------------------------------------
# Pass 4: constant folding / dead-neuron elimination
# ---------------------------------------------------------------------------

def fold_and_eliminate(net: CNet) -> dict:
    """Count reachable-constant neurons and delete unconsumed ones.

    Constants are *detected* here (their consumers' table entries collapse
    via pass 3, since a constant producer has a singleton reachable set) and
    removal happens once nothing reads them.  Sweeping from the output layer
    backwards cascades a whole chain of dead neurons in one pass.  The final
    layer is the output contract and is never touched.
    """
    constants = 0
    for lay in net.layers:
        for n in lay.neurons:
            vals = n.table if n.reachable is None else n.table[n.reachable]
            constants += int(vals.size > 0 and
                             int(vals.min()) == int(vals.max()))
    removed = 0
    for li in range(len(net.layers) - 2, -1, -1):
        lay = net.layers[li]
        consumed = set()
        for n in net.layers[li + 1].neurons:
            consumed.update(int(f) for f in n.indices)
        keep = [j for j in range(lay.out_features) if j in consumed]
        if len(keep) == lay.out_features:
            continue
        if not keep:
            # pathological (nothing consumed): keep one neuron so layer
            # shapes stay non-degenerate for every lowering target
            keep = [0]
        remap = np.zeros(lay.out_features, dtype=np.int32)
        for new_j, old_j in enumerate(keep):
            remap[old_j] = new_j
        removed += lay.out_features - len(keep)
        lay.neurons = [lay.neurons[j] for j in keep]
        _remap_consumers(net, li, remap)
    return {"constants": constants, "removed_neurons": removed}
