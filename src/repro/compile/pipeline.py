"""The truth-table compiler driver: ``optimize(netlist, level=...)``.

Levels (each includes the previous):

  0 — no rewriting; analysis + lowering only (stats still reported).
  1 — reachable-code analysis / don't-care canonicalization + dead-neuron
      elimination.
  2 — (default) + neuron CSE and dead-input pruning, one round.
  3 — + cross-layer code re-encoding (reencode.py: intermediate bus
      features narrowed to ceil(log2 k) bits with coordinated
      producer/consumer rewrites), and the full round iterated to a
      fixpoint: constants exposed by one round's pruning collapse further
      consumers in the next, and narrowed features hand pruning fresh
      singleton elements, until nothing changes.
  4 — + two-level logic synthesis (alias for ``level=3, synth=True``):
      each surviving neuron's table is minimized into an SOP cover
      (repro.synth) over its reachable on-set, attached to the netlist
      for the assign-network Verilog backend and measured LUT costing.

The input is either a ``list[LayerTruthTable]`` (straight from
``logicnet.generate_tables``) or a ``Netlist`` built by
``netlist.build_netlist``.  The result carries all three views of the
optimized network — uniform tables for the jnp/Pallas paths, an exact
per-neuron netlist for Verilog, and the raw IR — plus per-pass statistics
and before/after storage + LUT-cost accounting.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import obs
from repro.compile import passes, reachability, reencode
from repro.compile.ir import CNet
from repro.core.netlist import Netlist
from repro.core.truth_table import LayerTruthTable, MixedLayerTables

MAX_ROUNDS = 16  # fixpoint guard; each round strictly shrinks the net

# PassStats mirrored into the process registry so one snapshot answers
# "which compile pass got slower?" next to the serving-tier histograms
_M_OPT_RUNS = obs.registry().counter(
    "compile_optimize_runs_total",
    "optimize() invocations by pipeline level", labels=("level",))
_M_OPT_SECONDS = obs.registry().histogram(
    "compile_optimize_seconds", "end-to-end optimize() wall time")
_M_PASS_RUNS = obs.registry().counter(
    "compile_pass_runs_total",
    "pass executions across all optimize() rounds", labels=("pass",))
_M_PASS_SECONDS = obs.registry().counter(
    "compile_pass_seconds_total",
    "cumulative wall time per pass name", labels=("pass",))


@dataclasses.dataclass(frozen=True)
class PassStats:
    """One pass execution: what it removed and what it cost."""

    name: str
    round: int
    seconds: float
    detail: dict

    def as_dict(self) -> dict:
        return {"name": self.name, "round": self.round,
                "seconds": self.seconds, **self.detail}

    @classmethod
    def from_dict(cls, d: dict) -> "PassStats":
        """Inverse of ``as_dict`` (detail is the non-header remainder)."""
        d = dict(d)
        return cls(d.pop("name"), d.pop("round"), d.pop("seconds"), d)


@dataclasses.dataclass
class CompileStats:
    level: int
    rounds: int
    passes: list[PassStats]
    neurons_before: int
    neurons_after: int
    table_entries_before: int
    table_entries_after: int
    table_bytes_before: int
    table_bytes_after: int
    lut_cost_before: int
    lut_cost_after: int
    # synthesize_netlist() stats dict when optimize(..., synth=True) ran
    # (covered/fallback neuron counts, literal/term totals, seconds);
    # None when synthesis was not requested.
    synth: dict | None = None

    @property
    def dont_care_entries(self) -> int:
        return sum(p.detail.get("dont_care_entries", 0)
                   for p in self.passes if p.round == 0)

    @property
    def features_recoded(self) -> int:
        """Re-encoding *events* over all rounds: a feature narrowed again
        in a later round (its reachable set shrank further) counts once per
        round.  For a round-count-independent magnitude use ``bits_saved``,
        which telescopes (3->2 then 2->1 bits sums to the same 2 bits as a
        single 3->1 narrowing)."""
        return sum(p.detail.get("features_recoded", 0) for p in self.passes)

    @property
    def bits_saved(self) -> int:
        """Bus bits dropped by re-encoding (sum of old-new widths; exactly
        the original-to-final width delta regardless of round count)."""
        return sum(p.detail.get("bits_saved", 0) for p in self.passes)

    def as_dict(self) -> dict:
        return {
            "level": self.level,
            "rounds": self.rounds,
            "neurons_before": self.neurons_before,
            "neurons_after": self.neurons_after,
            "table_entries_before": self.table_entries_before,
            "table_entries_after": self.table_entries_after,
            "table_bytes_before": self.table_bytes_before,
            "table_bytes_after": self.table_bytes_after,
            "lut_cost_before": self.lut_cost_before,
            "lut_cost_after": self.lut_cost_after,
            "dont_care_entries": self.dont_care_entries,
            "features_recoded": self.features_recoded,
            "bits_saved": self.bits_saved,
            "synth": self.synth,
            "passes": [p.as_dict() for p in self.passes],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CompileStats":
        """Inverse of ``as_dict``: rebuild from a JSON record (derived
        properties — ``dont_care_entries`` etc. — are recomputed, not
        read).  The serving engine stores compile stats in its artifact
        metadata this way, so a loaded ``CompiledLUTNet`` reports the
        stats of the build that produced its slabs."""
        return cls(
            level=d["level"], rounds=d["rounds"],
            passes=[PassStats.from_dict(p) for p in d["passes"]],
            neurons_before=d["neurons_before"],
            neurons_after=d["neurons_after"],
            table_entries_before=d["table_entries_before"],
            table_entries_after=d["table_entries_after"],
            table_bytes_before=d["table_bytes_before"],
            table_bytes_after=d["table_bytes_after"],
            lut_cost_before=d["lut_cost_before"],
            lut_cost_after=d["lut_cost_after"],
            synth=d.get("synth"),
        )


@dataclasses.dataclass
class OptimizeResult:
    """Optimized network in every consumer's native representation."""

    cnet: CNet
    stats: CompileStats

    @property
    def tables(self) -> list[LayerTruthTable]:
        """Uniform per-layer tables for table_infer / the Pallas kernels."""
        if self._tables is None:
            self._tables = self.cnet.to_tables()
        return self._tables

    @property
    def mixed_tables(self) -> list[MixedLayerTables]:
        """Compact per-neuron tables for the fused mixed-width Pallas path.

        Unlike ``tables`` nothing is padded back to a uniform element
        width: the fused kernel's slabs built from this lowering cost
        exactly the bytes ``cnet.table_bytes()`` accounts for.
        """
        if self._mixed is None:
            self._mixed = self.cnet.to_mixed_tables()
        return self._mixed

    @property
    def netlist(self) -> Netlist:
        """Exact per-neuron netlist (with don't-care masks) for Verilog."""
        if self._netlist is None:
            self._netlist = self.cnet.to_netlist()
        return self._netlist

    def __post_init__(self) -> None:
        self._tables: list[LayerTruthTable] | None = None
        self._mixed: list[MixedLayerTables] | None = None
        self._netlist: Netlist | None = None


def _as_cnet(netlist, in_features: int | None) -> CNet:
    if isinstance(netlist, CNet):
        return netlist
    if isinstance(netlist, Netlist):
        return CNet.from_netlist(netlist)
    return CNet.from_tables(list(netlist), in_features)


def _shape_signature(net: CNet) -> tuple:
    return tuple((lay.out_features,
                  tuple(n.fan_in for n in lay.neurons),
                  tuple(-1 if n.out_width is None else n.out_width
                        for n in lay.neurons),
                  sum(int(n.table.sum()) for n in lay.neurons))
                 for lay in net.layers)


def optimize(netlist, level: int = 2, *,
             synth: bool = False,
             in_features: int | None = None) -> OptimizeResult:
    """Run the pass pipeline; see module docstring for the level ladder.

    ``netlist`` is a ``list[LayerTruthTable]``, a ``Netlist`` (from
    ``build_netlist``), or a ``CNet``.  The optimized network computes the
    same function as the input on every reachable input, bit-exactly —
    per-layer, fused-kernel and Verilog lowerings included.

    ``synth=True`` (or ``level=4``, an alias for ``level=3, synth=True``)
    appends the two-level synthesis stage: ``repro.synth`` minimizes each
    neuron's table into an SOP cover attached to ``result.netlist``, with
    the stats recorded in ``result.stats.synth``.
    """
    if level == 4:
        level, synth = 3, True
    if not 0 <= level <= 3:
        raise ValueError(f"optimize level must be in [0, 4], got {level}")
    net = _as_cnet(netlist, in_features)
    net.validate()

    before_neurons = net.n_neurons
    before_entries = net.n_table_entries
    before_bytes = net.table_bytes()
    before_lut = net.lut_cost()

    pass_stats: list[PassStats] = []

    t_opt = time.perf_counter()

    def run(name: str, fn, rnd: int) -> dict:
        t0 = time.perf_counter()
        detail = fn(net)
        seconds = time.perf_counter() - t0
        pass_stats.append(PassStats(name, rnd, seconds, detail))
        _M_PASS_RUNS.labels(**{"pass": name}).inc()
        _M_PASS_SECONDS.labels(**{"pass": name}).inc(seconds)
        return detail

    rounds = 0
    if level == 0:
        # analysis-only: reachability stats with no rewriting at all
        run("reachability",
            lambda n: reachability.analyze_and_canonicalize(
                n, rewrite=False), 0)
    else:
        max_rounds = MAX_ROUNDS if level >= 3 else 1
        for rnd in range(max_rounds):
            sig = _shape_signature(net)
            run("reachability", reachability.analyze_and_canonicalize, rnd)
            if level >= 2:
                run("prune_dead_inputs", passes.prune_dead_inputs, rnd)
                run("cse", passes.cse, rnd)
            if level >= 3:
                # after pruning/CSE so reachable sets are final for the
                # round; narrowed features then unlock further pruning in
                # the next round (singleton -> element removed), which is
                # why the round iterates to a fixpoint
                run("reencode", reencode.reencode, rnd)
            run("fold_and_eliminate", passes.fold_and_eliminate, rnd)
            rounds = rnd + 1
            if _shape_signature(net) == sig:
                break
    net.validate()
    _M_OPT_RUNS.labels(level=str(level)).inc()
    _M_OPT_SECONDS.observe(time.perf_counter() - t_opt)

    stats = CompileStats(
        level=level, rounds=rounds, passes=pass_stats,
        neurons_before=before_neurons, neurons_after=net.n_neurons,
        table_entries_before=before_entries,
        table_entries_after=net.n_table_entries,
        table_bytes_before=before_bytes, table_bytes_after=net.table_bytes(),
        lut_cost_before=before_lut,
        lut_cost_after=net.lut_cost(),
    )
    result = OptimizeResult(net, stats)
    if synth:
        # the synthesis stage runs on the lowered netlist (the exact
        # per-neuron view the Verilog backend consumes) so covers line
        # up with the emitted modules bit-for-bit
        from repro.synth import synthesize_netlist

        t0 = time.perf_counter()
        detail = synthesize_netlist(result.netlist)
        seconds = time.perf_counter() - t0
        pass_stats.append(PassStats("synth", rounds, seconds, dict(detail)))
        _M_PASS_RUNS.labels(**{"pass": "synth"}).inc()
        _M_PASS_SECONDS.labels(**{"pass": "synth"}).inc(seconds)
        stats.synth = {**detail, "seconds": seconds}
    return result


def optimize_tables(tables: list[LayerTruthTable], level: int = 2, *,
                    in_features: int | None = None
                    ) -> list[LayerTruthTable]:
    """Convenience: tables in, optimized uniform tables out."""
    return optimize(tables, level, in_features=in_features).tables


def tables_from_triples(layers) -> list[LayerTruthTable]:
    """``(indices, table, bw_in)`` triples -> ``LayerTruthTable`` list.

    Output bit-widths are inferred (the next layer's ``bw_in``; widest
    code for the last layer) since triples don't carry them; they only
    affect storage accounting, not the computed function.  Shared by
    ``optimize_triples`` and ``ops.lut_network``'s in-line compile step.
    """
    triples = [(np.asarray(i), np.asarray(t), int(b)) for i, t, b in layers]
    tables = []
    for li, (idx, tab, bw) in enumerate(triples):
        if li + 1 < len(triples):
            bw_out = triples[li + 1][2]
        else:
            bw_out = max(1, int(tab.max(initial=0)).bit_length())
        tables.append(LayerTruthTable(tab.astype(np.int32),
                                      idx.astype(np.int32), bw, bw_out))
    return tables


def optimize_triples(layers, level: int = 2, *,
                     in_features: int | None = None) -> list[tuple]:
    """``(indices, table, bw_in)`` triples in/out — ``ops.lut_network``'s
    wire format (uniform lowering; see ``OptimizeResult.mixed_tables`` /
    ``optimize_mixed_tables`` for the compact mixed-width lowering the
    fused kernel consumes directly)."""
    opt = optimize(tables_from_triples(layers), level,
                   in_features=in_features).tables
    return [(tt.indices, tt.table, tt.bw_in) for tt in opt]


def optimize_mixed_tables(tables, level: int = 2, *,
                          in_features: int | None = None
                          ) -> list[MixedLayerTables]:
    """Convenience: tables in, compact mixed-width tables out.

    The lowering ``kernels.lut_network.build_mixed_network_slabs`` packs
    into the fused kernel's exact-footprint slabs."""
    return optimize(tables, level, in_features=in_features).mixed_tables


def raw_stats(tables: list[LayerTruthTable],
              in_features: int | None = None) -> dict:
    """Storage/cost accounting of an *unoptimized* table stack (for the
    bench JSON's raw-vs-optimized comparison)."""
    net = CNet.from_tables(tables, in_features)
    return {"neurons": net.n_neurons,
            "table_entries": net.n_table_entries,
            "table_bytes": net.table_bytes(),
            "lut_cost": net.lut_cost()}


def summarize(stats: CompileStats) -> str:
    """One-line human summary (the bench prints it next to timings)."""
    s = stats

    def pct(a, b):
        return 100.0 * (1.0 - a / b) if b else 0.0
    recoded = (f" recoded={s.features_recoded}feat/-{s.bits_saved}bits"
               if s.features_recoded else "")
    return (f"level={s.level} rounds={s.rounds} "
            f"neurons {s.neurons_before}->{s.neurons_after} "
            f"entries {s.table_entries_before}->{s.table_entries_after} "
            f"bytes {s.table_bytes_before}->{s.table_bytes_after} "
            f"(-{pct(s.table_bytes_after, s.table_bytes_before):.1f}%) "
            f"LUTs {s.lut_cost_before}->{s.lut_cost_after}{recoded}")


__all__ = ["optimize", "optimize_tables", "optimize_triples",
           "optimize_mixed_tables", "tables_from_triples",
           "raw_stats", "summarize",
           "OptimizeResult", "CompileStats", "PassStats", "MAX_ROUNDS"]
