"""Pass 1: reachable-code analysis + don't-care canonicalization.

A generated truth table enumerates all ``2^(fan_in*bw_in)`` input codes,
but the previous layer can only *emit* the codes that actually appear in
its own tables — every other entry of a downstream table is a don't-care
the paper's FPGA flow leaves to the logic synthesizer.  This pass computes,
layer by layer, the set of codes each bus feature can carry, derives a
per-entry reachability mask for every neuron, and **canonicalizes** the
don't-care entries: each unreachable code of an input element is remapped
to that element's smallest reachable code, and the table value copied from
the resulting reachable entry.

After canonicalization the table is constant across every unreachable
digit value (by construction), which is what lets the later passes operate
on whole tables with plain equality:

  * dead-input pruning only has to test independence across *reachable*
    codes of an element;
  * CSE compares canonical tables byte-for-byte, so two neurons that agree
    on reachable inputs but differed on don't-cares now merge;
  * a neuron constant on reachable entries becomes a globally constant
    table.

Behaviour on reachable inputs is untouched — the whole-network function is
bit-identical for any input the network can actually see.  With
``rewrite=False`` the dataflow runs analysis-only (level 0): statistics
are computed but no neuron is mutated.
"""

from __future__ import annotations

import numpy as np

from repro.compile.ir import (ENTRY_CHUNK, CNet, CNeuron, entry_digits,
                              entry_widths_offsets)


def scan_neuron(n: CNeuron, elem_widths: np.ndarray,
                feat_codes: list[np.ndarray],
                rewrite: bool) -> tuple[np.ndarray, int]:
    """One chunked sweep over the neuron's entries.

    ``elem_widths`` is the per-element input code width (mixed once the
    re-encoding pass has narrowed upstream features).  Computes the
    per-entry reachability mask and — when ``rewrite`` — canonicalizes
    don't-cares in the same pass (the digit decomposition is the dominant
    cost for wide fan-ins, so it is done exactly once).  Canonical map, per
    element k reading feature f: a reachable code maps to itself, an
    unreachable one to ``min(reachable codes of f)``; the new table value
    at entry e is the old value at the element-wise mapped entry, so
    unreachable entries become exact copies of reachable ones.  Returns
    ``(mask, n_dont_care)``.
    """
    offs = entry_widths_offsets(elem_widths)
    elem_ok, code_maps = [], []
    for k, f in enumerate(n.indices):
        n_codes = 1 << int(elem_widths[k])
        reach = feat_codes[int(f)]
        ok = np.isin(np.arange(n_codes), reach)
        elem_ok.append(ok)
        cmap = np.arange(n_codes, dtype=np.int64)
        cmap[~ok] = int(reach.min())
        code_maps.append(cmap)

    mask = np.ones(n.n_entries, dtype=bool)
    old = n.table.copy() if rewrite else n.table
    for start in range(0, n.n_entries, ENTRY_CHUNK):
        ids = np.arange(start, min(start + ENTRY_CHUNK, n.n_entries),
                        dtype=np.int64)
        digits = entry_digits(ids, elem_widths)
        canon = np.zeros_like(ids)
        for k in range(n.fan_in):
            mask[ids] &= elem_ok[k][digits[:, k]]
            if rewrite:
                canon |= code_maps[k][digits[:, k]] << int(offs[k])
        if rewrite:
            n.table[ids] = old[canon]
    if rewrite:
        n.reachable = mask
    return mask, int(n.n_entries - mask.sum())


def analyze_and_canonicalize(net: CNet, rewrite: bool = True) -> dict:
    """Run the forward dataflow over the whole net.

    With ``rewrite`` (the default) don't-cares are canonicalized in place
    and reachability masks attached; without it the net is left untouched
    (analysis-only, the level-0 mode).  Returns stats: total/unreachable
    entry counts and the per-layer list of per-feature reachable-code
    counts (the quantity the ROADMAP's reachable-set-aware-training
    follow-on would regularize).
    """
    # network inputs: every code of the input quantizer can occur
    feat_codes: list[np.ndarray] = [
        np.arange(1 << net.layers[0].bw_in, dtype=np.int64)
        for _ in range(net.in_features)]
    dont_care = 0
    reach_counts: list[list[int]] = []
    for li, lay in enumerate(net.layers):
        widths = net.input_widths(li)
        next_codes = []
        for n in lay.neurons:
            mask, n_dc = scan_neuron(n, widths[n.indices], feat_codes,
                                     rewrite)
            dont_care += n_dc
            next_codes.append(np.unique(n.table[mask]))
        reach_counts.append([len(c) for c in next_codes])
        feat_codes = next_codes
    return {
        "total_entries": net.n_table_entries,
        "dont_care_entries": dont_care,
        "reachable_code_counts": reach_counts,
    }
