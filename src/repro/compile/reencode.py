"""Pass 5: cross-layer code re-encoding (level 3).

The table size of a neuron doubles with every input *bit*, so the bits a
feature actually needs is the strongest compression lever the netlist has:
a bus feature whose reachable code set holds k < 2^bw distinct values only
carries ``ceil(log2 k)`` bits of information, yet every consumer still
indexes its table with the full bw-bit container.  This pass re-codes such
features into the compact width with coordinated producer/consumer
rewrites:

  * the **producer**'s table values are replaced by the rank of each code
    in its sorted reachable set — the neuron now emits the compact code —
    and its ``out_width`` is set to the new width (``CNet.input_widths``
    derives every consumer's element widths from it);
  * every **consumer**'s table is re-indexed under the new encoding: the
    rebuilt table is dense over the compact element widths, entry values
    gathered from the old table at the decoded (old-code) entry.  Compact
    digit values >= k (present when k is not a power of two) can never
    arrive; they decode to compact code 0's old code, so the rebuilt table
    stays canonical (unreachable digits copy reachable columns) and the
    per-entry reachability masks are rebuilt alongside.

The final layer's *output* bus is the network's output contract and is
never re-encoded (the identity-preserving exception); its inputs — like
any layer's — may be.  The network input bus is the input quantizer's
contract and is likewise untouched (``CNet.input_widths`` pins layer 0 to
the uniform ``bw_in``).

A single-code feature (k == 1) clamps to the 1-bit minimum width — the
"width 0" case — emitting constant code 0; the dead-input pruning pass in
the same fixpoint round then removes the element from every consumer (a
singleton reachable set is always independent), which is exactly the
zero-bit outcome.  Re-encoding is idempotent: a compact feature carries
the dense set {0..k-1}, so it is only re-coded again if a later round's
pruning shrinks k itself — which is why the pipeline iterates the round to
a fixpoint at level 3.

Requires the reachability pass to have run in the same round (tables
canonicalized, masks attached): canonicalization guarantees every table
value appears in the reachable value set, so the producer rank-map covers
don't-care entries too.
"""

from __future__ import annotations

import numpy as np

from repro.compile.ir import ENTRY_CHUNK, CNet, entry_digits


def reencode(net: CNet) -> dict:
    """Narrow every intermediate bus feature to its information content.

    Mutates the net in place; behaviour on reachable inputs is preserved
    bit-exactly (the whole-network function is unchanged — consumers are
    re-indexed in lockstep with their producers).  Returns stats:
    ``features_recoded``, ``bits_saved`` (bus bits dropped across all
    recoded features) and before/after packed table bytes.
    """
    features_recoded = 0
    bits_saved = 0
    bytes_before = net.table_bytes()
    for li in range(len(net.layers) - 1):
        lay = net.layers[li]
        nxt = net.layers[li + 1]
        old_w = net.input_widths(li + 1)        # current widths of lay's bus
        new_w = old_w.copy()
        decode: list[np.ndarray | None] = [None] * lay.out_features
        for j, n in enumerate(lay.neurons):
            vals = np.unique(n.table if n.reachable is None
                             else n.table[n.reachable])
            # ceil(log2 k) bits hold k codes; clamp at the 1-bit minimum so
            # every lowering target keeps a well-formed wire (k == 1 is
            # finished off by dead-input pruning, see module docstring)
            w_new = max(1, int(len(vals) - 1).bit_length())
            if w_new >= int(old_w[j]):
                continue
            new_w[j] = w_new
            decode[j] = vals.astype(np.int64)
            n.table = np.searchsorted(vals, n.table).astype(np.int32)
            features_recoded += 1
            bits_saved += int(old_w[j]) - w_new
        if all(d is None for d in decode):
            continue
        for m in nxt.neurons:
            if all(decode[int(f)] is None for f in m.indices):
                continue
            ew_old = old_w[m.indices]
            ew_new = new_w[m.indices]
            n_new = 1 << int(ew_new.sum())
            new_table = np.empty(n_new, dtype=m.table.dtype)
            new_mask = np.empty(n_new, dtype=bool)
            old_mask = m.reachable
            # chunked like reachability's sweep: wide fan-ins never
            # materialize the full (entries, fan_in) digit matrix at once
            for start in range(0, n_new, ENTRY_CHUNK):
                ids = np.arange(start, min(start + ENTRY_CHUNK, n_new),
                                dtype=np.int64)
                dig = entry_digits(ids, ew_new)
                old_entry = np.zeros_like(ids)
                valid = np.ones(ids.shape, dtype=bool)
                off = 0
                for k, f in enumerate(m.indices):
                    d = dig[:, k]
                    dec = decode[int(f)]
                    if dec is not None:
                        ok = d < len(dec)
                        valid &= ok
                        d = dec[np.where(ok, d, 0)]
                    old_entry |= d.astype(np.int64) << off
                    off += int(ew_old[k])
                new_table[ids] = m.table[old_entry]
                new_mask[ids] = (valid if old_mask is None
                                 else old_mask[old_entry] & valid)
            m.table = new_table
            m.reachable = new_mask
        # materialize every width so tightening the layer's uniform
        # container below cannot silently re-widen untouched neurons
        for j in range(lay.out_features):
            lay.neurons[j].out_width = int(new_w[j])
        lay.bw_out = nxt.bw_in = int(new_w.max(initial=1))
    return {"features_recoded": features_recoded,
            "bits_saved": bits_saved,
            "table_bytes_before": bytes_before,
            "table_bytes_after": net.table_bytes()}
