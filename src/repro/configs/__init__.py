"""Architecture registry: exact assigned configs + reduced smoke variants.

``get_config(arch_id)`` returns the full (dry-run-only) config;
``get_smoke_config(arch_id)`` a reduced same-family config that runs a
real step on CPU.  ``SHAPES`` are the four assigned input-shape cells;
``cell_kind``/``cell_skip`` encode the per-family applicability rules
(DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelCfg

ARCH_IDS = [
    "qwen3-moe-235b-a22b",
    "olmoe-1b-7b",
    "gemma3-27b",
    "qwen3-1.7b",
    "starcoder2-15b",
    "phi3-mini-3.8b",
    "zamba2-2.7b",
    "mamba2-370m",
    "whisper-medium",
    "qwen2-vl-2b",
]


def _module(arch_id: str):
    return importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))


def get_config(arch_id: str) -> ModelCfg:
    return _module(arch_id).config()


def get_smoke_config(arch_id: str) -> ModelCfg:
    return _module(arch_id).smoke_config()


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_skip(cfg: ModelCfg, shape: str) -> str | None:
    """Reason the (arch, shape) cell is skipped, or None if it runs."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        if cfg.local_global_ratio > 0:
            return ("full-attention global layers every "
                    f"{cfg.local_global_ratio + 1} layers keep 512k "
                    "quadratic (see DESIGN.md)")
        return "pure full-attention arch: 512k decode is quadratic-cost"
    return None
