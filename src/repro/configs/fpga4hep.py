"""The paper's own jet-substructure models (Table 6.1, models A-E):
16 expert features -> 5 jet classes (q, g, W, Z, t)."""

from repro.core.logicnet import LogicNetCfg

IN_FEATURES = 16
N_CLASSES = 5


def model_a() -> LogicNetCfg:
    return LogicNetCfg(IN_FEATURES, N_CLASSES, hidden=(64, 64, 64),
                       fan_in=3, bw=3, final_dense=True, bw_fc=3)


def model_b() -> LogicNetCfg:
    return LogicNetCfg(IN_FEATURES, N_CLASSES, hidden=(128, 64, 32),
                       fan_in=3, bw=3, final_dense=True, bw_fc=3)


def model_c() -> LogicNetCfg:
    return LogicNetCfg(IN_FEATURES, N_CLASSES, hidden=(64, 32, 32),
                       fan_in=3, bw=2, final_dense=True, bw_fc=2)


def model_d() -> LogicNetCfg:
    return LogicNetCfg(IN_FEATURES, N_CLASSES, hidden=(64, 32, 32),
                       fan_in=5, bw=2, final_dense=False, fan_in_fc=6,
                       bw_fc=4)


def model_e() -> LogicNetCfg:
    return LogicNetCfg(IN_FEATURES, N_CLASSES, hidden=(64, 64, 64),
                       fan_in=4, bw=2, final_dense=False, fan_in_fc=4,
                       bw_fc=4)


MODELS = {"A": model_a, "B": model_b, "C": model_c, "D": model_d,
          "E": model_e}
