"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5:1 local:global attention, 1024-token sliding window,
128k context.  [hf:google/gemma-3-27b family]"""

from repro.models.config import ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        arch_id="gemma3-27b",
        n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=21504, vocab=262144,
        sliding_window=1024, local_global_ratio=5,
        rope_theta=1_000_000.0, act_fn="gelu",
        tie_embeddings=True,
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        arch_id="gemma3-27b-smoke",
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        sliding_window=32, local_global_ratio=5, act_fn="gelu",
        tie_embeddings=True, attn_chunk=32, remat="none",
    )
