"""mamba2-370m [ssm]: 48L d_model=1024, attn-free, vocab=50280,
ssm_state=128; SSD (state-space duality).  [arXiv:2405.21060]"""

from repro.models.config import ModelCfg, SSMCfg


def config() -> ModelCfg:
    return ModelCfg(
        arch_id="mamba2-370m",
        n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16,  # unused
        d_ff=0, vocab=50280,
        block_kind="ssm",
        ssm=SSMCfg(d_state=128, head_dim=64, expand=2),
        tie_embeddings=True,
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        arch_id="mamba2-370m-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=256,
        block_kind="ssm",
        ssm=SSMCfg(d_state=16, head_dim=16, expand=2, chunk=16),
        tie_embeddings=True, remat="none",
    )
