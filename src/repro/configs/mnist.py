"""The paper's MNIST topologies (Table 7.1 MLPs, §7 skip variants).

Inputs are flattened 28x28 images (784 features), 10 classes; the final
layer is dense ("the last layer cannot have low per-neuron fan-in", §7).
"""

from repro.core.logicnet import LogicNetCfg

IN_FEATURES = 28 * 28
N_CLASSES = 10


def mlp(hidden: tuple[int, ...], bw: int, fan_in: int,
        skips: tuple = ()) -> LogicNetCfg:
    return LogicNetCfg(IN_FEATURES, N_CLASSES, hidden=hidden, fan_in=fan_in,
                       bw=bw, final_dense=True, bw_fc=bw, skips=skips)


# Table 7.1 rows: (hidden, bw, fan_in)
TABLE_7_1 = [
    ((512,), 2, 6),
    ((1024,), 2, 5),
    ((2048, 2048), 2, 5),
    ((512, 512), 2, 6),
    ((1024, 1024), 2, 5),
    ((2048, 2048), 2, 5),
    ((512, 512, 512), 2, 6),
    ((1024, 1024, 1024), 2, 5),
    ((2048, 2048, 2048), 2, 5),
]
