"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8.  [arXiv:2409.02060]"""

from repro.models.config import ModelCfg, MoECfg


def config() -> ModelCfg:
    return ModelCfg(
        arch_id="olmoe-1b-7b",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304,
        moe=MoECfg(n_experts=64, top_k=8),
        rope_theta=10_000.0, tie_embeddings=False,
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        arch_id="olmoe-1b-7b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=256,
        moe=MoECfg(n_experts=4, top_k=2),
        tie_embeddings=False, attn_chunk=64, remat="none",
    )
