"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064; RoPE + SwiGLU.  [arXiv:2404.14219]"""

from repro.models.config import ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        arch_id="phi3-mini-3.8b",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32064,
        rope_theta=10_000.0, tie_embeddings=False,
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        arch_id="phi3-mini-3.8b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        tie_embeddings=False, attn_chunk=64, remat="none",
    )
