"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936; M-RoPE, dynamic resolution.  Vision frontend is a STUB:
input_specs feeds precomputed patch embeddings for the first
``vision_tokens`` positions.  [arXiv:2409.12191]"""

from repro.models.config import ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        arch_id="qwen2-vl-2b",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
        d_ff=8960, vocab=151936,
        mrope=True, vision_tokens=256,
        rope_theta=1_000_000.0, tie_embeddings=True,
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        arch_id="qwen2-vl-2b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256,
        mrope=True, vision_tokens=16,
        tie_embeddings=True, attn_chunk=32, remat="none",
    )
