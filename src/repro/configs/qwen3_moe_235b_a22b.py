"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-235B-A22B family]"""

from repro.models.config import ModelCfg, MoECfg


def config() -> ModelCfg:
    return ModelCfg(
        arch_id="qwen3-moe-235b-a22b",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
        d_ff=1536, vocab=151936,
        moe=MoECfg(n_experts=128, top_k=8),
        qk_norm=True, rope_theta=1_000_000.0,
        tie_embeddings=False,
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        arch_id="qwen3-moe-235b-a22b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=256,
        moe=MoECfg(n_experts=8, top_k=2),
        qk_norm=True, rope_theta=1_000_000.0,
        tie_embeddings=False, attn_chunk=64, remat="none",
    )
