"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152; GQA + RoPE.  [arXiv:2402.19173]

Note: starcoder2 uses a non-gated MLP; we keep the zoo-uniform SwiGLU with
d_ff as given (parameter count differs by the gate matrix; recorded in
DESIGN.md as an adaptation).
"""

from repro.models.config import ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        arch_id="starcoder2-15b",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, head_dim=128,
        d_ff=24576, vocab=49152,
        rope_theta=100_000.0, tie_embeddings=False,
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        arch_id="starcoder2-15b-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab=256,
        tie_embeddings=False, attn_chunk=64, remat="none",
    )
