"""whisper-medium [audio]: enc-dec, 24L decoder (+24L encoder)
d_model=1024 16H (kv=16) d_ff=4096 vocab=51865; conv frontend is a STUB —
input_specs feeds precomputed 1500-frame embeddings.  [arXiv:2212.04356]

Adaptation note (DESIGN.md): sinusoidal/learned absolute positions in the
original are a learned encoder pos-emb + decoder RoPE here.
"""

from repro.models.config import ModelCfg


def config() -> ModelCfg:
    return ModelCfg(
        arch_id="whisper-medium",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865,
        enc_dec=True, n_enc_layers=24, enc_frames=1500,
        act_fn="gelu", tie_embeddings=True,
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        arch_id="whisper-medium-smoke",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        enc_dec=True, n_enc_layers=2, enc_frames=16,
        act_fn="gelu", tie_embeddings=True, attn_chunk=32, remat="none",
    )
