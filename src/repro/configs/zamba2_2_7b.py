"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64; Mamba2 backbone + shared attention blocks
(weight re-use), one shared block every 6 layers.  [arXiv:2411.15242]"""

from repro.models.config import ModelCfg, SSMCfg


def config() -> ModelCfg:
    return ModelCfg(
        arch_id="zamba2-2.7b",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000,
        block_kind="ssm",
        ssm=SSMCfg(d_state=64, head_dim=64, expand=2),
        hybrid_attn_every=6,
        tie_embeddings=True,
    )


def smoke_config() -> ModelCfg:
    return ModelCfg(
        arch_id="zamba2-2.7b-smoke",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        block_kind="ssm",
        ssm=SSMCfg(d_state=16, head_dim=16, expand=2, chunk=16),
        hybrid_attn_every=2,
        tie_embeddings=True, attn_chunk=64, remat="none",
    )
