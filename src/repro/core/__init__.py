"""LogicNets core: the paper's contribution as composable JAX modules."""

from repro.core.quantize import QuantizerCfg, QuantTensor, quantize, codes  # noqa: F401
from repro.core.layers import (  # noqa: F401
    SparseLinearCfg, DenseQuantLinearCfg, SparseConvCfg,
)
from repro.core.logicnet import LogicNetCfg  # noqa: F401
