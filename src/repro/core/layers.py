"""LogicNets layer types (paper §4.2–§4.4): SparseLinear, DenseQuantLinear,
SparseConv — as pure-functional JAX modules.

Every layer type has an *implicit input quantizer* (§4 design choice: LUT
cost is exponential in input bits, linear in output bits, so input
quantization is mandatory and output quantization optional).  Params and
batch-norm running stats are plain dicts; fan-in masks are static arrays kept
beside the params (never touched by the optimizer).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import lut_cost as lc
from repro.core import sparsity
from repro.core.quantize import QuantizerCfg, quantize

BN_EPS = 1e-5
BN_MOMENTUM = 0.1


# ---------------------------------------------------------------------------
# Batch norm (per-feature, as the thesis places after every linear)
# ---------------------------------------------------------------------------

def bn_init(features: int) -> tuple[dict, dict]:
    params = {"scale": jnp.ones((features,), jnp.float32),
              "bias": jnp.zeros((features,), jnp.float32)}
    state = {"mean": jnp.zeros((features,), jnp.float32),
             "var": jnp.ones((features,), jnp.float32)}
    return params, state


def bn_apply(params: dict, state: dict, x: jax.Array, train: bool,
             axis: tuple[int, ...] = (0,)) -> tuple[jax.Array, dict]:
    if train:
        mean = jnp.mean(x, axis=axis)
        var = jnp.var(x, axis=axis)
        new_state = {
            "mean": (1 - BN_MOMENTUM) * state["mean"] + BN_MOMENTUM * mean,
            "var": (1 - BN_MOMENTUM) * state["var"] + BN_MOMENTUM * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    shape = [1] * x.ndim
    shape[-1 if axis == (0,) or x.ndim == 2 else 1] = -1
    # For NHWC conv activations we normalize over (0, 1, 2); features last.
    y = (x - mean) * jax.lax.rsqrt(var + BN_EPS)
    y = y * params["scale"] + params["bias"]
    return y, new_state


def bn_eval_fn(params: dict, state: dict):
    """Per-feature affine the truth-table generator folds into the neuron."""
    scale = params["scale"] * jax.lax.rsqrt(state["var"] + BN_EPS)
    bias = params["bias"] - state["mean"] * scale
    return scale, bias


# ---------------------------------------------------------------------------
# SparseLinear (§4.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SparseLinearCfg:
    in_features: int
    out_features: int
    fan_in: int                      # per-neuron synapse count (X)
    bw_in: int                       # input quantizer bit-width (BW)
    max_val_in: float = 2.0
    use_bn: bool = True

    @property
    def in_quant(self) -> QuantizerCfg:
        return QuantizerCfg(self.bw_in, self.max_val_in)

    @property
    def fan_in_bits(self) -> int:
        return self.fan_in * self.bw_in

    def luts(self, bw_out: int) -> int:
        """Analytical LUT cost of this layer for a bw_out-bit output (§4.2)."""
        return lc.sparse_linear_cost(self.out_features, self.fan_in,
                                     self.bw_in, bw_out)


def sparse_linear_init(cfg: SparseLinearCfg, key: jax.Array,
                       mask_seed: int = 0) -> dict[str, Any]:
    kw, _ = jax.random.split(key)
    scale = 1.0 / jnp.sqrt(jnp.maximum(cfg.fan_in, 1.0))
    w = jax.random.normal(kw, (cfg.in_features, cfg.out_features),
                          jnp.float32) * scale
    bn_p, bn_s = bn_init(cfg.out_features)
    return {
        "params": {"w": w, "b": jnp.zeros((cfg.out_features,), jnp.float32),
                   "bn": bn_p},
        "mask": sparsity.apriori_mask(mask_seed, cfg.in_features,
                                      cfg.out_features, cfg.fan_in),
        "bn_state": bn_s,
    }


def sparse_linear_apply(cfg: SparseLinearCfg, layer: dict[str, Any],
                        x: jax.Array, train: bool = False
                        ) -> tuple[jax.Array, dict[str, Any]]:
    """Input-quantize -> masked linear -> BN.  Returns pre-(next)quantizer
    activations plus the layer dict with updated BN state."""
    qt = quantize(cfg.in_quant, x)
    w = layer["params"]["w"] * layer["mask"]
    y = qt.value @ w + layer["params"]["b"]
    if cfg.use_bn:
        y, bn_s = bn_apply(layer["params"]["bn"], layer["bn_state"], y, train)
        layer = dict(layer, bn_state=bn_s)
    return y, layer


# ---------------------------------------------------------------------------
# DenseQuantLinear (§4.3) — used for the final (dense) layer of most models
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseQuantLinearCfg:
    in_features: int
    out_features: int
    bw_in: int
    max_val_in: float = 2.0
    bw_weight: int = 4               # for the eq. 4.1 cost model
    use_bn: bool = True

    @property
    def in_quant(self) -> QuantizerCfg:
        return QuantizerCfg(self.bw_in, self.max_val_in)

    def luts(self) -> float:
        return lc.dense_quant_linear_cost(self.out_features, self.in_features,
                                          self.bw_in, self.bw_weight)


def dense_quant_linear_init(cfg: DenseQuantLinearCfg,
                            key: jax.Array) -> dict[str, Any]:
    scale = 1.0 / jnp.sqrt(cfg.in_features)
    w = jax.random.normal(key, (cfg.in_features, cfg.out_features),
                          jnp.float32) * scale
    bn_p, bn_s = bn_init(cfg.out_features)
    return {
        "params": {"w": w, "b": jnp.zeros((cfg.out_features,), jnp.float32),
                   "bn": bn_p},
        "bn_state": bn_s,
    }


def dense_quant_linear_apply(cfg: DenseQuantLinearCfg, layer: dict[str, Any],
                             x: jax.Array, train: bool = False
                             ) -> tuple[jax.Array, dict[str, Any]]:
    qt = quantize(cfg.in_quant, x)
    y = qt.value @ layer["params"]["w"] + layer["params"]["b"]
    if cfg.use_bn:
        y, bn_s = bn_apply(layer["params"]["bn"], layer["bn_state"], y, train)
        layer = dict(layer, bn_state=bn_s)
    return y, layer


# ---------------------------------------------------------------------------
# SparseConv (§4.4) — sparse quantized depthwise-separable convolution
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SparseConvCfg:
    in_channels: int
    out_channels: int
    kernel_size: int = 3
    stride: int = 1
    x_k: int = 5                     # depthwise kernel sparsity (synapses)
    x_s: int = 5                     # pointwise sparsity (synapses)
    bw_in: int = 2                   # input quantizer bits
    bw_mid: int = 2                  # intermediate quantizer bits
    max_val_in: float = 2.0
    max_val_mid: float = 2.0
    first_layer: bool = False

    @property
    def in_quant(self) -> QuantizerCfg:
        return QuantizerCfg(self.bw_in, self.max_val_in)

    @property
    def mid_quant(self) -> QuantizerCfg:
        return QuantizerCfg(self.bw_mid, self.max_val_mid)

    @property
    def dw_channels(self) -> int:
        # §4.4: first layer with 1 input channel replicates the input to
        # out_channels depthwise kernels (a single sparse 2D kernel cannot
        # extract enough information).
        if self.first_layer and self.in_channels == 1:
            return self.out_channels
        return self.in_channels

    def luts(self, out_pix: int, o_bits: int) -> tuple[int, int]:
        dw = lc.sparse_conv_dw_cost(out_pix, self.bw_mid, self.dw_channels,
                                    self.x_k, self.bw_in)
        pw = lc.sparse_conv_pw_cost(out_pix, o_bits, self.out_channels,
                                    self.x_s, self.bw_mid)
        return dw, pw


def sparse_conv_init(cfg: SparseConvCfg, key: jax.Array,
                     mask_seed: int = 0) -> dict[str, Any]:
    k_dw, k_pw = jax.random.split(key)
    dw_ch = cfg.dw_channels
    k2 = cfg.kernel_size * cfg.kernel_size
    # Depthwise: (k, k, dw_ch) one kernel per channel; mask keeps x_k taps.
    w_dw = jax.random.normal(k_dw, (cfg.kernel_size, cfg.kernel_size, dw_ch),
                             jnp.float32) / jnp.sqrt(float(cfg.x_k))
    m_dw = sparsity.apriori_mask(mask_seed, k2, dw_ch,
                                 min(cfg.x_k, k2)).reshape(
        cfg.kernel_size, cfg.kernel_size, dw_ch)
    # Pointwise: (dw_ch, out_channels); mask keeps x_s input channels/neuron.
    w_pw = jax.random.normal(k_pw, (dw_ch, cfg.out_channels),
                             jnp.float32) / jnp.sqrt(float(cfg.x_s))
    m_pw = sparsity.apriori_mask(mask_seed + 1, dw_ch, cfg.out_channels,
                                 min(cfg.x_s, dw_ch))
    bn1_p, bn1_s = bn_init(dw_ch)
    bn2_p, bn2_s = bn_init(cfg.out_channels)
    return {
        "params": {"w_dw": w_dw, "w_pw": w_pw,
                   "b_dw": jnp.zeros((dw_ch,), jnp.float32),
                   "b_pw": jnp.zeros((cfg.out_channels,), jnp.float32),
                   "bn1": bn1_p, "bn2": bn2_p},
        "mask_dw": m_dw, "mask_pw": m_pw,
        "bn_state": {"bn1": bn1_s, "bn2": bn2_s},
    }


def _depthwise(x: jax.Array, w: jax.Array, stride: int,
               replicate: bool) -> jax.Array:
    """NHWC depthwise conv; ``replicate`` broadcasts 1 input channel to all
    kernels (first-layer rule, §4.4)."""
    dw_ch = w.shape[-1]
    if replicate:
        x = jnp.broadcast_to(x, x.shape[:-1] + (dw_ch,))
    kernel = w[:, :, None, :]  # (kh, kw, 1, out_ch): depthwise HWIO
    return jax.lax.conv_general_dilated(
        x, kernel, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=dw_ch)


def sparse_conv_apply(cfg: SparseConvCfg, layer: dict[str, Any],
                      x: jax.Array, train: bool = False
                      ) -> tuple[jax.Array, dict[str, Any]]:
    """quant -> sparse depthwise -> BN -> quant -> sparse pointwise -> BN."""
    p, bn = layer["params"], layer["bn_state"]
    replicate = cfg.first_layer and cfg.in_channels == 1
    qt = quantize(cfg.in_quant, x)
    w_dw = p["w_dw"] * layer["mask_dw"]
    h = _depthwise(qt.value, w_dw, cfg.stride, replicate) + p["b_dw"]
    h, bn1_s = bn_apply(p["bn1"], bn["bn1"], h, train, axis=(0, 1, 2))
    qm = quantize(cfg.mid_quant, h)
    w_pw = p["w_pw"] * layer["mask_pw"]
    y = jnp.einsum("bhwc,co->bhwo", qm.value, w_pw) + p["b_pw"]
    y, bn2_s = bn_apply(p["bn2"], bn["bn2"], y, train, axis=(0, 1, 2))
    layer = dict(layer, bn_state={"bn1": bn1_s, "bn2": bn2_s})
    return y, layer
