"""LogicNet network assembly (paper Part II): config -> train -> truth
tables -> netlist -> Verilog, plus LUT-cost accounting and skip connections.

A LogicNet is a stack of SparseLinear layers (with mandatory input
quantizers) and an optional final DenseQuantLinear — the topology family of
Tables 6.1 / 7.1.  Skip connections (§7 'Skip Connections') concatenate an
earlier layer's activations into a later layer's input; because per-neuron
fan-in is what prices a neuron, skips are LUT-cost-free.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core import netlist as NL
from repro.core import table_infer
from repro.core import truth_table as TT
from repro.core.quantize import QuantizerCfg, codes, dequantize_code


@dataclasses.dataclass(frozen=True)
class LogicNetCfg:
    """Model family of the paper's experiments.

    hidden: neuron counts per hidden layer (HL column).
    fan_in: per-neuron synapses X (uniform across hidden layers).
    bw:     activation bit-width BW.
    final_dense: dense final layer (the usual MNIST/JSC choice); when False
                 the final layer is sparse with fan_in_fc synapses (X_fc).
    bw_fc:  output bit-width of the network (BW_fc).
    skips:  list of (src_layer, dst_layer) activation concatenations.
    """

    in_features: int
    n_classes: int
    hidden: tuple[int, ...]
    fan_in: int
    bw: int
    final_dense: bool = True
    fan_in_fc: int | None = None
    bw_fc: int = 3
    max_val: float = 2.0
    skips: tuple[tuple[int, int], ...] = ()

    def layer_cfgs(self) -> list[Any]:
        cfgs: list[Any] = []
        widths = [self.in_features, *self.hidden]
        for i, out_f in enumerate(self.hidden):
            in_f = widths[i] + sum(self.hidden[s] if s > 0 else
                                   self.in_features
                                   for s, d in self.skips if d == i)
            cfgs.append(L.SparseLinearCfg(
                in_f, out_f, min(self.fan_in, in_f), self.bw,
                self.max_val))
        in_f = widths[-1] + sum(self.hidden[s] if s > 0 else self.in_features
                                for s, d in self.skips
                                if d == len(self.hidden))
        if self.final_dense:
            cfgs.append(L.DenseQuantLinearCfg(
                in_f, self.n_classes, self.bw, self.max_val))
        else:
            cfgs.append(L.SparseLinearCfg(
                in_f, self.n_classes,
                min(self.fan_in_fc or self.fan_in, in_f), self.bw,
                self.max_val))
        return cfgs

    @property
    def out_quant(self) -> QuantizerCfg:
        return QuantizerCfg(self.bw_fc, self.max_val)

    def luts(self) -> list[int]:
        """Per-layer analytical LUT cost (LUTL1..LUTLn columns).

        Final *sparse* layers are costed at 2*BW_fc output bits — the
        signed-logit accounting that reproduces Table 6.1 models D
        (LUTL4=3400) and E (LUTL4=200) exactly.
        """
        out = []
        cfgs = self.layer_cfgs()
        for i, c in enumerate(cfgs):
            if isinstance(c, L.SparseLinearCfg):
                bw_out = (cfgs[i + 1].bw_in if i + 1 < len(cfgs)
                          else 2 * self.bw_fc)
                out.append(c.luts(bw_out))
            else:
                out.append(int(round(c.luts())))
        return out

    def total_luts(self) -> int:
        return sum(self.luts())


def init(cfg: LogicNetCfg, key: jax.Array, mask_seed: int = 0) -> list[dict]:
    model = []
    for i, c in enumerate(cfg.layer_cfgs()):
        key, sub = jax.random.split(key)
        if isinstance(c, L.SparseLinearCfg):
            model.append(L.sparse_linear_init(c, sub, mask_seed + i))
        else:
            model.append(L.dense_quant_linear_init(c, sub))
    return model


def forward(cfg: LogicNetCfg, model: list[dict], x: jax.Array,
            train: bool = False) -> tuple[jax.Array, list[dict]]:
    """Float (STE fake-quant) forward.  Returns logits + updated BN state."""
    cfgs = cfg.layer_cfgs()
    acts = [x]
    new_model = []
    h = x
    for i, (c, layer) in enumerate(zip(cfgs, model)):
        inp = h
        for s, d in cfg.skips:
            if d == i:
                inp = jnp.concatenate([inp, acts[s]], axis=-1)
        if isinstance(c, L.SparseLinearCfg):
            h, layer = L.sparse_linear_apply(c, layer, inp, train)
        else:
            h, layer = L.dense_quant_linear_apply(c, layer, inp, train)
        acts.append(h)
        new_model.append(layer)
    return h, new_model


def loss_fn(cfg: LogicNetCfg, model: list[dict], x: jax.Array,
            y: jax.Array, train: bool = True
            ) -> tuple[jax.Array, list[dict]]:
    logits, new_model = forward(cfg, model, x, train)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll, new_model


def accuracy(cfg: LogicNetCfg, model: list[dict], x: jax.Array,
             y: jax.Array) -> jax.Array:
    logits, _ = forward(cfg, model, x, train=False)
    return (jnp.argmax(logits, axis=-1) == y).mean()


# ---------------------------------------------------------------------------
# Conversion: NEQs -> HBBs (design-flow step 3)
# ---------------------------------------------------------------------------

def generate_tables(cfg: LogicNetCfg, model: list[dict]
                    ) -> list[TT.LayerTruthTable]:
    """Truth tables for every *sparse* layer (dense final layers are kept as
    arithmetic, as in the thesis — Verilog gen supports SparseLinear only)."""
    if cfg.skips:
        raise NotImplementedError(
            "table conversion for skip topologies needs bus rewiring; "
            "train-time support only (as in the thesis)")
    cfgs = cfg.layer_cfgs()
    tables = []
    for i, (c, layer) in enumerate(zip(cfgs, model)):
        if not isinstance(c, L.SparseLinearCfg):
            break
        out_q = (cfgs[i + 1].in_quant if i + 1 < len(cfgs)
                 else cfg.out_quant)
        tables.append(TT.generate_sparse_linear_table(c, layer, out_q))
    return tables


def verify_tables(cfg: LogicNetCfg, model: list[dict],
                  tables: list[TT.LayerTruthTable], x: jax.Array,
                  fused: bool = False,
                  optimize_level: int | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Functional verification: float path vs table path on the sparse stack.

    Returns (codes_float_path, codes_table_path); the contract is exact
    equality.  ``fused`` runs the table path through the whole-network
    Pallas engine (``repro.engine`` via ``network_table_forward`` — the
    flags are compatibility wrappers over the one compiled path) instead
    of the per-layer jnp reference;
    ``optimize_level`` first shrinks the tables through the truth-table
    compiler (``repro.compile``) — the equality contract must survive it.
    ``fused=True`` with an ``optimize_level`` executes the compiler's
    mixed-width lowering (exact per-neuron table sizes in VMEM), so this
    is also the mixed kernel's end-to-end verification hook.
    """
    cfgs = cfg.layer_cfgs()
    in_codes = codes(cfgs[0].in_quant, x)
    table_out = table_infer.network_table_forward(
        tables, in_codes, fused=fused, optimize_level=optimize_level)

    h = x
    layer = None
    for i in range(len(tables)):
        c = cfgs[i]
        h, _ = L.sparse_linear_apply(c, model[i], h, train=False)
    out_q = (cfgs[len(tables)].in_quant if len(tables) < len(cfgs)
             else cfg.out_quant)
    float_out = codes(out_q, h)
    return float_out, table_out


def sparse_head_forward(cfg: LogicNetCfg, model: list[dict],
                        tables: list[TT.LayerTruthTable],
                        x: jax.Array, fused: bool = False,
                        optimize_level: int | None = None) -> jax.Array:
    """Deployment-style forward: sparse stack via tables, then the dense
    final layer (if any) in arithmetic.  ``fused`` executes the sparse
    stack as one whole-network Pallas kernel (the FPGA-pipeline path);
    ``optimize_level`` runs the truth-table compiler first and the fused
    engine consumes its mixed-width lowering, so the VMEM slabs shrink to
    the compiler-exact footprint (bit-identical output on reachable
    inputs).  Both flags route through the memoized serving engine
    (``repro.engine``), so calling this in a loop does not recompile; a
    production loop should still compile once via
    ``repro.engine.compile_network`` and keep the artifact."""
    cfgs = cfg.layer_cfgs()
    c0 = cfgs[0]
    in_codes = codes(c0.in_quant, x)
    out_codes = table_infer.network_table_forward(
        tables, in_codes, fused=fused, optimize_level=optimize_level)
    if len(tables) == len(cfgs):
        return out_codes
    cfin = cfgs[-1]
    h = dequantize_code(cfin.in_quant, out_codes)
    logits, _ = L.dense_quant_linear_apply(cfin, model[-1], h, train=False)
    return logits


def to_verilog(cfg: LogicNetCfg, model: list[dict],
               pipeline: bool = False,
               optimize_level: int | None = None,
               sop: bool = False) -> dict[str, str]:
    """Generate RTL; ``optimize_level`` routes the netlist through the
    truth-table compiler first — deduped/shrunk case-statement modules with
    don't-care entries folded into each module's ``default:`` arm.
    ``sop=True`` emits two-level sum-of-products assigns for neurons the
    minimizer covered (``optimize_level=4`` attaches the covers); the rest
    keep the case-statement form."""
    from repro.core import verilog
    tables = generate_tables(cfg, model)
    if optimize_level is not None:
        from repro.compile import optimize
        nl = optimize(tables, optimize_level,
                      in_features=cfg.in_features).netlist
    else:
        nl = NL.build_netlist(tables, cfg.in_features)
    return verilog.generate_verilog(nl, pipeline, sop=sop)
