"""Analytical LUT-cost model (paper §2.1 eqs. 2.1–2.3, §4 eqs. 4.1–4.4).

All counts are for hardware building blocks composed solely of 6:1 LUTs —
the paper's pessimistic cost heuristic (actual Vivado synthesis lands
1.6–9.5x lower, Table 5.2).  Integer-exact: validated byte-for-byte against
Table 2.1 and the LUT columns of Table 6.1 in the tests.
"""

from __future__ import annotations

import dataclasses


def code_width(bits: int) -> int:
    """Bytes of the smallest {1, 2, 4}-byte int holding a ``bits``-bit code.

    The single source of truth for packed-table storage accounting
    (``Netlist.table_bytes``, ``CNet.table_bytes``,
    ``table_infer.table_memory_bytes``, ``table_vmem_bytes``) — these
    byte counts feed the raw-vs-optimized comparisons in the CI
    COMPILE_stats artifact, so they must all use the same ladder.
    """
    return 1 if bits <= 8 else (2 if bits <= 16 else 4)


def lut_cost_per_bit(n_fan_in_bits: int) -> int:
    """6-LUT count for one output bit of a neuron with N fan-in bits.

    Closed form (2.3): (2^(N-4) - (-1)^N) / 3, valid for N >= 6; any boolean
    function of <= 6 inputs fits a single 6:1 LUT.
    """
    n = int(n_fan_in_bits)
    if n <= 0:
        raise ValueError(f"fan-in bits must be positive, got {n}")
    if n <= 6:
        return 1
    return (2 ** (n - 4) - (-1) ** n) // 3


def lut_cost(n_fan_in_bits: int, m_out_bits: int) -> int:
    """Eq. (2.3): LUT_{N,M} = M * (2^(N-4) - (-1)^N) / 3 (clamped at 1/bit)."""
    return int(m_out_bits) * lut_cost_per_bit(n_fan_in_bits)


def lut_cost_recursive(n_fan_in_bits: int, m_out_bits: int) -> int:
    """Eq. (2.1) recursion — used to property-test the closed form."""
    n, m = int(n_fan_in_bits), int(m_out_bits)
    if n <= 6:
        return m
    per_bit = lut_cost_recursive(n - 1, m) // m
    return m * (2 * per_bit - (-1) ** n)


@dataclasses.dataclass(frozen=True)
class StaticMappingRow:
    """One row of Table 2.1."""

    fan_in: int
    n_6luts: int
    truth_table_bits: int
    lut_config_bits: int
    pct_utilized: float


def static_mapping_row(fan_in_bits: int) -> StaticMappingRow:
    """Table 2.1: mapping a ``fan_in_bits``:1 truth table onto 6:1 LUTs."""
    n = lut_cost_per_bit(fan_in_bits)
    tt_bits = 2 ** fan_in_bits
    cfg_bits = 64 * n
    return StaticMappingRow(fan_in_bits, n, tt_bits, cfg_bits,
                            100.0 * tt_bits / cfg_bits)


def truth_table_bits(ip_bits: int, op_bits: int) -> int:
    """Storage for the naive LUT of a neuron f: B^ip -> B^op (§3 intro):
    2^ip * (op + ip) bits (the paper stores inputs alongside outputs)."""
    return (2 ** ip_bits) * (op_bits + ip_bits)


def truth_table_output_bits(ip_bits: int, op_bits: int) -> int:
    """Output-only storage, 2^ip * op bits — the §1.2 '4.50e15 bits for a
    fan-in-3 16-bit neuron' accounting."""
    return (2 ** ip_bits) * op_bits


# ---------------------------------------------------------------------------
# Layer-level costs
# ---------------------------------------------------------------------------

def sparse_linear_cost(out_features: int, fan_in: int, bw_in: int,
                       bw_out: int) -> int:
    """LUT cost of a SparseLinear layer: every neuron sees fan_in synapses of
    bw_in bits each and emits bw_out bits."""
    return out_features * lut_cost(fan_in * bw_in, bw_out)


def dense_quant_linear_cost(n_out: int, n_in: int, bw_in: int,
                            bw_wt: int) -> float:
    """Eq. (4.1): LUTS = n(O) * (n(I) * BWin * BWwt * 1.0699 + 10.779)."""
    return n_out * (n_in * bw_in * bw_wt * 1.0699 + 10.779)


def dense_conv_cost(out_pix: int, o_bits: int, n_ofm: int, n_ifm: int,
                    k: int, i_bits: int) -> int:
    """Eq. (4.2): fully-unfolded dense convolution."""
    return out_pix * o_bits * n_ofm * lut_cost_per_bit(n_ifm * k * k * i_bits)


def sparse_conv_dw_cost(out_pix: int, o_bits: int, n_ofm: int, x_k: int,
                        i_bits: int) -> int:
    """Eq. (4.3): depthwise stage; X_k = kernel sparsity (synapse count)."""
    return out_pix * o_bits * n_ofm * lut_cost_per_bit(x_k * i_bits)


def sparse_conv_pw_cost(out_pix: int, o_bits: int, n_ofm: int, x_s: int,
                        i_bits: int) -> int:
    """Eq. (4.4): pointwise stage; X_s = pointwise sparsity (synapse count)."""
    return out_pix * o_bits * n_ofm * lut_cost_per_bit(x_s * i_bits)


def netlist_lut_cost(netlist) -> int:
    """Analytical 6-LUT cost of a (possibly optimized) ``Netlist``.

    Per-neuron ``lut_cost(len(input_bits), out_bits)`` summed over the net —
    the quantity the compile pipeline reports as pre- vs post-optimization
    cost.  Unlike the config-level ``sparse_linear_cost`` this prices each
    neuron at its *own* width, so pruned inputs and eliminated neurons show
    up directly.
    """
    total = 0
    for layer in netlist.layers:
        for n in layer:
            total += lut_cost(max(len(n.input_bits), 1), n.out_bits)
    return total


# ---------------------------------------------------------------------------
# Measured post-synthesis cost (two-level SOP covers, repro.synth)
# ---------------------------------------------------------------------------

def sop_lut_estimate(cover, k: int = 6) -> int:
    """k-LUT estimate for one neuron's minimized SOP cover.

    Per output bit: each product term of L literals packs into an AND
    tree of ``ceil((L-1)/(k-1))`` k-input LUTs (0 when L <= 1 — a bare
    wire or inverter absorbs into the OR stage), then the T terms
    combine through an OR tree of ``ceil((T-1)/(k-1))`` LUTs; a bit
    whose whole expression fits one LUT costs 1.  The estimate is
    clamped per bit by the worst-case ``lut_cost_per_bit`` of the bit's
    *actual support* — two-level form can be a bad shape for LUT
    packing (many wide terms), but a LUT never needs more than the
    generic bound on the inputs the bit truly depends on.  Constant and
    single-literal bits cost 0.
    """
    if k < 2:
        raise ValueError(f"k-LUT packing needs k >= 2, got {k}")

    def tree(n_inputs: int) -> int:
        # LUTs to reduce n_inputs signals to 1 through k-ary nodes
        if n_inputs <= 1:
            return 0
        return -(-(n_inputs - 1) // (k - 1))

    total = 0
    for b in range(cover.out_bits):
        cubes = cover.bits[b]
        support = len(cover.bit_support(b))
        if support == 0:        # constant bit: a tied-off wire, no LUT
            continue
        lits = [c.n_literals for c in cubes]
        if len(cubes) == 1 and lits[0] <= 1:
            continue            # bare wire / single inverter
        if support <= k:
            est = 1             # whole bit fits one k-LUT
        else:
            est = sum(tree(n) for n in lits) + tree(len(cubes))
            est = max(est, 1)
        total += min(est, lut_cost_per_bit(support))
    return total


def netlist_sop_cost(netlist, k: int = 6) -> dict:
    """Measured post-synthesis cost of a synthesized ``Netlist``.

    Sums :func:`sop_lut_estimate` over every neuron carrying an SOP
    cover; neurons without one (budget fallback) are priced at the
    worst-case :func:`lut_cost` bound.  Returns the accounting dict the
    bench reports next to the analytical bound: ``est_kluts`` (the
    headline), ``literals`` / ``terms`` totals, and the
    covered/fallback split.
    """
    est = literals = terms = 0
    covered = fallback = 0
    for layer in netlist.layers:
        for n in layer:
            if n.sop is None:
                fallback += 1
                est += lut_cost(max(len(n.input_bits), 1), n.out_bits)
            else:
                covered += 1
                est += sop_lut_estimate(n.sop, k)
                literals += n.sop.n_literals
                terms += n.sop.n_terms
    return {"est_kluts": est, "literals": literals, "terms": terms,
            "covered_neurons": covered, "fallback_neurons": fallback,
            "k": k}


# ---------------------------------------------------------------------------
# TPU-path cost model (hardware adaptation, see DESIGN.md §2)
# ---------------------------------------------------------------------------

def table_vmem_bytes(out_features: int, fan_in: int, bw_in: int,
                     bw_out: int) -> int:
    """Bytes of VMEM the truth-table tensor occupies on the TPU gather path.

    Each neuron stores 2^(fan_in*bw_in) output codes; codes are packed to the
    smallest of {1, 2, 4} bytes that holds bw_out bits.
    """
    entries = 2 ** (fan_in * bw_in)
    return out_features * entries * code_width(bw_out)
