"""Netlist of Hardware Building Blocks (paper §4 design flow step 3).

The trained network of Neuron EQuivalents (NEQs) becomes a list of LUT
layers; each neuron is one HBB: (input bit positions on the layer bus,
truth-table entries).  This IR feeds both the Verilog generator and the
TPU lut_lookup serving path.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.truth_table import LayerTruthTable


@dataclasses.dataclass
class NeuronHBB:
    """One hardware building block (a configured multi-bit LUT).

    ``reachable`` (optional, set by the compile pipeline) marks which table
    entries can actually occur at runtime; unreachable entries are
    don't-cares that the Verilog generator may fold into a ``default:`` arm.
    """

    layer: int
    neuron: int
    input_bits: list[int]     # positions on the incoming layer bus, LSB first
    out_bits: int
    table: np.ndarray         # (2^len(input_bits),) output codes
    reachable: np.ndarray | None = None   # (2^len(input_bits),) bool
    # minimized two-level cover (repro.synth.SopCover), attached by
    # synth.synthesize_netlist; None = unsynthesized or budget fallback.
    # Exact on reachable entries only — may differ from `table` on
    # don't-cares.
    sop: object | None = None

    @property
    def n_entries(self) -> int:
        return int(self.table.shape[0])


@dataclasses.dataclass
class Netlist:
    in_bits: int                     # width of the input bus M0
    out_bits: int                    # width of the output bus
    layers: list[list[NeuronHBB]]
    # per-layer input code width; recorded by build_netlist (and the compile
    # pipeline's lowering) so the optimizer can lift bus bits back to
    # feature indices.  None on hand-built netlists.
    layer_bw_in: list[int] | None = None
    # per-layer, per-feature input code widths — set by the compile
    # pipeline's lowering once the cross-layer re-encoding pass has narrowed
    # individual bus features below the uniform layer_bw_in.  Feature f of
    # layer l's input bus occupies bits [sum(widths[:f]), sum(widths[:f+1]))
    # of that layer's bus.  None means every feature is layer_bw_in wide.
    layer_in_widths: list[list[int]] | None = None

    @property
    def n_hbbs(self) -> int:
        return sum(len(l) for l in self.layers)

    def table_bytes(self) -> int:
        """Per-neuron packed table storage (minimal {1,2,4}-byte codes)."""
        from repro.core.lut_cost import code_width

        return sum(n.n_entries * code_width(n.out_bits)
                   for layer in self.layers for n in layer)


def build_netlist(tables: list[LayerTruthTable], in_features: int) -> Netlist:
    """Wire LayerTruthTables into a bus-addressed netlist.

    Layer l's input bus packs feature f's code at bits
    [bw_in*f, bw_in*(f+1)) — the convention shared with table_infer.
    """
    layers = []
    bus_features = in_features
    for li, tt in enumerate(tables):
        if li > 0 and bus_features != tables[li - 1].out_features:
            raise ValueError("layer width mismatch")
        neurons = []
        for j in range(tt.out_features):
            bits = []
            for k in range(tt.fan_in):          # element k -> LSB-first
                f = int(tt.indices[j, k])
                bits.extend(tt.bw_in * f + b for b in range(tt.bw_in))
            neurons.append(NeuronHBB(li, j, bits, tt.bw_out, tt.table[j]))
        layers.append(neurons)
        bus_features = tt.out_features
    in_bits = tables[0].bw_in * in_features
    out_bits = tables[-1].bw_out * tables[-1].out_features
    return Netlist(in_bits, out_bits, layers,
                   layer_bw_in=[tt.bw_in for tt in tables])
