"""Activation quantizers (paper §3.1.2, §4.1).

The paper uses Brevitas' ``QuantHardTanh`` (bit-width 1) and ``QuantReLU``
(bit-width >= 2).  Both are reproduced here as pure-JAX fake-quant functions
with straight-through-estimator (STE) gradients, returning a ``QuantTensor``
(value-in-dequantized-representation, scale, bit_width) exactly like the
Brevitas NamedTuple in Listing 4.1.

Integer *codes* are the bridge to truth tables: ``codes()`` maps a quantized
activation to its integer level, ``dequantize_code()`` inverts it.  The pair
is exact (code -> value -> code round-trips bit-perfectly), which is what
makes truth-table functional verification exact.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class QuantTensor(NamedTuple):
    """Mirror of Brevitas' QuantTensor: dequantized value + scale + bits."""

    value: jax.Array
    scale: jax.Array
    bit_width: int


@dataclasses.dataclass(frozen=True)
class QuantizerCfg:
    """Configuration of one activation quantizer.

    bit_width == 1  -> QuantHardTanh: output in {-max_val, +max_val}.
    bit_width >= 2  -> QuantReLU: uniform levels {0, ..., 2^b - 1} * step,
                       step = max_val / (2^b - 1).
    """

    bit_width: int
    max_val: float = 1.0

    @property
    def n_levels(self) -> int:
        return 2 ** self.bit_width

    @property
    def step(self) -> float:
        if self.bit_width == 1:
            # two levels: -max_val, +max_val
            return 2.0 * self.max_val
        return self.max_val / (self.n_levels - 1)


def _ste(x: jax.Array, q: jax.Array) -> jax.Array:
    """Straight-through estimator: forward is *exactly* q (bit-exact on the
    quantizer grid — required for truth-table equality), gradient is the
    identity on x (the clipped pre-activation).  ``x - stop_grad(x)`` is an
    exact zero with gradient one; ``q``'s own gradient is zero a.e. (round /
    where)."""
    return q + (x - jax.lax.stop_gradient(x))


def quantize(cfg: QuantizerCfg, x: jax.Array) -> QuantTensor:
    """Fake-quantize ``x``; forward value is exactly on the quantizer grid."""
    if cfg.bit_width == 1:
        # QuantHardTanh: sign() to +-max_val.  Clip for the STE pass-through
        # region, as brevitas does for hardtanh.
        clipped = jnp.clip(x, -cfg.max_val, cfg.max_val)
        q = jnp.where(x >= 0.0, cfg.max_val, -cfg.max_val).astype(x.dtype)
        return QuantTensor(_ste(clipped, q), jnp.asarray(cfg.max_val, x.dtype), 1)
    # QuantReLU
    step = jnp.asarray(cfg.step, x.dtype)
    clipped = jnp.clip(x, 0.0, cfg.max_val)
    q = jnp.round(clipped / step) * step
    return QuantTensor(_ste(clipped, q), step, cfg.bit_width)


def codes(cfg: QuantizerCfg, x: jax.Array) -> jax.Array:
    """Integer level of each element of ``x`` after quantization.

    For bit_width 1 the codes are {0, 1} (0 -> -max_val, 1 -> +max_val);
    otherwise {0, ..., 2^b - 1}.
    """
    if cfg.bit_width == 1:
        return (x >= 0.0).astype(jnp.int32)
    step = cfg.step
    c = jnp.round(jnp.clip(x, 0.0, cfg.max_val) / step)
    return c.astype(jnp.int32)


def dequantize_code(cfg: QuantizerCfg, c: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Exact inverse of :func:`codes` onto the quantizer grid."""
    c = c.astype(dtype)
    if cfg.bit_width == 1:
        return (2.0 * c - 1.0) * cfg.max_val
    return c * cfg.step


def all_codes(cfg: QuantizerCfg) -> jax.Array:
    """All integer levels of this quantizer, shape (2^bit_width,)."""
    return jnp.arange(cfg.n_levels, dtype=jnp.int32)
