"""Per-neuron fan-in sparsity (paper §1.2.2, §3.1.1, Algorithm 1).

LogicNets needs *per-neuron* fan-in bounds, not layer-granular sparsity: every
output neuron must see exactly ``fan_in`` inputs so its truth table stays
enumerable.  Three families from the paper:

* A-priori fixed sparsity — random bipartite expander (Deep Expander
  Networks): each neuron picks ``fan_in`` distinct inputs uniformly at
  random; the mask never changes during training.
* Iterative pruning — per-neuron magnitude pruning on a decay schedule:
  the per-neuron connection count anneals from dense to ``fan_in``.
* Sparse momentum (modified, Algorithm 1) — per-neuron prune by |w|,
  per-neuron regrow by |momentum| of inactive weights.  The paper's
  modification drops cross-layer momentum redistribution (fixed fan-in
  makes it useless) — we keep the tracked quantities for parity.

Also: the Erdős–Rényi layer-sparsity allocation discussed in §3.3.
Masks are (in_features, out_features) float {0,1} arrays; weights are stored
dense and multiplied by the mask (weights themselves may be full precision —
they are absorbed into truth tables at conversion time).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# A-priori fixed sparsity (random bipartite expander)
# ---------------------------------------------------------------------------

def apriori_mask(seed: int, in_features: int, out_features: int,
                 fan_in: int) -> jax.Array:
    """Random-expander mask: each output neuron gets ``fan_in`` distinct inputs.

    Returns float32 (in_features, out_features) with exactly ``fan_in`` ones
    per column.
    """
    if fan_in > in_features:
        raise ValueError(f"fan_in {fan_in} > in_features {in_features}")
    rng = np.random.default_rng(seed)
    mask = np.zeros((in_features, out_features), dtype=np.float32)
    for j in range(out_features):
        idx = rng.choice(in_features, size=fan_in, replace=False)
        mask[idx, j] = 1.0
    return jnp.asarray(mask)


def mask_to_indices(mask: jax.Array) -> np.ndarray:
    """(out_features, fan_in) int32 input indices per neuron (sorted).

    Requires a uniform per-neuron fan-in; raises otherwise — that is the
    LogicNets invariant.
    """
    m = np.asarray(mask)
    counts = m.sum(axis=0).astype(np.int64)
    if counts.size == 0:
        raise ValueError("empty mask")
    if not (counts == counts[0]).all():
        raise ValueError(f"non-uniform per-neuron fan-in: {np.unique(counts)}")
    fan_in = int(counts[0])
    out_features = m.shape[1]
    idx = np.zeros((out_features, fan_in), dtype=np.int32)
    for j in range(out_features):
        idx[j] = np.nonzero(m[:, j])[0]
    return idx


# ---------------------------------------------------------------------------
# Per-neuron top-k re-masking (shared by iterative pruning / sparse momentum)
# ---------------------------------------------------------------------------

def _per_neuron_topk_mask(score: jax.Array, k: int) -> jax.Array:
    """Keep, per column (neuron), the ``k`` highest-scoring rows.

    Exact count even with ties (rank by double argsort, stable).
    score: (in_features, out_features) -> float {0,1} mask of same shape.
    """
    # Descending rank per column.
    order = jnp.argsort(-score, axis=0, stable=True)
    ranks = jnp.argsort(order, axis=0, stable=True)
    return (ranks < k).astype(score.dtype)


def iterative_prune_mask(weights: jax.Array, mask: jax.Array,
                         target_fan_in: int, frac: float) -> jax.Array:
    """One iterative-pruning step (paper Fig. 3.2 pipeline).

    ``frac`` in [0, 1] is training progress; the per-neuron keep count decays
    from in_features (dense) to target_fan_in following a cubic schedule
    (Zhu & Gupta style), pruning smallest-magnitude *active* weights per
    neuron.  Returns the new mask.
    """
    in_features = weights.shape[0]
    frac = float(np.clip(frac, 0.0, 1.0))
    keep = int(round(target_fan_in + (in_features - target_fan_in)
                     * (1.0 - frac) ** 3))
    keep = max(target_fan_in, min(in_features, keep))
    score = jnp.abs(weights) * mask  # only active weights compete
    return _per_neuron_topk_mask(score, keep)


def sparse_momentum_step(weights: jax.Array, momentum: jax.Array,
                         mask: jax.Array, fan_in: int,
                         prune_rate: float) -> jax.Array:
    """Algorithm 1 (modified per-neuron sparse learning), one pruning step.

    Per neuron: prune ``P1 = ceil(prune_rate * fan_in)`` smallest-|w| active
    weights, regrow the same number of inactive weights with the largest
    |momentum|.  The fixed fan-in F is preserved exactly (the paper's
    modification: no cross-layer redistribution).
    """
    n_prune = int(np.ceil(prune_rate * fan_in))
    n_prune = min(n_prune, fan_in)
    keep = fan_in - n_prune
    big = jnp.asarray(np.finfo(np.float32).max, weights.dtype)
    # Keep the (fan_in - n_prune) largest-|w| active weights ...
    active_score = jnp.where(mask > 0, jnp.abs(weights), -big)
    kept = _per_neuron_topk_mask(active_score, keep)
    # ... regrow n_prune inactive weights by |momentum|.
    inactive_score = jnp.where(kept > 0, -big, jnp.abs(momentum))
    regrown = _per_neuron_topk_mask(inactive_score, n_prune)
    return jnp.clip(kept + regrown, 0.0, 1.0)


def momentum_ema(momentum: jax.Array, grad: jax.Array,
                 alpha: float = 0.9) -> jax.Array:
    """Exponentially smoothed gradient M^{t+1} = a M^t + (1-a) dE/dW (§3.1.1)."""
    return alpha * momentum + (1.0 - alpha) * grad


def mean_momentum_contributions(momenta: list[jax.Array],
                                masks: list[jax.Array]) -> jax.Array:
    """Normalized mean momentum per layer (tracked-for-parity, §3.1.1).

    The paper keeps computing this even though the fixed-fan-in modification
    gives it "no redistribution utility"; we do the same so the algorithm's
    variables stay observable.
    """
    means = jnp.stack([
        jnp.abs(m * (k > 0)).sum() / jnp.maximum((k > 0).sum(), 1)
        for m, k in zip(momenta, masks)
    ])
    return means / jnp.maximum(means.sum(), 1e-12)


# ---------------------------------------------------------------------------
# Erdős–Rényi layer-sparsity allocation (§3.3.1)
# ---------------------------------------------------------------------------

def erdos_renyi_sparsity(layer_dims: list[tuple[int, int]],
                         scale: float = 1.0) -> list[float]:
    """Per-layer sparsity ~ 1 - scale * (n_in + n_out) / (n_in * n_out).

    Larger layers get higher sparsity (fewer connections per weight), smaller
    layers lower — §3.3.1's balancing argument.
    """
    out = []
    for n_in, n_out in layer_dims:
        s = 1.0 - scale * (n_in + n_out) / (n_in * n_out)
        out.append(float(np.clip(s, 0.0, 1.0)))
    return out


def fan_in_from_sparsity(in_features: int, sparsity: float,
                         minimum: int = 1) -> int:
    """Convert a layer sparsity to the per-neuron fan-in it implies."""
    return max(minimum, int(round(in_features * (1.0 - sparsity))))
