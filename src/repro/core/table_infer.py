"""Truth-table functional verification (paper §4.2 'use_table' forward).

Runs the network *through the generated tables*: pack each neuron's selected
input codes into a table index, gather the output code.  Must match the
quantized float forward bit-exactly — that is the verification contract, and
it is also precisely what the Pallas ``lut_lookup`` kernel executes on TPU
(this module doubles as its reference semantics at the network level).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.truth_table import LayerTruthTable


def pack_codes(codes: jax.Array, indices: jax.Array, bw_in: int) -> jax.Array:
    """(batch, in_features) codes + (O, fi) indices -> (batch, O) table ids.

    Element k of a neuron's fan-in list lands at bits [bw_in*k, bw_in*(k+1)).
    """
    gathered = codes[:, indices]                       # (batch, O, fi)
    shifts = bw_in * jnp.arange(indices.shape[1], dtype=jnp.int32)
    return jnp.sum(gathered << shifts[None, None, :], axis=-1)


def layer_table_forward(tt: LayerTruthTable, codes: jax.Array) -> jax.Array:
    """One sparse layer via its truth table: (batch, I) -> (batch, O) codes."""
    table = jnp.asarray(tt.table)                      # (O, E)
    idx = jnp.asarray(tt.indices)
    entry = pack_codes(codes, idx, tt.bw_in)           # (batch, O)
    # Per-neuron gather: out[b, o] = table[o, entry[b, o]].
    return jnp.take_along_axis(table[None, :, :],
                               entry[:, :, None], axis=2)[..., 0]


def network_table_forward(tables: list[LayerTruthTable],
                          in_codes: jax.Array,
                          fused: bool = False,
                          optimize_level: int | None = None) -> jax.Array:
    """Full sparse-stack forward on integer codes.

    ``fused=True`` routes through the whole-network Pallas engine
    (``kernels.ops.lut_network``, itself a thin memoized wrapper over
    ``repro.engine.compile_network``): one kernel launch for the entire
    stack, activation codes held in VMEM between layers, with automatic
    fallback to per-layer execution when the fused slabs would overflow
    VMEM.  Both paths are bit-exact with this function's plain-jnp
    semantics — that equality is the engine's verification contract, which
    is why the ``fused=False`` path deliberately stays the hand-rolled jnp
    loop below.  A throughput serving loop should hold a
    ``repro.engine.CompiledLUTNet`` directly (compile once, ``save``/
    ``load`` for deployment); these flags are the compatibility surface.

    ``optimize_level`` (0-3) first runs the truth-table compiler
    (``repro.compile.optimize``) over the stack — don't-care
    canonicalization, CSE, dead-input pruning, DCE, and at level 3
    cross-layer code re-encoding (per-feature bus narrowing, iterated to a
    fixpoint) — shrinking the tables while keeping the output
    bit-identical on every reachable input.  With ``fused=True`` the
    compile step happens inside ``lut_network``, which then executes the
    compiler's compact *mixed-width* lowering directly (per-(neuron,
    element) shift slabs, exact per-neuron table sizes) instead of the
    padded uniform tables — the VMEM slabs cost exactly what the compiler
    proved.
    """
    if fused:
        from repro.kernels.ops import lut_network
        return lut_network(in_codes,
                           [(tt.indices, tt.table, tt.bw_in)
                            for tt in tables], fused=True,
                           optimize_level=optimize_level)
    if optimize_level is not None:
        from repro.compile import optimize_tables
        tables = optimize_tables(list(tables), optimize_level,
                                 in_features=in_codes.shape[-1])
    c = in_codes
    for tt in tables:
        c = layer_table_forward(tt, c)
    return c


def table_memory_bytes(tables: list[LayerTruthTable]) -> int:
    """Table 5.1-style storage accounting (packed to minimal int width)."""
    from repro.core.lut_cost import code_width

    return sum(tt.out_features * tt.n_entries * code_width(tt.bw_out)
               for tt in tables)
