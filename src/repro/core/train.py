"""LogicNet training: the three sparsity regimes of the paper on one loop.

* 'apriori'   — fixed random expander masks (never change)
* 'iterative' — per-neuron magnitude pruning, cubic anneal to fan_in
* 'momentum'  — Algorithm 1 sparse-momentum prune/regrow

All three preserve the per-neuron fan-in invariant by construction (tested
in tests/test_sparsity.py); 'iterative' reaches it by the end of the decay
schedule.  BN state updates ride along the forward pass; masks are frozen
from the optimizer and applied to gradients.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import logicnet as LN
from repro.core import sparsity as SP
from repro.optim.adamw import AdamWCfg, adamw_update, init_opt_state


@dataclasses.dataclass
class TrainResult:
    model: list
    losses: list
    accuracy: float


def _mask_fn_for(model: list) -> Callable:
    masks = {i: layer.get("mask") for i, layer in enumerate(model)}

    def mask_fn(path: str, params):
        m = re.match(r"\[(\d+)\]\['w'\]$", path)
        if m is None:
            return None
        return masks.get(int(m.group(1)))

    return mask_fn


def train_logicnet(cfg: LN.LogicNetCfg, x_train: np.ndarray,
                   y_train: np.ndarray, x_test: np.ndarray,
                   y_test: np.ndarray, *, method: str = "apriori",
                   steps: int = 600, batch: int = 256, lr: float = 1e-2,
                   prune_every: int = 50, prune_rate: float = 0.3,
                   seed: int = 0) -> TrainResult:
    key = jax.random.PRNGKey(seed)
    model = LN.init(cfg, key, mask_seed=seed)
    layer_cfgs = cfg.layer_cfgs()

    if method == "iterative":
        # start dense; anneal per-neuron counts down to fan_in
        for i, layer in enumerate(model):
            if "mask" in layer:
                layer["mask"] = jnp.ones_like(layer["mask"])

    opt_cfg = AdamWCfg(lr=lr, weight_decay=0.0, clip_norm=1.0)
    params_list = [l["params"] for l in model]
    opt_state = init_opt_state(params_list)

    xt = jnp.asarray(x_train)
    yt = jnp.asarray(y_train)
    n = xt.shape[0]

    def assemble(params_list, model):
        return [dict(layer, params=p)
                for p, layer in zip(params_list, model)]

    @jax.jit
    def train_step(params_list, masks, bn_states, opt_state, xb, yb):
        def loss(params_list):
            mdl = [
                {"params": p, **({"mask": m} if m is not None else {}),
                 "bn_state": s}
                for p, m, s in zip(params_list, masks, bn_states)]
            nll, new_mdl = LN.loss_fn(cfg, mdl, xb, yb, train=True)
            return nll, [l["bn_state"] for l in new_mdl]

        (nll, new_bn), grads = jax.value_and_grad(loss, has_aux=True)(
            params_list)

        def mask_fn(path, params):
            m = re.match(r"\[(\d+)\]\['w'\]$", path)
            if m is None:
                return None
            return masks[int(m.group(1))]

        new_params, new_opt = adamw_update(opt_cfg, params_list, grads,
                                           opt_state, mask_fn=mask_fn)
        return new_params, new_bn, new_opt, nll

    masks = [l.get("mask") for l in model]
    bn_states = [l.get("bn_state") for l in model]
    losses = []
    rng = np.random.default_rng(seed)
    # Anneal sparsity over the first 60% of training; the remainder is
    # recovery at the final fan-in (pruning at the last step would leave
    # the network no time to adapt — the paper retrains after each prune).
    anneal_end = max(1, int(0.6 * steps))
    prune_every = min(prune_every, max(5, steps // 12))
    for step in range(steps):
        idx = rng.integers(0, n, size=batch)
        xb, yb = xt[idx], yt[idx]
        params_list, bn_states, opt_state, nll = train_step(
            params_list, masks, bn_states, opt_state, xb, yb)
        losses.append(float(nll))

        if method in ("iterative", "momentum") and step > 0 \
                and step % prune_every == 0 \
                and step <= anneal_end + prune_every:
            frac = min(1.0, step / anneal_end)
            for i, c in enumerate(layer_cfgs):
                if masks[i] is None:
                    continue
                fan_in = getattr(c, "fan_in", None)
                if fan_in is None:
                    continue
                w = params_list[i]["w"]
                if method == "iterative":
                    masks[i] = SP.iterative_prune_mask(w, masks[i], fan_in,
                                                       frac)
                else:
                    mom = opt_state["m"][i]["w"]
                    masks[i] = SP.sparse_momentum_step(
                        w * masks[i], mom, masks[i], fan_in, prune_rate)
                # keep pruned weights exactly zero
                params_list[i] = dict(params_list[i],
                                      w=params_list[i]["w"] * masks[i])

    # final hard projection for iterative (guarantee exact fan-in)
    if method == "iterative":
        for i, c in enumerate(layer_cfgs):
            if masks[i] is None or not hasattr(c, "fan_in"):
                continue
            masks[i] = SP.iterative_prune_mask(params_list[i]["w"],
                                               masks[i], c.fan_in, 1.0)
            params_list[i] = dict(params_list[i],
                                  w=params_list[i]["w"] * masks[i])

    model = [
        {**({"mask": m} if m is not None else {}),
         "params": p, "bn_state": s}
        for p, m, s in zip(params_list, masks, bn_states)]
    acc = float(LN.accuracy(cfg, model, jnp.asarray(x_test),
                            jnp.asarray(y_test)))
    return TrainResult(model=model, losses=losses, accuracy=acc)


def auc_roc_ovr(cfg: LN.LogicNetCfg, model: list, x: np.ndarray,
                y: np.ndarray) -> dict[int, float]:
    """One-vs-rest AUC-ROC per class (Table 6.2 metric), pure numpy."""
    logits, _ = LN.forward(cfg, model, jnp.asarray(x), train=False)
    scores = np.asarray(jax.nn.softmax(logits, axis=-1))
    aucs = {}
    for c in range(scores.shape[1]):
        pos = scores[y == c, c]
        neg = scores[y != c, c]
        if len(pos) == 0 or len(neg) == 0:
            aucs[c] = float("nan")
            continue
        # Mann-Whitney U
        order = np.argsort(np.concatenate([pos, neg]), kind="stable")
        ranks = np.empty_like(order, dtype=np.float64)
        ranks[order] = np.arange(1, len(order) + 1)
        r_pos = ranks[:len(pos)].sum()
        u = r_pos - len(pos) * (len(pos) + 1) / 2
        aucs[c] = float(u / (len(pos) * len(neg)))
    return aucs
