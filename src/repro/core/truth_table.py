"""Truth-table generation (paper §5.1) and the logic-minimization proxy.

A trained SparseLinear neuron j with fan-in ``fi`` synapses and a ``bi``-bit
input quantizer is a boolean function of ``fi*bi`` bits.  We enumerate all
``2^(fi*bi)`` input codes, run them through the *exact* neuron function
(dequantize -> dot(w) + b -> folded BN -> next layer's input quantizer) and
record the output codes.

Bit-packing convention (shared with table_infer, the Pallas lut_lookup
kernel, and the Verilog generator): input element k (k-th entry of the
neuron's sorted fan-in index list) occupies bits [bi*k, bi*(k+1)) of the
table index, LSB first.  A layer's flattened bus packs feature f's code at
bits [bi*f, bi*(f+1)).

Per-neuron generation is chunked over table entries so 20+-bit fan-ins
stream through without materializing (entries x neurons) floats at once —
the "on the go calculation ... for each neuron" the paper calls for.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import layers as L
from repro.core.quantize import QuantizerCfg, codes, dequantize_code
from repro.core.sparsity import mask_to_indices

MAX_FAN_IN_BITS = 24  # enumeration gate; exponential blow-up is fundamental


@dataclasses.dataclass
class LayerTruthTable:
    """Truth tables for one sparse layer.

    table:   (out_features, 2^(fan_in*bw_in)) int32 output codes
    indices: (out_features, fan_in) int32 input feature indices (sorted)
    bw_in:   input quantizer bits (per element)
    bw_out:  output quantizer bits
    """

    table: np.ndarray
    indices: np.ndarray
    bw_in: int
    bw_out: int

    @property
    def out_features(self) -> int:
        return self.table.shape[0]

    @property
    def fan_in(self) -> int:
        return self.indices.shape[1]

    @property
    def n_entries(self) -> int:
        return self.table.shape[1]


@dataclasses.dataclass(frozen=True)
class MixedLayerTables:
    """Compact mixed-width truth tables for one sparse layer.

    The exact-width sibling of ``LayerTruthTable``: where the uniform form
    pads every fan-in element to a common ``bw_in`` (so the kernels can use
    one ``bw_in * k`` shift for the whole layer), this form keeps each
    neuron's table dense over the *actual* per-element code widths the
    compiler proved (``repro.compile``'s dead-input pruning and level-3
    re-encoding).  Element k of neuron j contributes
    ``(code & (2^elem_widths[j,k] - 1)) << shifts[j,k]`` to its table
    entry, and the table holds exactly ``2^entry_bits[j]`` codes — no
    padding to the widest feature or to a per-layer entry count.

    indices:     (out_features, fan_in_max) int32 input feature indices;
                 neurons below ``fan_in_max`` repeat their first index
                 (the padded elements carry ``elem_widths == 0`` so they
                 contribute nothing to the packed entry).
    shifts:      (out_features, fan_in_max) int32 LSB-first bit offsets of
                 each element inside the neuron's packed table entry.
    elem_widths: (out_features, fan_in_max) int32 per-element code widths
                 (0 marks a padded element).
    entry_bits:  (out_features,) int32 — ``sum_k elem_widths[j, k]``;
                 neuron j's table has ``2^entry_bits[j]`` entries.
    tables:      per-neuron ``(2^entry_bits[j],)`` int32 output codes.

    Produced by ``repro.compile.ir.CNet.to_mixed_tables``; consumed by
    ``repro.kernels.lut_network.build_mixed_network_slabs`` (the fused
    mixed-width Pallas path).
    """

    indices: np.ndarray
    shifts: np.ndarray
    elem_widths: np.ndarray
    entry_bits: np.ndarray
    tables: tuple[np.ndarray, ...]

    @property
    def out_features(self) -> int:
        return self.indices.shape[0]

    @property
    def fan_in_max(self) -> int:
        return self.indices.shape[1]

    @property
    def n_entries(self) -> int:
        """Total table entries across the layer (the exact slab rows)."""
        return int(sum(t.shape[0] for t in self.tables))


def _entry_digits(entry_ids: jax.Array, fan_in: int, bw_in: int) -> jax.Array:
    """(E,) table indices -> (E, fan_in) per-element codes (LSB-first)."""
    shifts = bw_in * jnp.arange(fan_in, dtype=entry_ids.dtype)
    mask = (1 << bw_in) - 1
    return (entry_ids[:, None] >> shifts[None, :]) & mask


def generate_sparse_linear_table(cfg: L.SparseLinearCfg, layer: dict,
                                 out_quant: QuantizerCfg,
                                 chunk: int = 1 << 14) -> LayerTruthTable:
    """Enumerate truth tables for every neuron of a SparseLinear layer.

    ``out_quant`` is the *next* module's input quantizer (or the network's
    final output quantizer) — §4.2: "it expects us to give the next module
    in the forward pass".
    """
    fi_bits = cfg.fan_in_bits
    if fi_bits > MAX_FAN_IN_BITS:
        raise ValueError(
            f"fan-in {fi_bits} bits exceeds enumeration gate "
            f"({MAX_FAN_IN_BITS}); 2^{fi_bits} entries is infeasible — the "
            "same wall the paper hits on FPGAs")
    idx = mask_to_indices(layer["mask"])                    # (O, fi)
    w = np.asarray(layer["params"]["w"] * layer["mask"])    # (I, O)
    b = np.asarray(layer["params"]["b"])                    # (O,)
    wj = np.take_along_axis(w, idx.T, axis=0).T             # (O, fi)
    if cfg.use_bn:
        scale, bias = L.bn_eval_fn(layer["params"]["bn"], layer["bn_state"])
        scale, bias = np.asarray(scale), np.asarray(bias)
    else:
        scale, bias = np.ones_like(b), np.zeros_like(b)

    n_entries = 2 ** fi_bits
    in_q = cfg.in_quant
    wj_j, b_j = jnp.asarray(wj), jnp.asarray(b)
    scale_j, bias_j = jnp.asarray(scale), jnp.asarray(bias)

    @jax.jit
    def eval_chunk(entry_ids: jax.Array) -> jax.Array:
        digits = _entry_digits(entry_ids, cfg.fan_in, in_q.bit_width)
        vals = dequantize_code(in_q, digits)                # (E, fi)
        pre = vals @ wj_j.T + b_j                           # (E, O)
        y = pre * scale_j + bias_j
        return codes(out_quant, y).T                        # (O, E)

    out = np.empty((cfg.out_features, n_entries), dtype=np.int32)
    for start in range(0, n_entries, chunk):
        stop = min(start + chunk, n_entries)
        ids = jnp.arange(start, stop, dtype=jnp.int32)
        out[:, start:stop] = np.asarray(eval_chunk(ids))
    return LayerTruthTable(out, idx, in_q.bit_width, out_quant.bit_width)


def table_as_listing(tt: LayerTruthTable, neuron: int) -> list[list[int]]:
    """Listing 5.1 structure: [[input codes...], [output codes...]]."""
    return [list(range(tt.n_entries)), tt.table[neuron].tolist()]


# ---------------------------------------------------------------------------
# Logic-minimization proxy (§5.3 / Table 5.2 stand-in; see DESIGN.md §2)
# ---------------------------------------------------------------------------

def minimized_lut_estimate(tt: LayerTruthTable) -> int:
    """Cheap stand-in for Vivado synthesis results (Table 5.2).

    Three reductions Vivado reliably finds that we can count exactly:
      * constant output bits cost 0 LUTs;
      * duplicate neurons (identical table + identical fan-in wires) are
        synthesized once;
      * per output bit, if the function ignores some inputs (the bit is
        independent of an input element), the effective fan-in shrinks.
    Returns an estimated 6-LUT count for the layer (<= analytical cost).
    """
    from repro.core.lut_cost import lut_cost_per_bit

    seen: dict[bytes, int] = {}
    total = 0
    for j in range(tt.out_features):
        key = tt.table[j].tobytes() + tt.indices[j].tobytes()
        if key in seen:
            continue
        seen[key] = j
        for bit in range(tt.bw_out):
            col = (tt.table[j] >> bit) & 1
            if col.min() == col.max():
                continue  # constant bit: free
            eff_bits = _effective_fan_in_bits(col, tt.fan_in, tt.bw_in)
            total += lut_cost_per_bit(max(eff_bits, 1))
    return total


def _effective_fan_in_bits(col: np.ndarray, fan_in: int, bw_in: int) -> int:
    """Count input *bits* this single-output-bit function depends on."""
    n_bits = fan_in * bw_in
    entries = np.arange(col.shape[0])
    used = 0
    for bit in range(n_bits):
        lo = entries[(entries >> bit) & 1 == 0]
        if not np.array_equal(col[lo], col[lo | (1 << bit)]):
            used += 1
    return used
