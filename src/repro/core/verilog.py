"""Verilog code generation (paper §5.2, Listings 5.2–5.6).

Emits the exact module structure of the thesis: a ``LogicNetModule`` top,
one ``LUTLayer{l}`` per layer wiring per-neuron input selections, and one
``LUT_L{l}_N{n}`` case-statement module per neuron.  No LUT primitives are
instantiated — "we define the entire truth table and leave it up to the
logic synthesis tool" (§5.2).  Optional pipeline registers between layers
(Fig. 5.1) for the fully-pipelined variant (§5.4).

``evaluate_verilog`` is a mini-interpreter for the restricted subset we
emit, used by the tests to prove generated-RTL == truth-table forward.
"""

from __future__ import annotations

import re

import numpy as np

from repro.core.netlist import Netlist


def _concat_expr(bus: str, bits: list[int]) -> str:
    """Verilog concatenation {MSB, ..., LSB} for LSB-first bit positions."""
    return "{" + ", ".join(f"{bus}[{b}]" for b in reversed(bits)) + "}"


def neuron_module(name: str, n_in_bits: int, out_bits: int,
                  table: np.ndarray,
                  reachable: np.ndarray | None = None) -> str:
    """One case-statement LUT module, always with an explicit ``default:``.

    Without the default arm an incomplete case would make the synthesized
    combinational block diverge from ``evaluate_verilog`` (and infer a
    latch) on any uncovered input.  When a ``reachable`` mask is given
    (compile-pipeline output), unreachable entries are don't-cares: they are
    folded into the default arm, whose value is the most common *reachable*
    output code — and reachable arms equal to it are omitted too, since the
    default reproduces them exactly.
    """
    lines = [f"module {name} ( input [{n_in_bits - 1}:0] M0, "
             f"output [{out_bits - 1}:0] M1 );",
             f"  reg [{out_bits - 1}:0] M1;",
             "  always @ (M0) begin",
             "    case (M0)"]
    if reachable is None:
        default = 0
        emit = np.ones(len(table), dtype=bool)
    else:
        vals, counts = np.unique(np.asarray(table)[reachable],
                                 return_counts=True)
        default = int(vals[np.argmax(counts)])
        emit = reachable & (np.asarray(table) != default)
    for entry, code in enumerate(table):
        if emit[entry]:
            lines.append(f"      {n_in_bits}'d{entry}: "
                         f"M1 = {out_bits}'d{int(code)};")
    lines.append(f"      default: M1 = {out_bits}'d{default};")
    lines += ["    endcase", "  end", "endmodule"]
    return "\n".join(lines)


def neuron_module_sop(name: str, n_in_bits: int, out_bits: int,
                      cover) -> str:
    """One assign-network LUT module from a minimized SOP cover.

    Instead of the full case statement, each output bit is an OR of
    parenthesized AND terms over ``M0`` literals — the two-level form
    ``repro.synth`` minimized, handed to the downstream synthesis tool as
    explicit structure rather than a table.  Constant bits become
    ``1'b0`` / ``1'b1``.  On don't-care (unreachable) inputs the module
    may differ from its case-statement sibling; on reachable inputs they
    are bit-identical (the minimizer's exactness contract).
    """
    lines = [f"module {name} ( input [{n_in_bits - 1}:0] M0, "
             f"output [{out_bits - 1}:0] M1 );"]
    for b, cubes in enumerate(cover.bits):
        terms: list[str] | None = []
        for c in cubes:
            lits = c.literals()
            if not lits:            # tautology cube: the bit is constant 1
                terms = None
                break
            terms.append("(" + " & ".join(
                ("" if positive else "~") + f"M0[{p}]"
                for p, positive in lits) + ")")
        if terms is None:
            rhs = "1'b1"
        elif not terms:
            rhs = "1'b0"
        else:
            rhs = " | ".join(terms)
        lines.append(f"  assign M1[{b}] = {rhs};")
    lines.append("endmodule")
    return "\n".join(lines)


def layer_module(netlist: Netlist, layer: int) -> str:
    neurons = netlist.layers[layer]
    in_bits = (netlist.in_bits if layer == 0 else
               sum(n.out_bits for n in netlist.layers[layer - 1]))
    out_bits = sum(n.out_bits for n in neurons)
    lines = [f"module LUTLayer{layer} (input [{in_bits - 1}:0] M0, "
             f"output [{out_bits - 1}:0] M1);"]
    pos = 0
    for n in neurons:
        wire = f"inpWire{layer}_{n.neuron}"
        width = len(n.input_bits)
        lines.append(f"  wire [{width - 1}:0] {wire} = "
                     f"{_concat_expr('M0', n.input_bits)};")
        hi, lo = pos + n.out_bits - 1, pos
        lines.append(f"  LUT_L{layer}_N{n.neuron} "
                     f"LUT_L{layer}_N{n.neuron}_inst "
                     f"(.M0({wire}), .M1(M1[{hi}:{lo}]));")
        pos += n.out_bits
    lines.append("endmodule")
    return "\n".join(lines)


def top_module(netlist: Netlist, pipeline: bool = False) -> str:
    n_layers = len(netlist.layers)
    widths = [netlist.in_bits] + [sum(n.out_bits for n in layer)
                                  for layer in netlist.layers]
    lines = [f"module LogicNetModule (input [{widths[0] - 1}:0] M0, "
             f"output [{widths[-1] - 1}:0] M{n_layers}"
             + (", input clk" if pipeline else "") + ");"]
    for l in range(1, n_layers):
        kind = "reg" if pipeline else "wire"
        lines.append(f"  {kind} [{widths[l] - 1}:0] M{l};")
    if pipeline:
        lines.append(f"  reg [{widths[0] - 1}:0] M0_r;")
        for l in range(1, n_layers):
            lines.append(f"  wire [{widths[l] - 1}:0] M{l}_w;")
        lines.append("  always @ (posedge clk) begin")
        lines.append("    M0_r <= M0;")
        for l in range(1, n_layers):
            lines.append(f"    M{l} <= M{l}_w;")
        lines.append("  end")
    for l in range(n_layers):
        src = ("M0_r" if pipeline and l == 0 else f"M{l}")
        dst = (f"M{l + 1}_w" if pipeline and l + 1 < n_layers
               else f"M{l + 1}")
        lines.append(f"  LUTLayer{l} LUTLayer{l}_inst "
                     f"(.M0({src}), .M1({dst}));")
    lines.append("endmodule")
    return "\n".join(lines)


def generate_verilog(netlist: Netlist, pipeline: bool = False,
                     sop: bool = False) -> dict[str, str]:
    """All .v sources, keyed by file name (Listing 5.2–5.6 layout).

    ``sop=True`` emits assign-network modules from the minimized covers
    that ``compile.optimize(..., synth=True)`` attached to the netlist
    (``NeuronHBB.sop``); neurons without a cover (synthesis budget
    fallback, or an unsynthesized netlist) keep the case-statement form.
    Layer/top modules are identical either way.
    """
    files = {"LogicNetModule.v": top_module(netlist, pipeline)}
    for l, layer in enumerate(netlist.layers):
        files[f"LUTLayer{l}.v"] = layer_module(netlist, l)
        for n in layer:
            name = f"LUT_L{l}_N{n.neuron}"
            if sop and n.sop is not None:
                files[f"{name}.v"] = neuron_module_sop(
                    name, len(n.input_bits), n.out_bits, n.sop)
            else:
                files[f"{name}.v"] = neuron_module(
                    name, len(n.input_bits), n.out_bits, n.table,
                    n.reachable)
    return files


# ---------------------------------------------------------------------------
# Mini evaluator for the emitted subset (test oracle for RTL == tables)
# ---------------------------------------------------------------------------

_CASE_RE = re.compile(r"(\d+)'d(\d+):\s*M1\s*=\s*(\d+)'d(\d+);")
_DEFAULT_RE = re.compile(r"default:\s*M1\s*=\s*(\d+)'d(\d+);")
_ASSIGN_RE = re.compile(r"assign M1\[(\d+)\] = (.*);")
_LIT_RE = re.compile(r"(~?)M0\[(\d+)\]")
_WIDTH_RE = re.compile(r"input \[(\d+):0\] M0")
_WIRE_RE = re.compile(
    r"wire \[(\d+):0\] (inpWire\d+_\d+) = \{([^}]*)\};")
_INST_RE = re.compile(
    r"LUT_L(\d+)_N(\d+) LUT_L\d+_N\d+_inst "
    r"\(\.M0\((inpWire\d+_\d+)\), \.M1\(M1\[(\d+):(\d+)\]\)\);")


def _parse_tables(files: dict[str, str]) -> dict[str, np.ndarray]:
    tables = {}
    for fname, text in files.items():
        if not fname.startswith("LUT_L"):
            continue
        n_in_bits = int(_WIDTH_RE.search(text).group(1)) + 1
        if "assign M1[" in text:
            # SOP assign-network module: rebuild the full table by
            # evaluating every product term, so downstream evaluation is
            # identical to the case-statement path
            words = np.arange(1 << n_in_bits, dtype=np.int64)
            table = np.zeros(words.shape, dtype=np.int64)
            for m in _ASSIGN_RE.finditer(text):
                b, rhs = int(m.group(1)), m.group(2)
                if rhs == "1'b0":
                    continue
                if rhs == "1'b1":
                    table |= np.int64(1) << b
                    continue
                hit = np.zeros(words.shape, dtype=bool)
                for term in re.findall(r"\(([^()]*)\)", rhs):
                    mask = value = 0
                    for neg, pos in _LIT_RE.findall(term):
                        mask |= 1 << int(pos)
                        if not neg:
                            value |= 1 << int(pos)
                    hit |= (words & mask) == value
                table |= hit.astype(np.int64) << b
            tables[fname[:-2]] = table
            continue
        dm = _DEFAULT_RE.search(text)
        default = int(dm.group(2)) if dm else 0
        # every entry not listed as an explicit arm takes the default value
        # — exactly the case-statement semantics synthesis sees
        table = np.full(1 << n_in_bits, default, dtype=np.int64)
        for m in _CASE_RE.finditer(text):
            table[int(m.group(2))] = int(m.group(4))
        tables[fname[:-2]] = table
    return tables


def evaluate_verilog(files: dict[str, str], input_word: int,
                     n_layers: int) -> int:
    """Evaluate the generated combinational network on one input word."""
    tables = _parse_tables(files)
    bus = input_word
    for l in range(n_layers):
        text = files[f"LUTLayer{l}.v"]
        wires: dict[str, int] = {}
        for m in _WIRE_RE.finditer(text):
            name, sel = m.group(2), m.group(3)
            bits = [int(b) for b in re.findall(r"M0\[(\d+)\]", sel)]
            val = 0
            for i, b in enumerate(reversed(bits)):      # MSB-first concat
                val |= ((bus >> b) & 1) << i
            wires[name] = val
        out = 0
        for m in _INST_RE.finditer(text):
            mod = f"LUT_L{m.group(1)}_N{m.group(2)}"
            hi, lo = int(m.group(4)), int(m.group(5))
            out |= int(tables[mod][wires[m.group(3)]]) << lo
        bus = out
    return bus
