"""Deterministic synthetic data pipelines (offline container; DESIGN.md §6)."""

from repro.data.pipeline import (  # noqa: F401
    TokenStream, jet_substructure_data, mnist_like_data,
)
