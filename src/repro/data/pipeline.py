"""Data pipelines.

All generators are deterministic functions of (seed, step, host), so any
host of a 1000-node fleet reproduces its shard independently — restart /
elastic re-shard never replays or skips data (the per-host slice is
computed from ``process_index`` at call time).

* ``TokenStream``   — synthetic LM token batches (Zipfian unigram mixture
  with short-range structure so perplexity is learnable).
* ``jet_substructure_data`` — 16-feature 5-class mixture mirroring the
  FPGA4HEP task's shape/statistics (paper §6).
* ``mnist_like_data``      — procedurally rendered 28x28 digit-like
  classes (paper §7).
"""

from __future__ import annotations

import dataclasses

import numpy as np



@dataclasses.dataclass
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host: int = 0

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        self.local_batch = self.global_batch // self.n_hosts

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Per-host slice of the global batch at ``step``; deterministic."""
        rng = np.random.default_rng(
            (self.seed, step, self.host))
        # Zipf unigram base with a copy-back structure: token[t] often
        # repeats token[t-k] — gives the model something to learn.
        zipf = rng.zipf(1.3, size=(self.local_batch, self.seq_len + 1))
        toks = np.minimum(zipf, self.vocab - 1).astype(np.int32)
        k = 1 + (step % 7)
        copy = rng.random((self.local_batch, self.seq_len + 1)) < 0.5
        toks[:, k:][copy[:, k:]] = toks[:, :-k][copy[:, k:]]
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:].astype(np.int32)}


def jet_substructure_data(n: int, seed: int = 0
                          ) -> tuple[np.ndarray, np.ndarray]:
    """16 expert features -> 5 jet classes (q, g, W, Z, t stand-ins).

    Class-conditional Gaussians with shared covariance structure and
    nonlinear feature interactions; Bayes accuracy ~ high 80s%, like the
    real task's AUC regime.
    """
    rng = np.random.default_rng(seed)
    n_classes, d = 5, 16
    means = rng.normal(0, 1.2, size=(n_classes, d))
    mix = rng.normal(0, 0.3, size=(d, d))
    y = rng.integers(0, n_classes, size=n)
    x = means[y] + rng.normal(0, 1.0, size=(n, d)) @ mix
    # nonlinear touches: jet-mass-like quadratic feature
    x[:, 0] = x[:, 0] + 0.3 * x[:, 1] * x[:, 2]
    x[:, 3] = np.abs(x[:, 3])
    return x.astype(np.float32), y.astype(np.int32)


_DIGIT_SEGS = {  # 7-segment-ish encodings for digit rendering
    0: "abcdef", 1: "bc", 2: "abdeg", 3: "abcdg", 4: "bcfg",
    5: "acdfg", 6: "acdefg", 7: "abc", 8: "abcdefg", 9: "abcdfg",
}


def _render_digit(d: int, rng: np.random.Generator) -> np.ndarray:
    img = np.zeros((28, 28), np.float32)
    segs = _DIGIT_SEGS[d]
    ox, oy = rng.integers(2, 8), rng.integers(2, 8)
    w, h = rng.integers(10, 14), rng.integers(14, 18)
    t = 2
    def hline(y0, x0, ln):
        img[y0:y0 + t, x0:x0 + ln] = 1.0
    def vline(y0, x0, ln):
        img[y0:y0 + ln, x0:x0 + t] = 1.0
    if "a" in segs: hline(oy, ox, w)
    if "g" in segs: hline(oy + h // 2, ox, w)
    if "d" in segs: hline(oy + h, ox, w)
    if "f" in segs: vline(oy, ox, h // 2)
    if "b" in segs: vline(oy, ox + w - t, h // 2)
    if "e" in segs: vline(oy + h // 2, ox, h // 2 + t)
    if "c" in segs: vline(oy + h // 2, ox + w - t, h // 2 + t)
    img += rng.normal(0, 0.15, img.shape).astype(np.float32)
    return np.clip(img, 0, 1)


def mnist_like_data(n: int, seed: int = 0
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Procedural 28x28 10-class digit images (N, 28, 28, 1)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, size=n)
    x = np.stack([_render_digit(int(d), rng) for d in y])
    return x[..., None].astype(np.float32), y.astype(np.int32)
