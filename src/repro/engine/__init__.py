"""Compile-once serving engine for LUT networks.

``compile_network(...) -> CompiledLUTNet`` is the first-class deployment
API: one compiler run, one slab build, one jitted batch-shape-robust
forward — then ``__call__`` serves, ``save``/``load`` round-trip the
artifact as an ``.npz``, and the legacy ``fused=`` / ``optimize_level=``
flags on ``ops.lut_network`` / ``table_infer.network_table_forward`` /
``logicnet.verify_tables`` / ``logicnet.sparse_head_forward`` are thin
compatibility wrappers over this one code path (memoized via
``cached_compile``).

``compile_network(..., autotune=True)`` swaps the static layout heuristic
for measurement: every eligible plan variant is timed on the actual
backend and the winning :class:`ExecutionPlan` (with its timing table)
persists in the artifact, so deployment replays it with zero search
(``repro.engine.autotune``).
"""

from repro.engine.autotune import ExecutionPlan, autotune_network
from repro.engine.engine import (ARTIFACT_KIND, FORMAT_VERSION,
                                 CompiledLUTNet, cache_clear, cache_size,
                                 cached_compile, compile_network,
                                 compile_runs, load)

__all__ = ["ARTIFACT_KIND", "FORMAT_VERSION", "CompiledLUTNet",
           "ExecutionPlan", "autotune_network", "cache_clear", "cache_size",
           "cached_compile", "compile_network", "compile_runs", "load"]
