"""Compile-time variant autotuner: measure once, persist, replay.

The heuristic ladder in ``compile_network`` picks an execution strategy
from a static byte estimate; LogicNets and *Rethinking Arithmetic* both
observe the winning implementation of a boolean-function network is
workload- and backend-dependent.  This module closes that gap the way the
ROADMAP's layout-autotuner item asked for: enumerate the
:class:`~repro.kernels.plan.PlanVariant` space (layout x block_b x pack),
build each eligible variant's slabs through the existing builders, time
its *jitted* forward on the actual backend over a representative batch
(warmup + median-of-k), and record the winner in an
:class:`ExecutionPlan` that rides in the artifact — deployment replays
the measured choice with zero search and zero extra traces.

The timing protocol is deliberately boring: a seeded synthetic batch (or
a caller-supplied one) shaped like serving traffic, ``AUTOTUNE_WARMUP``
untimed calls to absorb the jit trace, then ``AUTOTUNE_REPS`` timed
passes of ``AUTOTUNE_ITERS`` calls each, keeping the median.  Timings go
through the same process-wide jitted forwards serving uses
(``engine._FORWARDS``), so what is measured is what will run.

Search cost and coverage are observable: ``engine_autotune_seconds``
(histogram, one observation per search) and
``engine_autotune_variants_total`` (counter, labeled by layout).
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.lut_lookup import DEFAULT_BLOCK_B
from repro.kernels.lut_network import (build_mixed_network_slabs,
                                       build_network_slabs)
from repro.kernels.plan import (DEFAULT_BLOCK_BS, FUSED_VMEM_BUDGET_BYTES,
                                FusedPlan, PlanVariant, default_variant,
                                enumerate_variants)

# warmup absorbs the jit trace; each rep times ITERS back-to-back calls
# and the median rep survives (robust to a stray scheduler hiccup without
# needing many samples — interpret-mode calls are milliseconds each)
AUTOTUNE_WARMUP = 1
AUTOTUNE_ITERS = 2
AUTOTUNE_REPS = 3

_M_AUTOTUNE_SECONDS = obs.registry().histogram(
    "engine_autotune_seconds",
    "wall-clock seconds per compile-time variant search")
_M_AUTOTUNE_VARIANTS = obs.registry().counter(
    "engine_autotune_variants_total",
    "plan variants built and timed by the autotuner", labels=("layout",))


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """The execution strategy a ``CompiledLUTNet`` runs — and why.

    Supersedes the bare ``layout: str`` + ``FusedPlan`` pair: ``variant``
    pins layout, ``block_b`` and pack together with the byte costing, and
    the compat properties below keep every ``net.plan.reason``-style
    caller working unchanged.

    * ``source`` — ``"heuristic"`` (the static ladder chose), ``"autotune"``
      (measured), or ``"synthesized"`` (reconstructed while loading a
      pre-autotune format-1 artifact);
    * ``timings_us`` — variant key -> median microseconds per forward on
      the autotune batch (empty unless autotuned).  Persisted in the
      artifact so deployment can audit the search without re-running it;
    * ``batch`` — rows in the batch those timings were taken over;
    * ``default_key`` — the heuristic default's variant key, always
      present in ``timings_us`` after a search (the bench's collapse-only
      gate compares the winner against it).
    """

    variant: PlanVariant
    source: str = "heuristic"
    timings_us: dict = dataclasses.field(default_factory=dict)
    batch: int = 0
    default_key: str | None = None

    # -- compat shim: the old FusedPlan/layout surface ----------------------

    @property
    def layout(self) -> str:
        return self.variant.layout

    @property
    def block_b(self) -> int:
        return self.variant.block_b

    @property
    def pack(self) -> bool:
        return self.variant.pack

    @property
    def fused(self) -> bool:
        return self.variant.cost.fused

    @property
    def reason(self) -> str:
        return self.variant.cost.reason

    @property
    def slab_bytes(self) -> int:
        return self.variant.cost.slab_bytes

    @property
    def vmem_budget_bytes(self) -> int:
        return self.variant.cost.vmem_budget_bytes

    @property
    def f32_exact(self) -> bool:
        return self.variant.cost.f32_exact

    # -- (de)serialization --------------------------------------------------

    def as_dict(self) -> dict:
        return {"variant": self.variant.as_dict(), "source": self.source,
                "timings_us": dict(self.timings_us), "batch": self.batch,
                "default_key": self.default_key}

    @classmethod
    def from_dict(cls, d: dict) -> "ExecutionPlan":
        return cls(variant=PlanVariant.from_dict(d["variant"]),
                   source=str(d["source"]),
                   timings_us=dict(d.get("timings_us") or {}),
                   batch=int(d.get("batch") or 0),
                   default_key=d.get("default_key"))

    @classmethod
    def from_fused(cls, cost: FusedPlan, layout: str, block_b: int, *,
                   source: str = "heuristic") -> "ExecutionPlan":
        """Wrap a bare heuristic costing (or a format-1 artifact's
        deserialized ``FusedPlan``) into a plan with no timing table."""
        pack = cost.pack if layout in ("mixed", "uniform") else False
        return cls(variant=PlanVariant(layout, int(block_b), pack, cost),
                   source=source)


def _synthetic_codes(in_features: int, bw: int, batch: int,
                     seed: int = 0) -> np.ndarray:
    """Seeded stand-in for serving traffic: uniform codes over the first
    layer's input alphabet (every LUT entry reachable)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << bw, (batch, in_features), dtype=np.int32)


def _time_forward(fn, *, warmup: int, iters: int, reps: int) -> float:
    """Median microseconds per call of the zero-arg ``fn`` (device-synced)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = None
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / iters * 1e6)
    samples.sort()
    return samples[len(samples) // 2]


def autotune_network(uniform_triples, mixed_tables=None, *,
                     in_features: int,
                     block_b: int = DEFAULT_BLOCK_B,
                     vmem_budget_bytes: int = FUSED_VMEM_BUDGET_BYTES,
                     codes=None, block_bs=None, seed: int = 0,
                     warmup: int = AUTOTUNE_WARMUP,
                     iters: int = AUTOTUNE_ITERS,
                     reps: int = AUTOTUNE_REPS):
    """Time every eligible variant and return the measured winner.

    ``uniform_triples`` is the ``(indices, table, bw_in)`` triple list,
    ``mixed_tables`` the compiler's ``MixedLayerTables`` lowering when one
    exists.  ``codes`` supplies the representative batch (None: a seeded
    synthetic batch of ``max(block_bs)`` rows).  ``block_b`` is the
    caller's requested tile — it joins the sweep so the heuristic default
    variant is always among the timed candidates.

    Returns ``(plan, built)``: the :class:`ExecutionPlan` (``source=
    "autotune"``, full timing table) and the winner's already-built
    payload — ``NetworkSlabs`` / ``MixedNetworkSlabs`` for fused layouts,
    the jnp ``(idx, table, bw)`` tuple for per-layer — so
    ``compile_network`` never builds the winning slabs twice.
    """
    from repro.engine import engine as _eng   # lazy: engine imports us

    t_start = time.perf_counter()
    uniform_triples = list(uniform_triples)
    sweep = tuple(sorted({int(b) for b in (block_bs or DEFAULT_BLOCK_BS)}
                         | {int(block_b)}))
    variants = enumerate_variants(uniform_triples, mixed_tables,
                                  block_bs=sweep,
                                  vmem_budget_bytes=vmem_budget_bytes)
    default = default_variant(uniform_triples, mixed_tables,
                              block_b=block_b,
                              vmem_budget_bytes=vmem_budget_bytes)

    if codes is None:
        bw = int(uniform_triples[0][2])
        codes = _synthetic_codes(in_features, bw, max(sweep), seed)
    codes = jnp.asarray(np.asarray(codes, dtype=np.int32))
    batch = int(codes.shape[0])
    interp = not _eng._on_tpu()

    # one build per (layout, pack) — slabs are block_b-independent
    built: dict[tuple[str, bool], object] = {}

    def payload(v: PlanVariant):
        k = (v.layout, v.pack)
        if k not in built:
            if v.layout == "mixed":
                built[k] = build_mixed_network_slabs(mixed_tables,
                                                     pack=v.pack)
            elif v.layout == "uniform":
                built[k] = build_network_slabs(uniform_triples, pack=v.pack)
            else:
                built[k] = tuple(
                    (jnp.asarray(np.asarray(i, dtype=np.int32)),
                     jnp.asarray(np.asarray(t, dtype=np.int32)), int(b))
                    for i, t, b in uniform_triples)
        return built[k]

    def forward(v: PlanVariant, p):
        padded = -(-batch // v.block_b) * v.block_b
        x = codes
        if padded != batch:
            x = jnp.concatenate(
                [x, jnp.zeros((padded - batch, in_features), x.dtype)],
                axis=0)
        if v.layout == "mixed":
            return lambda c=x: _eng._mixed_forward(
                c, p.idx_slab, p.shift_slab, p.width_slab, p.table_slab,
                meta=p.meta, out_perm=p.out_perm, packed=p.packed,
                block_b=v.block_b, interpret=interp)
        if v.layout == "uniform":
            return lambda c=x: _eng._uniform_forward(
                c, p.idx_slab, p.table_slab, meta=p.meta, packed=p.packed,
                block_b=v.block_b, interpret=interp)
        idx_tabs = tuple((i, t) for i, t, _ in p)
        bws = tuple(b for _, _, b in p)
        return lambda c=x: _eng._per_layer_forward(
            c, idx_tabs, bws=bws, block_b=v.block_b, interpret=interp)

    timings: dict[str, float] = {}
    by_key: dict[str, PlanVariant] = {}
    for v in variants:
        fn = forward(v, payload(v))
        timings[v.key] = _time_forward(fn, warmup=warmup, iters=iters,
                                       reps=reps)
        by_key[v.key] = v
        _M_AUTOTUNE_VARIANTS.labels(layout=v.layout).inc()

    winner = by_key[min(timings, key=timings.get)]
    plan = ExecutionPlan(variant=winner, source="autotune",
                         timings_us=timings, batch=batch,
                         default_key=default.key)
    _M_AUTOTUNE_SECONDS.observe(time.perf_counter() - t_start)
    return plan, payload(winner)
