"""Compile-once serving artifact: ``compile_network(...) -> CompiledLUTNet``.

The paper's whole point is extreme-throughput inference — a LogicNet is a
pipeline of LUTs serving one input per clock — yet the legacy keyword-flag
API (``fused=`` / ``optimize_level=`` on four different entry points)
re-ran the truth-table compiler, rebuilt the VMEM slabs host-side and
re-traced the Pallas kernel on *every* call.  This module is the
ahead-of-time half of the deployment story:

    from repro import engine
    net = engine.compile_network(tables, optimize_level=3,
                                 in_features=cfg.in_features)
    out = net(codes)              # jitted, zero re-trace, zero re-compile
    net.plan                      # the ExecutionPlan that chose the layout
    net.stats                     # CompileStats from the one optimize run
    net.vmem_breakdown()          # per-slab VMEM bytes
    net.save("model_a.npz")       # deployment skips the compiler entirely
    net2 = engine.load("model_a.npz")   # exact same slabs, bit-exact

``compile_network`` runs ``repro.compile.optimize`` ONCE, costs both slab
layouts through ``kernels.ops.fused_plan``, builds the chosen slabs
(mixed-width, uniform, or the per-layer fallback) ONCE, and serves every
subsequent call through a shared jitted forward.

Batch-shape robustness: the forward functions are jitted with *static*
``block_b`` and every call pads its batch up to the next ``block_b``
multiple (sliced back afterwards), so a serving loop with ragged batch
sizes hits one trace per ``block_b`` bucket instead of one per distinct
batch size.  The jitted forwards take the slab arrays as *arguments*
(static metadata only is closed over), so two artifacts with the same
shapes — e.g. a live artifact and its ``save``/``load`` round-trip —
share a single trace.

Serialization rides the checkpoint manifest machinery
(``checkpoint.ckpt.save_arrays`` / ``load_arrays``): one ``.npz`` holding
the slab arrays plus a JSON metadata record (layout, static per-layer
shape metadata, the ExecutionPlan — variant, source and autotune timing
table — and the CompileStats of the build).
"""

from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.checkpoint.ckpt import load_arrays, save_arrays
from repro.compile.pipeline import CompileStats, OptimizeResult
from repro.engine.autotune import ExecutionPlan, autotune_network
from repro.kernels import ref
from repro.kernels.lut_lookup import DEFAULT_BLOCK_B, lut_lookup_pallas
from repro.kernels.lut_network import (LayerMeta, MixedGroupMeta,
                                       MixedLayerMeta, MixedNetworkSlabs,
                                       NetworkSlabs,
                                       build_mixed_network_slabs,
                                       build_network_slabs,
                                       lut_network_mixed_pallas,
                                       lut_network_pallas)
from repro.kernels.plan import (FUSED_VMEM_BUDGET_BYTES, FusedPlan,
                                fused_plan)

# format 2 (ExecutionPlan refactor): meta["plan"] is the full ExecutionPlan
# record (variant + autotune timing table); format-1 artifacts carried the
# bare FusedPlan and load() synthesizes their default plan.
# format 3 (slab row-dedup): mixed layer_meta groups may carry a third
# element — the per-neuron flat table offsets of shared rows — plus
# meta["dedup_entries_saved"]; dup-free artifacts still serialize the
# 2-element form, so they remain readable by format-2 builds
FORMAT_VERSION = 3
ARTIFACT_KIND = "repro.engine.CompiledLUTNet"

# process-wide count of optimize() runs issued by this module; serving
# tests and the bench's `serving` section assert it stays flat after
# warmup ("zero compiler re-runs")
_compile_runs = 0

# registry-backed build metrics (docs/observability.md): what the engine
# decided (layout), what it cost (compiler runs, slab build time), and
# how the legacy-flag memo behaves (hits/misses)
_M_COMPILER_RUNS = obs.registry().counter(
    "engine_compiler_runs_total",
    "truth-table compiler invocations issued by the engine")
_M_BUILDS = obs.registry().counter(
    "engine_builds_total", "CompiledLUTNet builds by chosen layout",
    labels=("layout",))
_M_SLAB_BUILD = obs.registry().histogram(
    "engine_slab_build_seconds",
    "host-side slab construction time per compile_network build")
_M_MEMO_HITS = obs.registry().counter(
    "engine_memo_hits_total",
    "cached_compile hits (legacy flag calls served from the memo)")
_M_MEMO_MISSES = obs.registry().counter(
    "engine_memo_misses_total",
    "cached_compile misses (legacy flag calls that built an artifact)")
_M_LOADS = obs.registry().counter(
    "engine_artifact_loads_total",
    "CompiledLUTNet artifacts rebuilt from disk via engine.load")


def compile_runs() -> int:
    """How many times this module has invoked the truth-table compiler."""
    return _compile_runs


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Shared jitted forwards — one per layout, keyed on (shapes, static meta).
#
# The slab arrays are jit *arguments*, not closure constants: every artifact
# with the same shapes and static metadata (including a save/load round-trip
# of the same model) reuses one trace, and a fresh artifact for a new model
# costs exactly one trace per block_b bucket.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("meta", "packed", "block_b",
                                             "interpret"))
def _uniform_forward(codes, idx_slab, table_slab, *, meta, packed, block_b,
                     interpret):
    slabs = NetworkSlabs(idx_slab, table_slab, meta, packed)
    return lut_network_pallas(codes, slabs, block_b=block_b,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("meta", "out_perm", "packed",
                                             "block_b", "interpret"))
def _mixed_forward(codes, idx_slab, shift_slab, width_slab, table_slab, *,
                   meta, out_perm, packed, block_b, interpret):
    slabs = MixedNetworkSlabs(idx_slab, shift_slab, width_slab, table_slab,
                              meta, out_perm, packed)
    return lut_network_mixed_pallas(codes, slabs, block_b=block_b,
                                    interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bws", "block_b", "interpret"))
def _per_layer_forward(codes, idx_tabs, *, bws, block_b, interpret):
    for (idx, tab), bw in zip(idx_tabs, bws):
        codes = lut_lookup_pallas(codes, idx, tab, bw, block_b=block_b,
                                  interpret=interpret)
    return codes


@functools.partial(jax.jit, static_argnames=("bws",))
def _reference_forward(codes, idx_tabs, *, bws):
    for (idx, tab), bw in zip(idx_tabs, bws):
        codes = ref.lut_lookup_ref(codes, idx, tab, bw)
    return codes


_FORWARDS = {"uniform": _uniform_forward, "mixed": _mixed_forward,
             "per_layer": _per_layer_forward, "reference": _reference_forward}


@dataclasses.dataclass(frozen=True)
class CompiledLUTNet:
    """An ahead-of-time compiled LUT network, ready to serve.

    ``layout`` is the execution path ``compile_network`` chose:

    * ``"mixed"``   — the fused mixed-width kernel over compiler-exact
      slabs (what ``optimize_level=`` + ``fused=True`` executes);
    * ``"uniform"`` — the fused kernel over row-stacked uniform slabs;
    * ``"per_layer"`` — one ``lut_lookup`` Pallas call per layer (the
      over-VMEM-budget / non-f32-exact fallback, still one jitted chain);
    * ``"reference"`` — the plain-jnp per-layer oracle (``use_pallas=False``
      compatibility; jitted but kernel-free).

    Exactly one of ``slabs`` / ``layers`` is populated.  ``plan`` is the
    :class:`~repro.engine.autotune.ExecutionPlan` that made the decision
    — heuristic, autotuned or synthesized from a pre-autotune artifact;
    its compat properties (``plan.reason``, ``plan.slab_bytes``, ...)
    keep the old bare-``FusedPlan`` surface working, and ``layout`` /
    ``block_b`` here always mirror ``plan.layout`` / ``plan.block_b``.
    ``stats`` is the ``CompileStats`` of the single
    ``repro.compile.optimize`` run (None when the build skipped the
    compiler).  The artifact is bit-exact with
    ``table_infer.network_table_forward`` on the stack it was built from.
    """

    layout: str
    n_in: int
    n_out: int
    block_b: int
    plan: ExecutionPlan
    stats: CompileStats | None
    slabs: NetworkSlabs | MixedNetworkSlabs | None = None
    layers: tuple[tuple[jax.Array, jax.Array, int], ...] | None = None

    def __call__(self, codes) -> jax.Array:
        """(batch, n_in) int codes -> (batch, n_out) int32 codes.

        Ragged batches are padded up to the next ``block_b`` multiple and
        sliced back, so any batch in (0, block_b] reuses one trace — a
        steady-state serving loop performs zero re-traces after warmup.
        """
        codes = jnp.asarray(codes, dtype=jnp.int32)
        if codes.ndim != 2 or codes.shape[1] != self.n_in:
            raise ValueError(
                f"expected (batch, {self.n_in}) codes, got {codes.shape}")
        batch = codes.shape[0]
        if batch == 0:
            return jnp.zeros((0, self.n_out), dtype=jnp.int32)
        padded = -(-batch // self.block_b) * self.block_b
        if padded != batch:
            codes = jnp.concatenate(
                [codes, jnp.zeros((padded - batch, self.n_in),
                                  dtype=codes.dtype)], axis=0)
        out = self._apply(codes)
        return out[:batch] if padded != batch else out

    def _apply(self, codes: jax.Array) -> jax.Array:
        interp = not _on_tpu()
        if self.layout == "mixed":
            s = self.slabs
            return _mixed_forward(
                codes, s.idx_slab, s.shift_slab, s.width_slab, s.table_slab,
                meta=s.meta, out_perm=s.out_perm, packed=s.packed,
                block_b=self.block_b, interpret=interp)
        if self.layout == "uniform":
            s = self.slabs
            return _uniform_forward(
                codes, s.idx_slab, s.table_slab, meta=s.meta,
                packed=s.packed, block_b=self.block_b, interpret=interp)
        idx_tabs = tuple((idx, tab) for idx, tab, _ in self.layers)
        bws = tuple(bw for _, _, bw in self.layers)
        if self.layout == "per_layer":
            return _per_layer_forward(codes, idx_tabs, bws=bws,
                                      block_b=self.block_b, interpret=interp)
        return _reference_forward(codes, idx_tabs, bws=bws)

    def jit_cache_size(self) -> int:
        """Trace count of this artifact's (shared) jitted forward.

        The forwards are process-wide per layout, so treat this as a
        monotonic counter: a steady-state serving loop must not grow it
        (the bench's ``retraces_after_warmup`` and the regression tests
        take before/after deltas).
        """
        return _FORWARDS[self.layout]._cache_size()

    def vmem_breakdown(self) -> dict:
        """Per-slab VMEM bytes of the chosen layout (serving diagnostics)."""
        if self.slabs is not None:
            return {**self.slabs.vmem_breakdown(), "layout": self.layout}
        idx = sum(i.size * i.dtype.itemsize for i, _, _ in self.layers)
        tab = sum(t.size * t.dtype.itemsize for _, t, _ in self.layers)
        return {"idx_slab_bytes": idx, "table_slab_bytes": tab,
                "total_bytes": idx + tab, "packed_int8": False,
                "layout": self.layout}

    # -- serialization ------------------------------------------------------

    def save(self, path: str) -> str:
        """Write the artifact as one ``.npz`` (checkpoint manifest format).

        Everything needed to serve — slab arrays, static shape metadata,
        the plan and the compile stats — round-trips; ``engine.load`` on a
        fresh process rebuilds bit-exact slabs without touching the
        compiler (a model A artifact at level 3 loads straight into its
        exact table slab).
        """
        meta: dict = {
            "kind": ARTIFACT_KIND, "format": FORMAT_VERSION,
            "layout": self.layout, "n_in": self.n_in, "n_out": self.n_out,
            "block_b": self.block_b,
            "plan": self.plan.as_dict(),
            "stats": None if self.stats is None else self.stats.as_dict(),
        }
        arrays: dict[str, np.ndarray] = {}
        if self.layout == "mixed":
            s = self.slabs
            arrays = {"idx_slab": s.idx_slab, "shift_slab": s.shift_slab,
                      "width_slab": s.width_slab, "table_slab": s.table_slab}
            meta["packed"] = s.packed
            meta["out_perm"] = (None if s.out_perm is None
                                else list(s.out_perm))
            meta["layer_meta"] = [
                {"n_out": m.n_out, "fan_in": m.fan_in,
                 # 2-element groups = legacy contiguous layout; a third
                 # element carries the row-dedup flat offsets (format 3)
                 "groups": [[g.n_out, g.entry_bits] if g.offs is None
                            else [g.n_out, g.entry_bits, list(g.offs)]
                            for g in m.groups]}
                for m in s.meta]
            meta["dedup_entries_saved"] = int(s.dedup_entries_saved)
        elif self.layout == "uniform":
            s = self.slabs
            arrays = {"idx_slab": s.idx_slab, "table_slab": s.table_slab}
            meta["packed"] = s.packed
            meta["layer_meta"] = [list(m) for m in s.meta]
        else:
            meta["bws"] = [int(bw) for _, _, bw in self.layers]
            for li, (idx, tab, _) in enumerate(self.layers):
                arrays[f"idx_{li}"] = idx
                arrays[f"table_{li}"] = tab
        return save_arrays(path, arrays, meta)


def load(path: str) -> CompiledLUTNet:
    """Rebuild a ``CompiledLUTNet`` from ``CompiledLUTNet.save`` output.

    No compiler run, no slab build: the saved slabs are handed to the
    shared jitted forwards as-is, so a deployment process pays one jit
    trace per batch bucket and nothing else.
    """
    arrays, meta = load_arrays(path)
    if meta.get("kind") != ARTIFACT_KIND:
        raise ValueError(
            f"{path} is not a {ARTIFACT_KIND} artifact "
            f"(kind={meta.get('kind')!r})")
    if meta.get("format", 0) > FORMAT_VERSION:
        raise ValueError(
            f"{path} has artifact format {meta['format']}; this build "
            f"reads <= {FORMAT_VERSION}")
    pd = meta["plan"]
    if "variant" in pd:
        plan = ExecutionPlan.from_dict(pd)
    else:
        # format-1 artifact: the record is a bare FusedPlan — synthesize
        # the default plan so the loaded artifact speaks the new surface
        # (zero search, zero compiler runs, bit-exact slabs as always)
        plan = ExecutionPlan.from_fused(
            FusedPlan.from_dict(pd), meta["layout"], int(meta["block_b"]),
            source="synthesized")
    stats = (None if meta["stats"] is None
             else CompileStats.from_dict(meta["stats"]))
    layout = meta["layout"]
    slabs = None
    layers = None
    if layout == "mixed":
        lm = tuple(
            MixedLayerMeta(m["n_out"], m["fan_in"],
                           tuple(MixedGroupMeta(
                               int(g[0]), int(g[1]),
                               tuple(int(o) for o in g[2])
                               if len(g) > 2 else None)
                                 for g in m["groups"]))
            for m in meta["layer_meta"])
        out_perm = (None if meta["out_perm"] is None
                    else tuple(int(p) for p in meta["out_perm"]))
        slabs = MixedNetworkSlabs(
            jnp.asarray(arrays["idx_slab"]), jnp.asarray(arrays["shift_slab"]),
            jnp.asarray(arrays["width_slab"]),
            jnp.asarray(arrays["table_slab"]),
            lm, out_perm, bool(meta["packed"]),
            dedup_entries_saved=int(meta.get("dedup_entries_saved", 0)))
    elif layout == "uniform":
        lm = tuple(LayerMeta(*(int(v) for v in m))
                   for m in meta["layer_meta"])
        slabs = NetworkSlabs(jnp.asarray(arrays["idx_slab"]),
                             jnp.asarray(arrays["table_slab"]),
                             lm, bool(meta["packed"]))
    else:
        layers = tuple(
            (jnp.asarray(arrays[f"idx_{li}"]),
             jnp.asarray(arrays[f"table_{li}"]), int(bw))
            for li, bw in enumerate(meta["bws"]))
    _M_LOADS.inc()
    return CompiledLUTNet(layout=layout, n_in=int(meta["n_in"]),
                          n_out=int(meta["n_out"]),
                          block_b=int(meta["block_b"]), plan=plan,
                          stats=stats, slabs=slabs, layers=layers)


# ---------------------------------------------------------------------------
# compile_network: the one place the compile/cost/build/jit decision lives
# ---------------------------------------------------------------------------


def _as_triples(layers) -> list[tuple[np.ndarray, np.ndarray, int]]:
    out = []
    for lay in layers:
        if hasattr(lay, "indices") and hasattr(lay, "table"):
            out.append((lay.indices, lay.table, int(lay.bw_in)))
        else:
            idx, tab, bw = lay
            out.append((idx, tab, int(bw)))
    if not out:
        raise ValueError("compile_network needs at least one layer")
    return out


def compile_network(layers, *, optimize_level: int | None = None,
                    in_features: int | None = None, fused: bool = True,
                    use_pallas: bool = True, block_b: int = DEFAULT_BLOCK_B,
                    vmem_budget_bytes: int = FUSED_VMEM_BUDGET_BYTES,
                    autotune: bool = False, autotune_codes=None,
                    autotune_block_bs=None) -> CompiledLUTNet:
    """Compile a sparse LUT stack into a serving artifact, once.

    ``layers`` is a ``LayerTruthTable`` list, a sequence of
    ``(indices, table, bw_in)`` triples, or an already-computed
    ``repro.compile.OptimizeResult`` (the compiler is then skipped and its
    lowerings reused — ``optimize_level`` must be None in that case).

    The decision ladder is exactly the one the legacy flags used to
    re-evaluate per call, now evaluated once:

    1. ``optimize_level`` set -> run ``compile.optimize`` (ONE run);
       cost the compiler's mixed-width lowering via ``fused_plan`` and
       take the fused mixed path when it fits the VMEM budget;
    2. otherwise cost the uniform layout; take the fused uniform path
       when eligible;
    3. otherwise fall back to the jitted per-layer chain (``use_pallas=
       False`` pins the plain-jnp reference chain instead).

    ``autotune=True`` replaces the static ladder with measurement: every
    eligible :class:`~repro.kernels.plan.PlanVariant` (layout x block_b x
    pack) is built and its jitted forward timed on the actual backend
    (see ``repro.engine.autotune``), and the artifact carries the winner
    plus the full timing table — ``save``/``load`` replay it with zero
    search.  ``autotune_codes`` supplies the representative batch
    (None: seeded synthetic codes); ``autotune_block_bs`` overrides the
    ``block_b`` sweep (the requested ``block_b`` always joins it, so the
    heuristic default stays among the candidates).  ``autotune`` is
    ignored when the caller pinned the path with ``fused=False`` or
    ``use_pallas=False`` — there is nothing left to search.

    ``in_features`` is the served input bus width (``codes.shape[-1]``);
    defaults to the widest first-layer index + 1.
    """
    global _compile_runs
    res: OptimizeResult | None = None
    if isinstance(layers, OptimizeResult):
        if optimize_level is not None:
            raise ValueError(
                "layers is already an OptimizeResult; optimize_level must "
                "be None (the compiler does not run again)")
        res = layers
    else:
        triples = _as_triples(layers)
        if in_features is None:
            # the input bus width: only the FIRST layer's indices address
            # it (later layers address their producer's bus)
            in_features = int(np.max(np.asarray(triples[0][0]))) + 1
        if optimize_level is not None:
            from repro.compile import optimize, tables_from_triples
            res = optimize(tables_from_triples(triples), optimize_level,
                           in_features=in_features)
            _compile_runs += 1
            _M_COMPILER_RUNS.inc()
    stats = res.stats if res is not None else None

    if autotune and use_pallas and fused:
        mixed = res.mixed_tables if res is not None else None
        if res is not None:
            triples = [(tt.indices, tt.table, tt.bw_in)
                       for tt in res.tables]
            if in_features is None:
                in_features = res.cnet.in_features
        # search cost is observed by autotune's own histogram
        # (engine_autotune_seconds), not the slab-build one
        plan, built = autotune_network(
            triples, mixed, in_features=in_features, block_b=block_b,
            vmem_budget_bytes=vmem_budget_bytes, codes=autotune_codes,
            block_bs=autotune_block_bs)
        _M_BUILDS.labels(layout=plan.layout).inc()
        if plan.layout in ("mixed", "uniform"):
            return CompiledLUTNet(layout=plan.layout, n_in=in_features,
                                  n_out=built.n_out, block_b=plan.block_b,
                                  plan=plan, stats=stats, slabs=built)
        n_out = int(np.asarray(triples[-1][1]).shape[0])
        return CompiledLUTNet(layout="per_layer", n_in=in_features,
                              n_out=n_out, block_b=plan.block_b, plan=plan,
                              stats=stats, layers=built)

    if res is not None and use_pallas and fused:
        mixed = res.mixed_tables
        cost = fused_plan(mixed, vmem_budget_bytes)
        if cost.fused:
            t0 = time.perf_counter()
            slabs = build_mixed_network_slabs(mixed, pack=cost.pack)
            _M_SLAB_BUILD.observe(time.perf_counter() - t0)
            _M_BUILDS.labels(layout="mixed").inc()
            return CompiledLUTNet(
                layout="mixed",
                n_in=res.cnet.in_features if in_features is None
                else in_features,
                n_out=slabs.n_out, block_b=block_b,
                plan=ExecutionPlan.from_fused(cost, "mixed", block_b),
                stats=stats, slabs=slabs)
    if res is not None:
        # the padded uniform lowering is only materialized once the mixed
        # fused path has been ruled out (same fall-through as the legacy
        # ops.lut_network); the optimized first layer may have pruned its
        # widest input feature, so the bus width comes from the IR, not
        # from the surviving indices
        triples = [(tt.indices, tt.table, tt.bw_in) for tt in res.tables]
        if in_features is None:
            in_features = res.cnet.in_features
    n_out = int(np.asarray(triples[-1][1]).shape[0])

    cost = fused_plan(triples, vmem_budget_bytes)
    if not use_pallas or not fused:
        cost = dataclasses.replace(cost, fused=False,
                                   reason="fused_disabled")
    if use_pallas and cost.fused:
        t0 = time.perf_counter()
        slabs = build_network_slabs(triples, pack=cost.pack)
        _M_SLAB_BUILD.observe(time.perf_counter() - t0)
        _M_BUILDS.labels(layout="uniform").inc()
        return CompiledLUTNet(
            layout="uniform", n_in=in_features, n_out=slabs.n_out,
            block_b=block_b,
            plan=ExecutionPlan.from_fused(cost, "uniform", block_b),
            stats=stats, slabs=slabs)
    t0 = time.perf_counter()
    jl = tuple((jnp.asarray(np.asarray(i, dtype=np.int32)),
                jnp.asarray(np.asarray(t, dtype=np.int32)), int(b))
               for i, t, b in triples)
    _M_SLAB_BUILD.observe(time.perf_counter() - t0)
    layout = "per_layer" if use_pallas else "reference"
    _M_BUILDS.labels(layout=layout).inc()
    return CompiledLUTNet(
        layout=layout, n_in=in_features, n_out=n_out, block_b=block_b,
        plan=ExecutionPlan.from_fused(cost, layout, block_b),
        stats=stats, layers=jl)


# ---------------------------------------------------------------------------
# Identity-keyed memo for the legacy flag API (ops.lut_network)
# ---------------------------------------------------------------------------

# key -> (layers kept alive so ids stay unique, CompiledLUTNet); insertion-
# ordered dict gives FIFO eviction
_cache: dict[tuple, tuple[list, CompiledLUTNet]] = {}
_CACHE_MAX = 16


def cached_compile(layers, *, optimize_level: int | None,
                   in_features: int, fused: bool, use_pallas: bool,
                   block_b: int, vmem_budget_bytes: int) -> CompiledLUTNet:
    """Memoized ``compile_network`` keyed by *layer identity* + flags.

    The escape hatch that keeps the legacy per-call API cheap: a caller
    looping over ``ops.lut_network(codes, layers, optimize_level=...)``
    with the same layer arrays hits the cached ``OptimizeResult`` + built
    slabs instead of silently recompiling every call.  Keys use ``id()``
    of the index/table arrays (cheap; no hashing of megabyte tables) and
    each entry pins its arrays, so a live id can never be recycled into a
    collision.  The flip side: arrays handed to ``lut_network`` must be
    treated as immutable — an in-place table edit will serve stale results
    until ``cache_clear()``.  FIFO-bounded to ``_CACHE_MAX`` entries.
    """
    layers = list(layers)
    triples = _as_triples(layers)
    key = (tuple((id(i), id(t), b) for i, t, b in triples),
           optimize_level, in_features, fused, use_pallas, block_b,
           vmem_budget_bytes)
    hit = _cache.get(key)
    if hit is not None:
        _M_MEMO_HITS.inc()
        return hit[1]
    _M_MEMO_MISSES.inc()
    eng = compile_network(triples, optimize_level=optimize_level,
                          in_features=in_features, fused=fused,
                          use_pallas=use_pallas, block_b=block_b,
                          vmem_budget_bytes=vmem_budget_bytes)
    while len(_cache) >= _CACHE_MAX:
        _cache.pop(next(iter(_cache)))
    _cache[key] = (layers, eng)
    return eng


def cache_size() -> int:
    """Number of memoized legacy-API artifacts (regression tests)."""
    return len(_cache)


def cache_clear() -> None:
    """Drop all memoized artifacts (tests / after in-place table edits)."""
    _cache.clear()
