# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

# the execution-plan machinery is the package's public surface: one
# block_b source of truth plus the variant space the engine autotunes
# over (see repro.kernels.plan)
from repro.kernels.lut_lookup import DEFAULT_BLOCK_B
from repro.kernels.plan import (DEFAULT_BLOCK_BS, FUSED_VMEM_BUDGET_BYTES,
                                FusedPlan, PlanVariant, default_variant,
                                enumerate_variants, fused_plan)

__all__ = [
    "DEFAULT_BLOCK_B",
    "DEFAULT_BLOCK_BS",
    "FUSED_VMEM_BUDGET_BYTES",
    "FusedPlan",
    "PlanVariant",
    "default_variant",
    "enumerate_variants",
    "fused_plan",
]
