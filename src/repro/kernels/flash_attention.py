"""Pallas TPU kernel: blocked flash attention (forward) with GQA + windows.

The LM-zoo prefill hot path.  Online-softmax over KV blocks: for each
(batch*head, q-block) grid cell the kernel streams KV blocks through VMEM,
maintaining running max/denominator so the (S x S) logits never materialize
in HBM — the standard memory-roofline move for 32k prefill.

Supports:
  * causal masking (decoder LMs),
  * GQA: q heads grouped over fewer KV heads (the BlockSpec index maps a
    q-head to its KV head, so KV tiles are fetched once per group),
  * sliding windows (gemma3 5:1 local:global pattern).

Grid: (batch, q_heads, S/block_q, S/block_k); the KV axis is innermost and
sequential, carrying (acc, m, l) in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int | None,
            block_q: int, block_k: int, n_k: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Skip fully-masked KV blocks (causal upper triangle / outside window).
    pred = jnp.bool_(True)
    if causal:
        pred &= k_start <= q_start + block_q - 1
    if window is not None:
        pred &= k_start + block_k - 1 > q_start - window

    @pl.when(pred)
    def _compute():
        q = q_ref[0, 0, ...].astype(jnp.float32)     # (bq, d)
        k = k_ref[0, 0, ...].astype(jnp.float32)     # (bk, d)
        v = v_ref[0, 0, ...].astype(jnp.float32)     # (bk, d)
        # Zero the padded KV tail of the last block: OOB tile regions are
        # undefined and 0 * undefined would still poison the accumulator.
        kv_valid = (k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0)) < seq_len
        k = jnp.where(kv_valid, k, 0)
        v = jnp.where(kv_valid, v, 0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len          # zero-padded KV tail of the last block
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                          # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (bq, bk)
        corr = jnp.exp(m_prev - m_new)               # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0, 0, ...] = (acc_ref[...] / denom).astype(out_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q (B, Hq, S, D); k, v (B, Hkv, S, D) -> (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    n_k = pl.cdiv(s, block_k)
    grid = (b, hq, pl.cdiv(s, block_q), n_k)

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          block_q=block_q, block_k=block_k, n_k=n_k,
                          seq_len=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, h, qi, ki: (bb, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, qi, ki, g=group: (bb, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, qi, ki, g=group: (bb, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, h, qi, ki: (bb, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
