"""Pallas TPU kernel: LogicNets LUT-layer inference (the HBB gather).

TPU adaptation of the paper's core mechanism (DESIGN.md §2): on an FPGA a
neuron *is* a configured K-LUT; on a TPU the layer's truth tables live as a
tensor in VMEM and inference is "pack input codes -> gather output codes".

Scattered gathers are slow on TPU (no hardware gather across lanes), so both
gathers are expressed as **one-hot contractions on the MXU**:

  * fan-in gather:  sel[o,k,i] = (indices[o,k] == i); g = sel · codes
  * table gather:   out[b,o]  += Σ_e (entry[b,o] == e+off) * table[o,e+off]
    streamed over E in chunks so the compare tensor stays inside VMEM.

Grid: (batch tiles × neuron tiles); per step the kernel sees a
(block_b, I) code slab, a (block_o, FI) index slab and a (block_o, E) table
slab — all VMEM-resident under the default tile sizes (see ops.lut_lookup
for the sizing arithmetic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The batch tile every kernel (and the engine) defaults to.  This is the
# single source of truth — ops.py, the plan machinery and the serving tier
# all consume it (re-exported as ``repro.kernels.DEFAULT_BLOCK_B``), so an
# ``ExecutionPlan``'s ``block_b`` is the only other place the tile lives.
DEFAULT_BLOCK_B = 128


def pack_fan_in_entries(codes: jax.Array, idx: jax.Array,
                        bw_in: int) -> jax.Array:
    """(bb, I) codes + (bo, FI) indices -> (bo, bb) packed table entries.

    Fan-in gather as a one-hot contraction (MXU), then shift-pack each
    neuron's gathered codes into its table index.  Shared by this
    per-layer kernel and the fused whole-network kernel (lut_network).
    """
    fan_in = idx.shape[1]
    g = gather_fan_in_codes(codes, idx)                   # (bo, FI, bb)
    shifts = bw_in * jax.lax.broadcasted_iota(jnp.int32, (fan_in, 1), 0)[:, 0]
    return jnp.sum(g << shifts[None, :, None], axis=1)    # (bo, bb)


def gather_fan_in_codes(codes: jax.Array, idx: jax.Array) -> jax.Array:
    """(bb, I) codes + (bo, FI) indices -> (bo, FI, bb) gathered codes.

    The fan-in gather as a one-hot MXU contraction — the shared first half
    of both packing conventions (uniform shift and per-element shifts).
    """
    bb, n_in = codes.shape
    bo, fan_in = idx.shape
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (n_in, 1), 0)[:, 0]
    sel = (idx[:, :, None] == iota_i[None, None, :]).astype(jnp.float32)
    # (bo*FI, I) @ (I, bb) -> (bo*FI, bb)
    g = jax.lax.dot(sel.reshape(bo * fan_in, n_in),
                    codes.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)
    return g.reshape(bo, fan_in, bb).astype(jnp.int32)


def pack_fan_in_entries_mixed(codes: jax.Array, idx: jax.Array,
                              shifts: jax.Array,
                              widths: jax.Array) -> jax.Array:
    """Mixed-width packing: per-(neuron, element) shifts instead of the
    uniform ``bw_in * k`` ladder.

    ``shifts``/``widths`` are (bo, FI) int32: element k of neuron j lands
    at bits [shifts[j,k], shifts[j,k] + widths[j,k]) of its table entry.
    A width of 0 marks a padded element (neurons below the layer's max
    fan-in) — the mask zeroes its contribution entirely, which is what
    lets the fused mixed-width kernel keep exact ``2^(sum widths)``-entry
    tables with no padding rows.  Real elements always carry codes below
    ``2^width`` (the producing layer's contract), so the mask is a no-op
    for them.
    """
    g = gather_fan_in_codes(codes, idx)                    # (bo, FI, bb)
    g = g & ((1 << widths) - 1)[:, :, None]
    return jnp.sum(g << shifts[:, :, None], axis=1)        # (bo, bb)


def _kernel(codes_ref, idx_ref, table_ref, out_ref, *, bw_in: int,
            e_chunk: int):
    codes = codes_ref[...]                      # (bb, I) int32
    idx = idx_ref[...]                          # (bo, FI) int32
    table = table_ref[...]                      # (bo, E) int32
    bb = codes.shape[0]
    bo = idx.shape[0]
    n_entries = table.shape[1]

    entry = pack_fan_in_entries(codes, idx, bw_in)        # (bo, bb)

    # --- table gather, streamed over entry chunks -------------------------
    n_chunks = pl.cdiv(n_entries, e_chunk)

    def body(c, acc):
        off = c * e_chunk
        tchunk = jax.lax.dynamic_slice(table, (0, off), (bo, e_chunk))
        eids = off + jax.lax.broadcasted_iota(jnp.int32, (1, e_chunk), 1)
        hit = (entry[:, :, None] == eids[None, :, :])     # (bo, bb, ec)
        return acc + jnp.sum(jnp.where(hit, tchunk[:, None, :], 0), axis=2)

    acc = jnp.zeros((bo, bb), jnp.int32)
    acc = jax.lax.fori_loop(0, n_chunks, body, acc)
    out_ref[...] = acc.T                                   # (bb, bo)


def lut_lookup_pallas(codes: jax.Array, indices: jax.Array, table: jax.Array,
                      bw_in: int, *, block_b: int = DEFAULT_BLOCK_B,
                      block_o: int = 128,
                      e_chunk: int = 512,
                      interpret: bool = False) -> jax.Array:
    """(batch, I) codes -> (batch, O) codes through per-neuron truth tables."""
    batch, n_in = codes.shape
    n_out, fan_in = indices.shape
    n_entries = table.shape[1]
    if batch == 0:
        # a zero-size grid (min(block_b, 0) == 0) is ill-formed; the empty
        # result needs no kernel at all
        return jnp.zeros((0, n_out), dtype=jnp.int32)
    block_b = min(block_b, batch)
    block_o = min(block_o, n_out)
    e_chunk = min(e_chunk, n_entries)
    # Both are powers of two (entries = 2^(fan_in*bw_in)), so chunks tile
    # the table exactly — required for the streamed compare to be sound.
    assert n_entries % e_chunk == 0, (n_entries, e_chunk)
    grid = (pl.cdiv(batch, block_b), pl.cdiv(n_out, block_o))

    return pl.pallas_call(
        functools.partial(_kernel, bw_in=bw_in, e_chunk=e_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_in), lambda b, o: (b, 0)),
            pl.BlockSpec((block_o, fan_in), lambda b, o: (o, 0)),
            pl.BlockSpec((block_o, n_entries), lambda b, o: (o, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda b, o: (b, o)),
        out_shape=jax.ShapeDtypeStruct((batch, n_out), jnp.int32),
        interpret=interpret,
    )(codes, indices, table)
