"""Pallas TPU kernel: fused whole-network LogicNets LUT inference.

The paper's deployment claim is that an extreme-throughput LogicNet *is* a
pipeline of LUTs: on the FPGA every layer's truth tables live in fabric and
an activation never leaves the chip between layers — that "no off-chip
round-trip" discipline is what buys sub-microsecond whole-network latency.
The per-layer ``lut_lookup`` kernel violates the analogy on TPU: each layer
is its own ``pallas_call``, so int32 activation codes bounce through HBM
between every pair of layers.

This kernel is the TPU transliteration of the FPGA pipeline.  All layers'
truth tables and fan-in indices are concatenated into two VMEM-resident
slabs (padded to the widest layer, with *static* per-layer shape metadata
compiled into the kernel), the grid runs over batch tiles only, and the
activation codes stay in registers/VMEM from network input to network
output — one ``pallas_call`` for the whole sparse stack, exactly as the
fabric holds the whole net.

Layout — layers are *row-stacked*, not padded to a (L, O_max, ...) box:

  * ``idx_slab``   (sum_l O_l, FI_max) int32 — layer l's fan-in indices in
    rows ``[row_off_l, row_off_l + O_l)``; padding is zero and never read
    (per-layer static slices, offsets compiled into the kernel).
  * ``table_slab`` (sum_l O_l, E_max) int32, or int8 when every layer's
    output codes fit a byte (``bw_out <= 8``).  Packed tables are widened
    in-kernel with a mask, quartering the VMEM footprint so deeper stacks
    stay under the budget that ``ops.lut_network`` enforces.

Row-stacking means a narrow layer costs only its own rows — heterogeneous
stacks (and stacks shrunk by ``repro.compile``'s dead-neuron elimination)
get proportionally smaller slabs, where the old box layout paid
``L * O_max`` rows regardless.

Per layer the fan-in gather is the same one-hot MXU contraction as
``lut_lookup``, but the table gather is upgraded from a streamed
compare/select to a *two-level one-hot gather* (see ``_layer_step``): the
bulk of the work becomes a batched matmul, which is where the fused
engine's measured speedup over the per-layer path comes from on top of
the saved HBM round trips.

Mixed-width layout (``MixedNetworkSlabs`` / ``lut_network_mixed_pallas``)
— the compiler-exact variant of the same engine.  ``repro.compile``'s
dead-input pruning and level-3 re-encoding leave each neuron with its own
per-element input widths and a compact ``2^(sum of widths)``-entry table;
the uniform layout above would pad all of that back to the layer's widest
feature and largest entry count.  The mixed slabs don't:

  * ``idx_slab`` / ``shift_slab`` / ``width_slab`` (sum_l O_l, FI_max)
    int32 — per-(neuron, element) fan-in indices, packed-entry bit
    offsets, and element widths (0 marks fan-in padding), generalizing the
    uniform ``bw_in * k`` shift ladder.
  * ``table_slab`` (1, sum_j 2^entry_bits_j) int32 | int8 — every
    neuron's table back to back, exactly ``2^(sum of its input widths)``
    entries each; a neuron's row offset is static, so the packed slab
    costs byte-for-byte what the netlist's ``table_bytes()`` accounting
    proves.

Within a layer neurons are grouped by entry count (equal-size tables
reshape into one ``(group, E)`` block for the same batched two-level
gather); the group sort permutes the layer's output bus, which the
builder folds into the *next* layer's indices — only the final layer's
permutation survives, undone in-kernel by one static one-hot matmul.

Both slab dataclasses split cleanly into *arrays* (the slabs) and
*static, hashable metadata* (``meta`` / ``out_perm`` / ``packed``) —
a deliberate contract the serving engine (``repro.engine``) relies on
twice: its jitted forwards close over the metadata only and take the
slab arrays as arguments (so equal-shaped artifacts share one trace),
and ``CompiledLUTNet.save``/``load`` serialize an artifact as exactly
those arrays plus a JSON record of the metadata, reconstructing the
slabs here without re-running either builder.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lut_lookup import (DEFAULT_BLOCK_B, pack_fan_in_entries,
                                      pack_fan_in_entries_mixed)


class LayerMeta(NamedTuple):
    """Static per-layer shape metadata (compiled into the kernel)."""

    n_out: int
    fan_in: int
    n_entries: int
    bw_in: int


@dataclasses.dataclass(frozen=True)
class NetworkSlabs:
    """A whole sparse stack packed for single-kernel execution.

    Arrays + static metadata only (see module docstring): constructing
    one from deserialized arrays — or from tracers inside a jitted
    wrapper — is supported and is how ``repro.engine`` serves and
    round-trips artifacts without rebuilding slabs.
    """

    idx_slab: jax.Array      # (sum_l O_l, FI_max) int32
    table_slab: jax.Array    # (sum_l O_l, E_max) int32 | int8 (packed)
    meta: tuple[LayerMeta, ...]
    packed: bool

    @property
    def n_layers(self) -> int:
        return len(self.meta)

    @property
    def n_out(self) -> int:
        return self.meta[-1].n_out

    def vmem_bytes(self) -> int:
        return (self.idx_slab.size * self.idx_slab.dtype.itemsize
                + self.table_slab.size * self.table_slab.dtype.itemsize)

    def vmem_breakdown(self) -> dict:
        """Per-slab VMEM bytes (bench / fused-fallback diagnostics)."""
        idx = self.idx_slab.size * self.idx_slab.dtype.itemsize
        tab = self.table_slab.size * self.table_slab.dtype.itemsize
        return {"idx_slab_bytes": idx, "table_slab_bytes": tab,
                "total_bytes": idx + tab, "packed_int8": self.packed}


def estimate_slab_bytes(layers: Sequence[tuple],
                        pack: bool | None = None) -> tuple[int, bool, bool]:
    """Projected fused-slab footprint, int8-pack and f32-exact eligibility.

    Computed from shapes plus one pass of min/max over the tables (no
    copies) — lets ``ops.lut_network`` decide *before* paying for slab
    construction it would discard on the per-layer fallback path.  Returns
    ``(bytes, pack, f32_exact)``; ``f32_exact`` is False when any output
    code is outside [0, 2^24), where the kernel's f32 one-hot gather
    would round.  ``pack`` follows ``build_network_slabs``: None auto-packs
    when every code fits a byte; an explicit value costs that choice
    instead (the plan machinery uses this to price pack on/off variants).
    """
    o_sum = sum(np.asarray(t).shape[0] for _, t, _ in layers)
    fi_max = max(np.asarray(i).shape[1] for i, _, _ in layers)
    e_max = max(np.asarray(t).shape[1] for _, t, _ in layers)
    lo_hi = [(int(np.min(t, initial=0)), int(np.max(t, initial=0)))
             for _, t, _ in layers]
    byte_ok = all(lo >= 0 and hi < 256 for lo, hi in lo_hi)
    f32_exact = all(lo >= 0 and hi < 1 << 24 for lo, hi in lo_hi)
    use_pack = _resolve_pack(byte_ok, pack)
    table_itemsize = 1 if use_pack else 4
    return (o_sum * fi_max * 4
            + o_sum * e_max * table_itemsize), use_pack, f32_exact


def _resolve_pack(byte_ok: bool, pack: bool | None) -> bool:
    """One pack policy for both slab builders: None auto-packs when every
    code fits an unsigned byte; an explicit True outside that range must
    raise — the int8 store would silently wrap codes >= 256 (uint8 cast).
    """
    if pack is None:
        return byte_ok
    if pack and not byte_ok:
        raise ValueError(
            "pack=True stores table codes as unsigned bytes; these tables "
            "hold codes outside [0, 256) — use pack=None (auto) or "
            "pack=False")
    return pack


def build_network_slabs(layers: Sequence[tuple], *,
                        pack: bool | None = None) -> NetworkSlabs:
    """Pack per-layer ``(indices, table, bw_in)`` triples into fused slabs.

    ``pack=None`` (auto) stores the table slab as int8 whenever every
    layer's output codes fit an unsigned byte — true for any LogicNets
    topology with ``bw_out <= 8``.  Host-side (numpy): tables come straight
    from ``LayerTruthTable`` generation.
    """
    if not layers:
        raise ValueError("fused network needs at least one layer")
    metas = []
    idx_np, tab_np = [], []
    for indices, table, bw_in in layers:
        idx = np.asarray(indices, dtype=np.int32)
        tab = np.asarray(table, dtype=np.int32)
        m = LayerMeta(tab.shape[0], idx.shape[1], tab.shape[1], int(bw_in))
        if m.n_entries != 1 << (m.fan_in * m.bw_in):
            raise ValueError(
                f"table has {m.n_entries} entries; fan_in={m.fan_in} at "
                f"bw_in={m.bw_in} requires 2^{m.fan_in * m.bw_in}")
        if int(tab.max(initial=0)) >= 1 << 24 or int(tab.min(initial=0)) < 0:
            raise ValueError(
                "fused kernel gathers tables through exact f32 one-hot "
                "contractions; output codes must be in [0, 2^24) — use the "
                "per-layer path (fused=False) for wider codes")
        metas.append(m)
        idx_np.append(idx)
        tab_np.append(tab)
    o_sum = sum(m.n_out for m in metas)
    fi_max = max(m.fan_in for m in metas)
    e_max = max(m.n_entries for m in metas)

    idx_slab = np.zeros((o_sum, fi_max), dtype=np.int32)
    pack = _resolve_pack(
        all(int(t.max(initial=0)) < 256 and int(t.min(initial=0)) >= 0
            for t in tab_np), pack)
    tab_dtype = np.int8 if pack else np.int32
    table_slab = np.zeros((o_sum, e_max), dtype=tab_dtype)
    row = 0
    for idx, tab, m in zip(idx_np, tab_np, metas):
        idx_slab[row:row + m.n_out, :m.fan_in] = idx
        table_slab[row:row + m.n_out, :m.n_entries] = (
            tab.astype(np.uint8).view(np.int8) if pack else tab)
        row += m.n_out
    return NetworkSlabs(jnp.asarray(idx_slab), jnp.asarray(table_slab),
                        tuple(metas), bool(pack))


def _table_gather_two_level(entry: jax.Array, table: jax.Array,
                            ent_bits: int) -> jax.Array:
    """Gather table[o, entry[o, b]] for all (o, b): (bo, bb) -> (bb, bo).

    Unlike the per-layer ``lut_lookup`` kernel (which streams an
    elementwise compare/select over all table entries), the gather here
    splits the packed entry index into low/high halves: the low half is
    gathered with one *batched matmul* against its one-hot (MXU work),
    which collapses the entry axis from E to sqrt(E); the high half then
    costs only an O(B*O*sqrt(E)) elementwise select.  Same exact result —
    one-hot contractions on small ints are exact in f32 — at matmul
    throughput instead of compare/select throughput.  Shared by the
    uniform and mixed-width fused kernels (the entry packing is what
    differs between them).
    """
    bo, n_entries = table.shape

    # two-level one-hot gather: entry = hi * n_lo + lo
    lo_bits = ent_bits // 2
    n_lo = 1 << lo_bits
    n_hi = n_entries // n_lo
    lo = entry & (n_lo - 1)
    hi = entry >> lo_bits

    lo_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_lo), 1)[0]
    oh_lo = (lo[:, :, None] == lo_iota[None, None, :]).astype(jnp.float32)
    # (bo, n_hi, n_lo) x (bo, bb, n_lo) -> (bo, n_hi, bb), batched over bo
    part = jax.lax.dot_general(
        table.astype(jnp.float32).reshape(bo, n_hi, n_lo), oh_lo,
        (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32)

    hi_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_hi), 1)[0]
    oh_hi = (hi[:, :, None] == hi_iota[None, None, :])       # (bo, bb, n_hi)
    out = jnp.sum(jnp.where(jnp.transpose(oh_hi, (0, 2, 1)), part, 0.0),
                  axis=1)                                    # (bo, bb)
    return out.astype(jnp.int32).T                           # (bb, bo)


def _layer_step(h: jax.Array, idx: jax.Array, table: jax.Array,
                bw_in: int) -> jax.Array:
    """One uniform-width LUT layer on in-register codes: (bb, I) -> (bb, O)."""
    fan_in = idx.shape[1]
    entry = pack_fan_in_entries(h, idx, bw_in)               # (bo, bb)
    return _table_gather_two_level(entry, table, fan_in * bw_in)


def _kernel(codes_ref, idx_ref, table_ref, out_ref, *,
            meta: tuple[LayerMeta, ...], packed: bool):
    h = codes_ref[...]                                       # (bb, I0)
    # Static unroll: each layer reads its (unpadded) row-slice of the slabs
    # and hands its output codes straight to the next layer — no HBM in
    # between.  Row offsets are compile-time constants.
    row = 0
    for m in meta:
        idx = idx_ref[row:row + m.n_out, :m.fan_in]
        table = table_ref[row:row + m.n_out, :m.n_entries]
        if packed:
            table = table.astype(jnp.int32) & 0xFF
        h = _layer_step(h, idx, table, m.bw_in)
        row += m.n_out
    out_ref[...] = h


def lut_network_pallas(codes: jax.Array, slabs: NetworkSlabs, *,
                       block_b: int = DEFAULT_BLOCK_B,
                       interpret: bool = False) -> jax.Array:
    """Whole sparse stack in one kernel: (batch, I0) -> (batch, O_last)."""
    batch, n_in = codes.shape
    if batch == 0:
        # a zero-size grid (min(block_b, 0) == 0) is ill-formed; the empty
        # result needs no kernel at all
        return jnp.zeros((0, slabs.n_out), dtype=jnp.int32)
    o_sum, fi_max = slabs.idx_slab.shape
    e_max = slabs.table_slab.shape[1]
    block_b = min(block_b, batch)
    grid = (pl.cdiv(batch, block_b),)

    return pl.pallas_call(
        functools.partial(_kernel, meta=slabs.meta, packed=slabs.packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_in), lambda b: (b, 0)),
            pl.BlockSpec((o_sum, fi_max), lambda b: (0, 0)),
            pl.BlockSpec((o_sum, e_max), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, slabs.n_out), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, slabs.n_out), jnp.int32),
        interpret=interpret,
    )(codes, slabs.idx_slab, slabs.table_slab)


# ---------------------------------------------------------------------------
# Mixed-width fused path: compiler-exact slabs (no padding to the widest
# feature / largest entry count) — see the module docstring's second half.
# ---------------------------------------------------------------------------


class MixedGroupMeta(NamedTuple):
    """One equal-entry-count neuron group inside a layer (static).

    ``offs`` (static, per neuron of the group) holds each neuron's entry
    offset into the flat table slab when row-dedup shared storage across
    neurons — identical tables (CSE'd neurons replicated for consumers
    in different layers, duplicated output heads, constant neurons)
    point at one copy.  None = legacy contiguous layout: the group's
    tables sit back-to-back at the running flat offset.
    """

    n_out: int
    entry_bits: int
    offs: tuple[int, ...] | None = None


class MixedLayerMeta(NamedTuple):
    """Static per-layer shape metadata for the mixed-width kernel."""

    n_out: int
    fan_in: int
    groups: tuple[MixedGroupMeta, ...]


@dataclasses.dataclass(frozen=True)
class MixedNetworkSlabs:
    """A sparse stack packed at its exact compiled table footprint.

    ``out_perm`` is the static gather that undoes the final layer's
    group-sort: ``result[:, j] == kernel_bus[:, out_perm[j]]`` (None when
    the sort was the identity).  Intermediate layers need no fixup — the
    builder rewrote each layer's fan-in indices against its producer's
    permuted bus.
    """

    idx_slab: jax.Array      # (sum_l O_l, FI_max) int32
    shift_slab: jax.Array    # (sum_l O_l, FI_max) int32
    width_slab: jax.Array    # (sum_l O_l, FI_max) int32
    table_slab: jax.Array    # (1, sum_j 2^entry_bits_j) int32 | int8
    meta: tuple[MixedLayerMeta, ...]
    out_perm: tuple[int, ...] | None
    packed: bool
    # table entries elided by build-time row dedup (identical tables
    # share one stored copy); 0 when no duplicates existed or dedup was
    # off — the slab arrays are then byte-identical to the legacy layout
    dedup_entries_saved: int = 0

    @property
    def n_layers(self) -> int:
        return len(self.meta)

    @property
    def n_out(self) -> int:
        return self.meta[-1].n_out

    def vmem_bytes(self) -> int:
        return sum(s.size * s.dtype.itemsize
                   for s in (self.idx_slab, self.shift_slab,
                             self.width_slab, self.table_slab))

    def vmem_breakdown(self) -> dict:
        """Per-slab VMEM bytes (bench / fused-fallback diagnostics).

        ``table_slab_bytes`` is the headline: with ``packed_int8`` it
        equals the netlist's exact per-neuron ``table_bytes()`` accounting
        for codes <= 8 bits — the fused path banks byte-for-byte what the
        compiler proved.
        """
        idx = self.idx_slab.size * self.idx_slab.dtype.itemsize
        sh = self.shift_slab.size * self.shift_slab.dtype.itemsize
        wd = self.width_slab.size * self.width_slab.dtype.itemsize
        tab = self.table_slab.size * self.table_slab.dtype.itemsize
        return {"idx_slab_bytes": idx, "shift_slab_bytes": sh,
                "width_slab_bytes": wd, "table_slab_bytes": tab,
                "total_bytes": idx + sh + wd + tab,
                "packed_int8": self.packed, "layout": "mixed"}


def _mixed_lo_hi(layers) -> tuple[int, int]:
    lo = min((int(t.min()) for L in layers for t in L.tables if t.size),
             default=0)
    hi = max((int(t.max()) for L in layers for t in L.tables if t.size),
             default=0)
    return lo, hi


def estimate_mixed_slab_bytes(layers,
                              pack: bool | None = None
                              ) -> tuple[int, bool, bool]:
    """Projected mixed-slab footprint, int8-pack and f32-exact eligibility.

    ``layers`` is a ``MixedLayerTables`` sequence (``repro.compile``'s
    ``CNet.to_mixed_tables`` lowering).  The table slab costs exactly the
    stack's total table entries (1 or 4 bytes each); the metadata adds
    three (sum O, FI_max) int32 slabs (indices, shifts, widths).  Same
    contract as ``estimate_slab_bytes``: lets the plan machinery decide
    before any slab is built, with ``pack`` forcing the on/off choice
    (None auto-packs when every code fits a byte).  The estimate is a
    pre-dedup *upper bound*: ``build_mixed_network_slabs``'s row dedup
    can only shrink the table slab below it (by
    ``dedup_entries_saved`` entries), never exceed it.
    """
    o_sum = sum(L.indices.shape[0] for L in layers)
    fi_max = max(L.indices.shape[1] for L in layers)
    entries = sum(L.n_entries for L in layers)
    lo, hi = _mixed_lo_hi(layers)
    use_pack = _resolve_pack(lo >= 0 and hi < 256, pack)
    f32_exact = lo >= 0 and hi < 1 << 24
    return (3 * o_sum * fi_max * 4
            + entries * (1 if use_pack else 4)), use_pack, f32_exact


def build_mixed_network_slabs(layers, *, pack: bool | None = None,
                              dedup: bool = True) -> MixedNetworkSlabs:
    """Pack ``MixedLayerTables`` into compiler-exact fused slabs.

    Host-side (numpy).  Within each layer, neurons are stably sorted by
    entry count so equal-size tables form contiguous groups (one batched
    two-level gather each); the sort permutes the layer's output bus, so
    the next layer's fan-in indices are rewritten against the permuted
    order and only the final layer's permutation is kept (``out_perm``)
    for the kernel to undo.  ``pack`` follows ``build_network_slabs``:
    None auto-packs to int8 when every code fits a byte, True validates
    the byte range and raises instead of wrapping.

    ``dedup=True`` content-dedups identical table rows across the whole
    slab: neurons with byte-identical tables (same entry count, same
    codes) share one stored copy, with each group's per-neuron flat
    offsets recorded in ``MixedGroupMeta.offs`` for the kernel's static
    reconstruction.  This catches what netlist-level CSE cannot merge —
    same-function neurons wired to *different* input indices, and
    replicated final-layer heads — on top of compiler-merged neurons
    whose consumers span layers.  When no duplicate exists the layout
    (and the serialized artifact) is byte-identical to ``dedup=False``.
    """
    layers = list(layers)
    if not layers:
        raise ValueError("fused network needs at least one layer")
    lo, hi = _mixed_lo_hi(layers)
    if hi >= 1 << 24 or lo < 0:
        raise ValueError(
            "fused kernel gathers tables through exact f32 one-hot "
            "contractions; output codes must be in [0, 2^24) — use the "
            "per-layer path (fused=False) for wider codes")
    pack = _resolve_pack(lo >= 0 and hi < 256, pack)

    fi_max = max(L.indices.shape[1] for L in layers)
    layer_meta_rows = []           # (o, fi, group boundaries, flat offsets)
    idx_rows, shift_rows, width_rows, flat_parts = [], [], [], []
    seen: dict[tuple[int, bytes], int] = {}   # table content -> flat offset
    next_off = 0
    entries_total = 0
    any_dup = False
    inv_prev: np.ndarray | None = None   # prev bus: old feature -> new pos
    for L in layers:
        o = L.indices.shape[0]
        fi = L.indices.shape[1]
        idx = np.asarray(L.indices, dtype=np.int32)
        if inv_prev is not None:
            idx = inv_prev[idx].astype(np.int32)
        eb = np.asarray(L.entry_bits, dtype=np.int64)
        order = np.argsort(eb, kind="stable")
        idx = idx[order]
        shifts = np.asarray(L.shifts, dtype=np.int32)[order]
        widths = np.asarray(L.elem_widths, dtype=np.int32)[order]
        eb = eb[order]
        bounds = []
        start = 0
        for j in range(1, o + 1):
            if j == o or eb[j] != eb[start]:
                bounds.append((start, j, int(eb[start])))
                start = j
        offs = []
        for j, src in enumerate(order):
            t = np.asarray(L.tables[src], dtype=np.int32)
            if t.shape[0] != 1 << int(eb[j]):
                raise ValueError(
                    f"neuron table has {t.shape[0]} entries; its element "
                    f"widths sum to {int(eb[j])} bits and require "
                    f"2^{int(eb[j])}")
            entries_total += t.shape[0]
            off = seen.get((t.shape[0], t.tobytes())) if dedup else None
            if off is None:
                off = next_off
                if dedup:
                    seen[(t.shape[0], t.tobytes())] = off
                flat_parts.append(t)
                next_off += t.shape[0]
            else:
                any_dup = True
            offs.append(off)
        pad = np.zeros((o, fi_max - fi), dtype=np.int32)
        idx_rows.append(np.concatenate([idx, pad], axis=1))
        shift_rows.append(np.concatenate([shifts, pad], axis=1))
        width_rows.append(np.concatenate([widths, pad], axis=1))
        layer_meta_rows.append((o, fi, bounds, offs))
        inv_prev = np.argsort(order)
    # offs only materialize when a duplicate actually exists, so a
    # dup-free build stays byte-identical (slabs, meta, artifact) to the
    # legacy contiguous layout
    metas = tuple(
        MixedLayerMeta(o, fi, tuple(
            MixedGroupMeta(e - s, ebits,
                           tuple(offs[s:e]) if any_dup else None)
            for s, e, ebits in bounds))
        for o, fi, bounds, offs in layer_meta_rows)
    flat = np.concatenate(flat_parts)
    if pack:
        flat = flat.astype(np.uint8).view(np.int8)
    out_perm = (None if np.array_equal(inv_prev, np.arange(len(inv_prev)))
                else tuple(int(p) for p in inv_prev))
    return MixedNetworkSlabs(
        jnp.asarray(np.concatenate(idx_rows)),
        jnp.asarray(np.concatenate(shift_rows)),
        jnp.asarray(np.concatenate(width_rows)),
        jnp.asarray(flat[None, :]),
        metas, out_perm, bool(pack),
        dedup_entries_saved=entries_total - next_off)


def _mixed_kernel(codes_ref, idx_ref, shift_ref, width_ref, table_ref,
                  out_ref, *, meta: tuple[MixedLayerMeta, ...],
                  packed: bool, out_perm: tuple[int, ...] | None):
    h = codes_ref[...]                                       # (bb, I0)
    # Static unroll over layers and, within a layer, over equal-entry-count
    # neuron groups: each group reads its exact row/flat-offset slices (all
    # compile-time constants) and runs the same batched two-level gather as
    # the uniform kernel — activation codes never leave VMEM.
    row = 0
    flat = 0
    for m in meta:
        parts = []
        for g in m.groups:
            idx = idx_ref[row:row + g.n_out, :m.fan_in]
            sh = shift_ref[row:row + g.n_out, :m.fan_in]
            wd = width_ref[row:row + g.n_out, :m.fan_in]
            n_e = 1 << g.entry_bits
            if g.offs is None:
                table = table_ref[0, flat:flat + g.n_out * n_e].reshape(
                    g.n_out, n_e)
                flat += g.n_out * n_e
            else:
                # row-dedup layout: per-neuron static flat offsets.
                # Consecutive offsets (the common case — dedup leaves
                # most runs contiguous) are merged into single slices so
                # the unrolled program stays near the legacy size.
                blocks = []
                i = 0
                while i < len(g.offs):
                    j = i
                    while (j + 1 < len(g.offs)
                           and g.offs[j + 1] == g.offs[j] + n_e):
                        j += 1
                    blocks.append(
                        table_ref[0, g.offs[i]:g.offs[j] + n_e].reshape(
                            j - i + 1, n_e))
                    i = j + 1
                table = (blocks[0] if len(blocks) == 1
                         else jnp.concatenate(blocks, axis=0))
            if packed:
                table = table.astype(jnp.int32) & 0xFF
            entry = pack_fan_in_entries_mixed(h, idx, sh, wd)
            parts.append(_table_gather_two_level(entry, table,
                                                 g.entry_bits))
            row += g.n_out
        h = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if out_perm is not None:
        # undo the final layer's group-sort: a static column shuffle
        # (compile-time slice per output) — Pallas kernels cannot capture
        # array constants, and a dynamic gather would be the slow path on
        # TPU anyway
        h = jnp.concatenate([h[:, p:p + 1] for p in out_perm], axis=1)
    out_ref[...] = h


def lut_network_mixed_pallas(codes: jax.Array, slabs: MixedNetworkSlabs, *,
                             block_b: int = DEFAULT_BLOCK_B,
                             interpret: bool = False) -> jax.Array:
    """Whole sparse stack, compiler-exact slabs: (batch, I0) -> (batch, O)."""
    batch, n_in = codes.shape
    if batch == 0:
        # same empty-batch edge as lut_network_pallas: no kernel to launch
        return jnp.zeros((0, slabs.n_out), dtype=jnp.int32)
    o_sum, fi_max = slabs.idx_slab.shape
    t_total = slabs.table_slab.shape[1]
    block_b = min(block_b, batch)
    grid = (pl.cdiv(batch, block_b),)

    return pl.pallas_call(
        functools.partial(_mixed_kernel, meta=slabs.meta,
                          packed=slabs.packed, out_perm=slabs.out_perm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_in), lambda b: (b, 0)),
            pl.BlockSpec((o_sum, fi_max), lambda b: (0, 0)),
            pl.BlockSpec((o_sum, fi_max), lambda b: (0, 0)),
            pl.BlockSpec((o_sum, fi_max), lambda b: (0, 0)),
            pl.BlockSpec((1, t_total), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, slabs.n_out), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, slabs.n_out), jnp.int32),
        interpret=interpret,
    )(codes, slabs.idx_slab, slabs.shift_slab, slabs.width_slab,
      slabs.table_slab)
