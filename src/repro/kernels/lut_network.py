"""Pallas TPU kernel: fused whole-network LogicNets LUT inference.

The paper's deployment claim is that an extreme-throughput LogicNet *is* a
pipeline of LUTs: on the FPGA every layer's truth tables live in fabric and
an activation never leaves the chip between layers — that "no off-chip
round-trip" discipline is what buys sub-microsecond whole-network latency.
The per-layer ``lut_lookup`` kernel violates the analogy on TPU: each layer
is its own ``pallas_call``, so int32 activation codes bounce through HBM
between every pair of layers.

This kernel is the TPU transliteration of the FPGA pipeline.  All layers'
truth tables and fan-in indices are concatenated into two VMEM-resident
slabs (padded to the widest layer, with *static* per-layer shape metadata
compiled into the kernel), the grid runs over batch tiles only, and the
activation codes stay in registers/VMEM from network input to network
output — one ``pallas_call`` for the whole sparse stack, exactly as the
fabric holds the whole net.

Layout — layers are *row-stacked*, not padded to a (L, O_max, ...) box:

  * ``idx_slab``   (sum_l O_l, FI_max) int32 — layer l's fan-in indices in
    rows ``[row_off_l, row_off_l + O_l)``; padding is zero and never read
    (per-layer static slices, offsets compiled into the kernel).
  * ``table_slab`` (sum_l O_l, E_max) int32, or int8 when every layer's
    output codes fit a byte (``bw_out <= 8``).  Packed tables are widened
    in-kernel with a mask, quartering the VMEM footprint so deeper stacks
    stay under the budget that ``ops.lut_network`` enforces.

Row-stacking means a narrow layer costs only its own rows — heterogeneous
stacks (and stacks shrunk by ``repro.compile``'s dead-neuron elimination)
get proportionally smaller slabs, where the old box layout paid
``L * O_max`` rows regardless.

Per layer the fan-in gather is the same one-hot MXU contraction as
``lut_lookup``, but the table gather is upgraded from a streamed
compare/select to a *two-level one-hot gather* (see ``_layer_step``): the
bulk of the work becomes a batched matmul, which is where the fused
engine's measured speedup over the per-layer path comes from on top of
the saved HBM round trips.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lut_lookup import pack_fan_in_entries


class LayerMeta(NamedTuple):
    """Static per-layer shape metadata (compiled into the kernel)."""

    n_out: int
    fan_in: int
    n_entries: int
    bw_in: int


@dataclasses.dataclass(frozen=True)
class NetworkSlabs:
    """A whole sparse stack packed for single-kernel execution."""

    idx_slab: jax.Array      # (sum_l O_l, FI_max) int32
    table_slab: jax.Array    # (sum_l O_l, E_max) int32 | int8 (packed)
    meta: tuple[LayerMeta, ...]
    packed: bool

    @property
    def n_layers(self) -> int:
        return len(self.meta)

    @property
    def n_out(self) -> int:
        return self.meta[-1].n_out

    def vmem_bytes(self) -> int:
        return (self.idx_slab.size * self.idx_slab.dtype.itemsize
                + self.table_slab.size * self.table_slab.dtype.itemsize)

    def vmem_breakdown(self) -> dict:
        """Per-slab VMEM bytes (bench / fused-fallback diagnostics)."""
        idx = self.idx_slab.size * self.idx_slab.dtype.itemsize
        tab = self.table_slab.size * self.table_slab.dtype.itemsize
        return {"idx_slab_bytes": idx, "table_slab_bytes": tab,
                "total_bytes": idx + tab, "packed_int8": self.packed}


def estimate_slab_bytes(layers: Sequence[tuple]) -> tuple[int, bool, bool]:
    """Projected fused-slab footprint, int8-pack and f32-exact eligibility.

    Computed from shapes plus one pass of min/max over the tables (no
    copies) — lets ``ops.lut_network`` decide *before* paying for slab
    construction it would discard on the per-layer fallback path.  Returns
    ``(bytes, pack, f32_exact)``; ``f32_exact`` is False when any output
    code is outside [0, 2^24), where the kernel's f32 one-hot gather
    would round.
    """
    o_sum = sum(np.asarray(t).shape[0] for _, t, _ in layers)
    fi_max = max(np.asarray(i).shape[1] for i, _, _ in layers)
    e_max = max(np.asarray(t).shape[1] for _, t, _ in layers)
    lo_hi = [(int(np.min(t, initial=0)), int(np.max(t, initial=0)))
             for _, t, _ in layers]
    pack = all(lo >= 0 and hi < 256 for lo, hi in lo_hi)
    f32_exact = all(lo >= 0 and hi < 1 << 24 for lo, hi in lo_hi)
    table_itemsize = 1 if pack else 4
    return (o_sum * fi_max * 4
            + o_sum * e_max * table_itemsize), pack, f32_exact


def build_network_slabs(layers: Sequence[tuple], *,
                        pack: bool | None = None) -> NetworkSlabs:
    """Pack per-layer ``(indices, table, bw_in)`` triples into fused slabs.

    ``pack=None`` (auto) stores the table slab as int8 whenever every
    layer's output codes fit an unsigned byte — true for any LogicNets
    topology with ``bw_out <= 8``.  Host-side (numpy): tables come straight
    from ``LayerTruthTable`` generation.
    """
    if not layers:
        raise ValueError("fused network needs at least one layer")
    metas = []
    idx_np, tab_np = [], []
    for indices, table, bw_in in layers:
        idx = np.asarray(indices, dtype=np.int32)
        tab = np.asarray(table, dtype=np.int32)
        m = LayerMeta(tab.shape[0], idx.shape[1], tab.shape[1], int(bw_in))
        if m.n_entries != 1 << (m.fan_in * m.bw_in):
            raise ValueError(
                f"table has {m.n_entries} entries; fan_in={m.fan_in} at "
                f"bw_in={m.bw_in} requires 2^{m.fan_in * m.bw_in}")
        if int(tab.max(initial=0)) >= 1 << 24 or int(tab.min(initial=0)) < 0:
            raise ValueError(
                "fused kernel gathers tables through exact f32 one-hot "
                "contractions; output codes must be in [0, 2^24) — use the "
                "per-layer path (fused=False) for wider codes")
        metas.append(m)
        idx_np.append(idx)
        tab_np.append(tab)
    o_sum = sum(m.n_out for m in metas)
    fi_max = max(m.fan_in for m in metas)
    e_max = max(m.n_entries for m in metas)

    idx_slab = np.zeros((o_sum, fi_max), dtype=np.int32)
    if pack is None:
        pack = all(int(t.max(initial=0)) < 256 and int(t.min(initial=0)) >= 0
                   for t in tab_np)
    tab_dtype = np.int8 if pack else np.int32
    table_slab = np.zeros((o_sum, e_max), dtype=tab_dtype)
    row = 0
    for idx, tab, m in zip(idx_np, tab_np, metas):
        idx_slab[row:row + m.n_out, :m.fan_in] = idx
        table_slab[row:row + m.n_out, :m.n_entries] = (
            tab.astype(np.uint8).view(np.int8) if pack else tab)
        row += m.n_out
    return NetworkSlabs(jnp.asarray(idx_slab), jnp.asarray(table_slab),
                        tuple(metas), bool(pack))


def _layer_step(h: jax.Array, idx: jax.Array, table: jax.Array,
                bw_in: int) -> jax.Array:
    """One LUT layer on in-register codes: (bb, I) -> (bb, O).

    Unlike the per-layer ``lut_lookup`` kernel (which streams an
    elementwise compare/select over all table entries), the table gather
    here splits the packed entry index into low/high halves: the low half
    is gathered with one *batched matmul* against its one-hot (MXU work),
    which collapses the entry axis from E to sqrt(E); the high half then
    costs only an O(B*O*sqrt(E)) elementwise select.  Same exact result —
    one-hot contractions on small ints are exact in f32 — at matmul
    throughput instead of compare/select throughput.
    """
    bo, fan_in = idx.shape
    n_entries = table.shape[1]

    entry = pack_fan_in_entries(h, idx, bw_in)               # (bo, bb)

    # two-level one-hot gather: entry = hi * n_lo + lo
    ent_bits = fan_in * bw_in
    lo_bits = ent_bits // 2
    n_lo = 1 << lo_bits
    n_hi = n_entries // n_lo
    lo = entry & (n_lo - 1)
    hi = entry >> lo_bits

    lo_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_lo), 1)[0]
    oh_lo = (lo[:, :, None] == lo_iota[None, None, :]).astype(jnp.float32)
    # (bo, n_hi, n_lo) x (bo, bb, n_lo) -> (bo, n_hi, bb), batched over bo
    part = jax.lax.dot_general(
        table.astype(jnp.float32).reshape(bo, n_hi, n_lo), oh_lo,
        (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32)

    hi_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_hi), 1)[0]
    oh_hi = (hi[:, :, None] == hi_iota[None, None, :])       # (bo, bb, n_hi)
    out = jnp.sum(jnp.where(jnp.transpose(oh_hi, (0, 2, 1)), part, 0.0),
                  axis=1)                                    # (bo, bb)
    return out.astype(jnp.int32).T                           # (bb, bo)


def _kernel(codes_ref, idx_ref, table_ref, out_ref, *,
            meta: tuple[LayerMeta, ...], packed: bool):
    h = codes_ref[...]                                       # (bb, I0)
    # Static unroll: each layer reads its (unpadded) row-slice of the slabs
    # and hands its output codes straight to the next layer — no HBM in
    # between.  Row offsets are compile-time constants.
    row = 0
    for m in meta:
        idx = idx_ref[row:row + m.n_out, :m.fan_in]
        table = table_ref[row:row + m.n_out, :m.n_entries]
        if packed:
            table = table.astype(jnp.int32) & 0xFF
        h = _layer_step(h, idx, table, m.bw_in)
        row += m.n_out
    out_ref[...] = h


def lut_network_pallas(codes: jax.Array, slabs: NetworkSlabs, *,
                       block_b: int = 128,
                       interpret: bool = False) -> jax.Array:
    """Whole sparse stack in one kernel: (batch, I0) -> (batch, O_last)."""
    batch, n_in = codes.shape
    o_sum, fi_max = slabs.idx_slab.shape
    e_max = slabs.table_slab.shape[1]
    block_b = min(block_b, batch)
    grid = (pl.cdiv(batch, block_b),)

    return pl.pallas_call(
        functools.partial(_kernel, meta=slabs.meta, packed=slabs.packed),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n_in), lambda b: (b, 0)),
            pl.BlockSpec((o_sum, fi_max), lambda b: (0, 0)),
            pl.BlockSpec((o_sum, e_max), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, slabs.n_out), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, slabs.n_out), jnp.int32),
        interpret=interpret,
    )(codes, slabs.idx_slab, slabs.table_slab)
