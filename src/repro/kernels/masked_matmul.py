"""Pallas TPU kernel: fan-in-masked matmul (LogicNets training hot path).

y = x @ (w * mask) + b with a per-neuron binary mask.  The mask multiply
happens on the (bk, bn) weight tile already resident in VMEM, so the MXU
sees an ordinary dense matmul — per-neuron sparsity costs no matmul
throughput (the paper's LUT-cost model prices fan-in, not FLOPs; on TPU the
fan-in mask is free compute-wise and we keep MXU alignment instead).

Grid (m, n, k) with a VMEM fp32 accumulator scratch; K is the innermost
(sequential) axis.  Block sizes default to MXU-aligned 128/128/512.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, mask_ref, b_ref, out_ref, acc_ref, *,
            n_k: int, k_dim: int, block_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Zero the K padding of the last block (OOB tile regions are undefined).
    kpos = k * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_k, 1), 0)
    valid = kpos < k_dim
    x = jnp.where(valid.T, x_ref[...], 0)
    wm = jnp.where(valid, w_ref[...] * mask_ref[...], 0)
    acc_ref[...] += jax.lax.dot(x, wm, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        out_ref[...] = (acc_ref[...] + b_ref[...]).astype(out_ref.dtype)


def masked_matmul_pallas(x: jax.Array, w: jax.Array, mask: jax.Array,
                         b: jax.Array | None = None, *,
                         block_m: int = 128, block_n: int = 128,
                         block_k: int = 512,
                         interpret: bool = False) -> jax.Array:
    """x (M, K) @ (w * mask) (K, N) + b (N,) -> (M, N)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and mask.shape == w.shape
    if b is None:
        b = jnp.zeros((n,), x.dtype)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    grid = (pl.cdiv(m, block_m), pl.cdiv(n, block_n), pl.cdiv(k, block_k))

    return pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2], k_dim=k, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w, mask, b)
