"""Jit'd public wrappers around the Pallas kernels.

On a TPU backend the kernels compile natively; elsewhere (this CPU
container) they run through the Pallas interpreter, which executes the
kernel body in Python for correctness validation — tests sweep shapes and
dtypes against the ref.py oracles either way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lut_lookup import lut_lookup_pallas
from repro.kernels.masked_matmul import masked_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("bw_in", "use_pallas"))
def lut_lookup(codes: jax.Array, indices: jax.Array, table: jax.Array,
               bw_in: int, use_pallas: bool = True) -> jax.Array:
    """LogicNets LUT-layer inference: (B, I) codes -> (B, O) codes."""
    if not use_pallas:
        return ref.lut_lookup_ref(codes, indices, table, bw_in)
    return lut_lookup_pallas(codes, indices, table, bw_in,
                             interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def masked_matmul(x: jax.Array, w: jax.Array, mask: jax.Array,
                  b: jax.Array | None = None,
                  use_pallas: bool = True) -> jax.Array:
    """y = x @ (w * mask) + b."""
    if not use_pallas:
        return ref.masked_matmul_ref(x, w, mask, b)
    return masked_matmul_pallas(x, w, mask, b, interpret=not _on_tpu())


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "use_pallas"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int | None = None,
                    use_pallas: bool = True) -> jax.Array:
    """Blocked attention; GQA via Hq % Hkv == 0."""
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=not _on_tpu())
