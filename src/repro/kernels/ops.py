"""Jit'd public wrappers around the Pallas kernels.

On a TPU backend the kernels compile natively; elsewhere (this CPU
container) they run through the Pallas interpreter, which executes the
kernel body in Python for correctness validation — tests sweep shapes and
dtypes against the ref.py oracles either way.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lut_lookup import DEFAULT_BLOCK_B, lut_lookup_pallas
from repro.kernels.masked_matmul import masked_matmul_pallas
# the fused-path costing lives in repro.kernels.plan since the
# ExecutionPlan refactor; re-exported here so long-standing importers
# (`from repro.kernels.ops import fused_plan`) keep working
from repro.kernels.plan import (FUSED_VMEM_BUDGET_BYTES,  # noqa: F401
                                FusedPlan, fused_plan)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("bw_in", "use_pallas", "block_b"))
def lut_lookup(codes: jax.Array, indices: jax.Array, table: jax.Array,
               bw_in: int, use_pallas: bool = True,
               block_b: int = DEFAULT_BLOCK_B) -> jax.Array:
    """LogicNets LUT-layer inference: (B, I) codes -> (B, O) codes.

    Jit'd with a shape/static-arg cache: repeated calls on the same layer
    shapes reuse the traced kernel — which is why ``lut_network``'s
    per-layer fallback must route through this wrapper rather than calling
    ``lut_lookup_pallas`` directly (the bare call re-traces every layer on
    every invocation).
    """
    if not use_pallas:
        return ref.lut_lookup_ref(codes, indices, table, bw_in)
    return lut_lookup_pallas(codes, indices, table, bw_in, block_b=block_b,
                             interpret=not _on_tpu())


def lut_network(codes: jax.Array, layers, *, fused: bool = True,
                use_pallas: bool = True, block_b: int = DEFAULT_BLOCK_B,
                vmem_budget_bytes: int = FUSED_VMEM_BUDGET_BYTES,
                optimize_level: int | None = None) -> jax.Array:
    """Whole sparse-stack LUT inference: (B, I0) codes -> (B, O_last) codes.

    ``layers`` is a sequence of ``(indices, table, bw_in)`` triples, one per
    sparse layer (exactly ``LayerTruthTable``'s fields).  With ``fused``
    the stack runs as a single ``pallas_call`` (activations never leave
    VMEM) when the concatenated slabs fit ``vmem_budget_bytes``; otherwise
    — and always when ``fused=False`` — it falls back to one
    ``lut_lookup`` call per layer.  Both paths are bit-exact with the
    ``table_infer.network_table_forward`` reference semantics.

    ``optimize_level`` (0-3) runs the truth-table compiler
    (``repro.compile``) over the stack first: smaller slabs mean stacks
    that used to overflow ``vmem_budget_bytes`` can take the fused path,
    and the output stays bit-identical on every reachable input.  The
    fused path then consumes the compiler's *mixed-width* lowering
    (``CNet.to_mixed_tables``) directly — per-(neuron, element) shift
    slabs and exact ``2^(sum of input widths)``-entry tables, so
    dead-input pruning and level-3 re-encoding bank their full table-byte
    savings as VMEM instead of being padded back to each bus's widest
    feature.

    This is now a thin compatibility wrapper over the serving engine
    (``repro.engine.compile_network``): the compile/cost/build/jit
    decision runs once and is memoized keyed on the layer arrays'
    *identity* plus the flags, so repeated calls with the same layers —
    the legacy serving-loop pattern — reuse the cached artifact instead
    of silently recompiling every call.  New code should hold the
    ``CompiledLUTNet`` directly (and ``save``/``load`` it for
    deployment); callers that mutate a table array in place must call
    ``repro.engine.cache_clear()`` to avoid stale results.

    Example::

        import numpy as np
        from repro.kernels.ops import lut_network
        rng = np.random.default_rng(0)
        idx = np.stack([np.sort(rng.choice(6, 2, replace=False))
                        for _ in range(4)]).astype(np.int32)
        tab = rng.integers(0, 4, (4, 16), dtype=np.int32)
        codes = rng.integers(0, 4, (3, 6), dtype=np.int32)
        out = lut_network(codes, [(idx, tab, 2)], fused=True)
        assert out.shape == (3, 4)
    """
    from repro import engine
    eng = engine.cached_compile(layers, optimize_level=optimize_level,
                                in_features=int(codes.shape[-1]),
                                fused=fused, use_pallas=use_pallas,
                                block_b=block_b,
                                vmem_budget_bytes=vmem_budget_bytes)
    return eng(codes)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def masked_matmul(x: jax.Array, w: jax.Array, mask: jax.Array,
                  b: jax.Array | None = None,
                  use_pallas: bool = True) -> jax.Array:
    """y = x @ (w * mask) + b."""
    if not use_pallas:
        return ref.masked_matmul_ref(x, w, mask, b)
    return masked_matmul_pallas(x, w, mask, b, interpret=not _on_tpu())


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "use_pallas"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int | None = None,
                    use_pallas: bool = True) -> jax.Array:
    """Blocked attention; GQA via Hq % Hkv == 0."""
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=not _on_tpu())
