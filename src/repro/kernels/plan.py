"""Execution-plan variants: the enumerable space the engine autotunes over.

The paper prices topologies by worst-case hardware cost before synthesis;
the software analogue of that choice is which *implementation* of a
LUT-network stack to run — mixed vs uniform slabs vs the per-layer
fallback, which batch tile ``block_b``, table slab packed to int8 or kept
int32.  Historically the engine answered with a static byte estimate
(``fused_plan``) plus scattered ``block_b=128`` defaults; this module
makes the space first-class:

* :class:`FusedPlan` — the byte/eligibility costing of one layout (moved
  here from ``ops.py``; ``ops`` re-exports it unchanged);
* :class:`PlanVariant` — one point in the variant space: a layout, a
  ``block_b`` and a pack choice, carrying its :class:`FusedPlan` cost;
* :func:`enumerate_variants` — every VMEM-eligible variant for a stack,
  each buildable through the existing slab builders;
* :func:`default_variant` — the heuristic ladder (mixed if eligible, else
  uniform if eligible, else per-layer) at :data:`DEFAULT_BLOCK_B`, i.e.
  exactly what ``engine.compile_network`` picks without autotuning.

``repro.engine.autotune`` times each variant's jitted forward and persists
the winner in the artifact as an ``ExecutionPlan``; this module stays
host-side and cheap (shape arithmetic plus one min/max pass per layout —
no slabs are built here).
"""

from __future__ import annotations

import dataclasses

from repro.kernels.lut_lookup import DEFAULT_BLOCK_B
from repro.kernels.lut_network import (estimate_mixed_slab_bytes,
                                       estimate_slab_bytes)

# block_b sweep the autotuner explores by default (the engine adds the
# caller's requested block_b to this set when it differs)
DEFAULT_BLOCK_BS = (64, 128, 256)

# Fused-network slab budget: the whole stack's tables + indices must sit in
# VMEM alongside a batch tile of codes and the per-layer scratch.  ~16 MB
# per core; keep the slabs under half of it and leave the rest to the
# compiler (same conservatism as the lut_lookup tile sizing).
FUSED_VMEM_BUDGET_BYTES = 8 * 2 ** 20


@dataclasses.dataclass(frozen=True)
class FusedPlan:
    """Why ``lut_network`` will (or won't) take the fused single-kernel path.

    ``reason`` is one of ``"fused"`` (eligible), ``"slab_exceeds_vmem_budget"``
    or ``"codes_exceed_f32_exact_range"`` — the two fallback causes the
    kernel enforces — or ``"fused_disabled"`` when the caller explicitly
    opted out (``fused=False`` / ``use_pallas=False``; the serving
    engine records the decision that was actually made, not just
    eligibility).  ``"per_layer_variant"`` marks the autotuner's
    per-layer candidate enumerated *alongside* eligible fused layouts
    (fell back by measurement, not by constraint).  ``layout`` records
    which slab layout was costed: ``"uniform"`` for
    ``(indices, table, bw_in)`` triples, ``"mixed"`` for the compiler's
    compact ``MixedLayerTables`` lowering (whose table slab holds exactly
    ``2^(sum of input widths)`` entries per neuron, so stacks that
    overflow the budget uniformly can still fuse).  The bench records
    this next to its timings so a regression gate can tell "fused fell
    back" apart from "fused got slower" (see benchmarks/kernel_bench.py).
    """

    fused: bool
    reason: str
    slab_bytes: int
    vmem_budget_bytes: int
    pack: bool
    f32_exact: bool
    layout: str = "uniform"

    def as_dict(self) -> dict:
        # headroom rides along so artifact consumers get the slab-vs-budget
        # breakdown from the one authoritative record
        return {**dataclasses.asdict(self),
                "headroom_bytes": self.vmem_budget_bytes - self.slab_bytes}

    @classmethod
    def from_dict(cls, d: dict) -> "FusedPlan":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


def fused_plan(layers, vmem_budget_bytes: int = FUSED_VMEM_BUDGET_BYTES,
               *, pack: bool | None = None) -> FusedPlan:
    """Evaluate the fused-path eligibility gate without building slabs.

    The single source of truth for the decision ``lut_network`` makes:
    projected slab bytes must fit the VMEM budget and every output code
    must be exact under the kernel's f32 one-hot gathers.  ``layers`` is
    either the uniform ``(indices, table, bw_in)`` triple list or the
    compiler's ``MixedLayerTables`` lowering (``CNet.to_mixed_tables``);
    the latter is costed at its exact compact footprint, which is what
    lets compiler-shrunk stacks that would overflow the budget uniformly
    become fused-eligible.  ``pack`` forces the int8 table-slab choice
    when given (None auto-packs) — :func:`enumerate_variants` uses this
    to price pack on/off as separate variants.

    Example::

        import numpy as np
        from repro.kernels.ops import fused_plan
        idx = np.zeros((4, 2), np.int32)            # 4 neurons, fan-in 2
        tab = np.zeros((4, 16), np.int32)           # bw=2: 2**(2*2) entries
        plan = fused_plan([(idx, tab, 2)])
        assert plan.fused and plan.reason == "fused"
        assert plan.layout == "uniform" and plan.slab_bytes > 0
    """
    layers = list(layers)
    mixed = bool(layers) and hasattr(layers[0], "entry_bits")
    estimate = estimate_mixed_slab_bytes if mixed else estimate_slab_bytes
    est_bytes, use_pack, f32_exact = estimate(layers, pack)
    if not f32_exact:
        fused, reason = False, "codes_exceed_f32_exact_range"
    elif est_bytes > vmem_budget_bytes:
        fused, reason = False, "slab_exceeds_vmem_budget"
    else:
        fused, reason = True, "fused"
    return FusedPlan(fused, reason, est_bytes, vmem_budget_bytes,
                     use_pack, f32_exact, "mixed" if mixed else "uniform")


@dataclasses.dataclass(frozen=True)
class PlanVariant:
    """One point in the execution-strategy space: layout x block_b x pack.

    ``layout`` is ``"mixed"``, ``"uniform"`` or ``"per_layer"`` (the
    engine additionally uses ``"reference"`` for its jnp oracle path —
    never enumerated here).  ``cost`` is the variant's byte/eligibility
    record; for ``per_layer`` it carries the uniform costing with
    ``fused=False`` so the fallback's *reason* survives in the artifact.
    ``key`` is the stable human-readable identity the autotuner's timing
    table and the bench are keyed on, e.g. ``"mixed/b128/packed"``.
    """

    layout: str
    block_b: int
    pack: bool
    cost: FusedPlan

    @property
    def key(self) -> str:
        return (f"{self.layout}/b{self.block_b}/"
                f"{'packed' if self.pack else 'unpacked'}")

    def as_dict(self) -> dict:
        return {"layout": self.layout, "block_b": self.block_b,
                "pack": self.pack, "cost": self.cost.as_dict()}

    @classmethod
    def from_dict(cls, d: dict) -> "PlanVariant":
        return cls(layout=str(d["layout"]), block_b=int(d["block_b"]),
                   pack=bool(d["pack"]),
                   cost=FusedPlan.from_dict(d["cost"]))


def enumerate_variants(uniform_triples=None, mixed_tables=None, *,
                       block_bs=DEFAULT_BLOCK_BS,
                       vmem_budget_bytes: int = FUSED_VMEM_BUDGET_BYTES,
                       include_per_layer: bool = True
                       ) -> tuple[PlanVariant, ...]:
    """Every buildable variant for a stack, in deterministic order.

    For each available layout (``mixed_tables`` when the compiler lowering
    exists, ``uniform_triples`` always) the auto-pack costing is computed
    once; pack=False is additionally enumerated when auto-pack chose int8
    (the unpacked slab trades VMEM for skipping the in-kernel widen), and
    each eligible (layout, pack) is crossed with every ``block_bs`` tile.
    Ineligible fused combinations (budget / f32-exactness) are dropped;
    the per-layer fallback is always enumerable and closes the space, so
    the result is non-empty whenever ``uniform_triples`` is given.
    """
    variants: list[PlanVariant] = []
    pools = []
    if mixed_tables is not None:
        pools.append(list(mixed_tables))
    if uniform_triples is not None:
        pools.append(list(uniform_triples))
    for layers in pools:
        auto = fused_plan(layers, vmem_budget_bytes)
        packs = [auto.pack] + ([False] if auto.pack else [])
        for p in packs:
            plan = (auto if p == auto.pack
                    else fused_plan(layers, vmem_budget_bytes, pack=p))
            if not plan.fused:
                continue
            for bb in block_bs:
                variants.append(PlanVariant(plan.layout, int(bb), p, plan))
    if include_per_layer and uniform_triples is not None:
        base = fused_plan(list(uniform_triples), vmem_budget_bytes)
        cost = dataclasses.replace(
            base, fused=False,
            reason=base.reason if not base.fused else "per_layer_variant")
        for bb in block_bs:
            variants.append(PlanVariant("per_layer", int(bb), False, cost))
    return tuple(variants)


def default_variant(uniform_triples=None, mixed_tables=None, *,
                    block_b: int = DEFAULT_BLOCK_B,
                    vmem_budget_bytes: int = FUSED_VMEM_BUDGET_BYTES
                    ) -> PlanVariant:
    """The heuristic choice ``engine.compile_network`` makes without
    autotuning: mixed if eligible, else uniform if eligible, else the
    per-layer fallback — at the requested ``block_b`` with auto pack."""
    if mixed_tables is not None:
        plan = fused_plan(list(mixed_tables), vmem_budget_bytes)
        if plan.fused:
            return PlanVariant("mixed", int(block_b), plan.pack, plan)
    if uniform_triples is None:
        raise ValueError("default_variant needs uniform_triples when the "
                         "mixed lowering is absent or ineligible")
    plan = fused_plan(list(uniform_triples), vmem_budget_bytes)
    if plan.fused:
        return PlanVariant("uniform", int(block_b), plan.pack, plan)
    return PlanVariant("per_layer", int(block_b), False,
                       dataclasses.replace(plan, fused=False))
