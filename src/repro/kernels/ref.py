"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lut_lookup_ref(codes: jax.Array, indices: jax.Array, table: jax.Array,
                   bw_in: int) -> jax.Array:
    """LogicNets LUT-layer inference.

    codes:   (batch, in_features) int32 input activation codes
    indices: (out_features, fan_in) int32 fan-in feature ids per neuron
    table:   (out_features, 2^(fan_in*bw_in)) int32 output codes
    returns: (batch, out_features) int32
    """
    gathered = codes[:, indices]                        # (B, O, FI)
    shifts = bw_in * jnp.arange(indices.shape[1], dtype=jnp.int32)
    entry = jnp.sum(gathered << shifts[None, None, :], axis=-1)  # (B, O)
    return jnp.take_along_axis(table[None], entry[:, :, None], axis=2)[..., 0]


def masked_matmul_ref(x: jax.Array, w: jax.Array, mask: jax.Array,
                      b: jax.Array | None = None) -> jax.Array:
    """Fan-in-masked linear: y = x @ (w * mask) (+ b)."""
    y = jnp.dot(x, w * mask, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    return y.astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int | None = None,
                        scale: float | None = None) -> jax.Array:
    """Plain softmax attention with GQA head sharing.

    q: (B, Hq, S, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0.
    ``window`` (if set) keeps only the last ``window`` keys per query
    (sliding-window / local attention, gemma3-style).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)
