import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import — jax locks the device
count on first init, and the dry-run needs 512 host devices to build the
production meshes ((16,16) single-pod, (2,16,16) multi-pod).

Per cell this script:
  1. builds abstract params/optimizer/cache (ShapeDtypeStruct — nothing is
     allocated),
  2. resolves sharding rules against the mesh,
  3. ``jit(step).lower(...).compile()`` — a failure here (sharding
     mismatch, OOM at compile, unsupported collective) is a bug in the
     system, not in the script,
  4. records memory_analysis / cost_analysis / collective bytes into a
     JSON artifact for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
  variants: --policy tp_only | --moe-dispatch sorted | --remat none|dots
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, cell_skip, get_config
from repro.launch import steps as S
from repro.launch.hlo_stats import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.parallel import sharding as SH
from repro.parallel.ctx import activation_sharding


def _apply_variants(cfg, args, scan_unroll: int = 1):
    changes = {"scan_unroll": scan_unroll, "attn_unroll": True}
    if args.moe_dispatch and cfg.moe is not None:
        changes["moe"] = dataclasses.replace(cfg.moe,
                                             dispatch=args.moe_dispatch)
    if args.remat:
        changes["remat"] = args.remat
    if args.attn_chunk:
        changes["attn_chunk"] = args.attn_chunk
    if args.cache_update:
        changes["cache_update"] = args.cache_update
    if args.logicnet_ffn:
        from repro.models.config import LogicNetFFNCfg
        changes["logicnet_ffn"] = LogicNetFFNCfg(fan_in=64, bw=4,
                                                 max_val=4.0)
    return dataclasses.replace(cfg, **changes)


def run_cell(arch: str, shape_name: str, multi_pod: bool, args,
             scan_unroll: int = 1) -> dict:
    cfg = _apply_variants(get_config(arch), args, scan_unroll)
    cell = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": args.variant, "kind": cell.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "scan_unroll": scan_unroll,
        "scan_length": cfg.scan_length,
        "fit_unroll": cfg.fit_unroll,
    }
    skip = cell_skip(cfg, shape_name)
    if skip:
        record["status"] = "skipped"
        record["skip_reason"] = skip
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = (SH.multi_pod_policy(args.policy) if multi_pod
              else SH.ShardingPolicy(mode=args.policy))
    n_chips = mesh.devices.size
    record["chips"] = n_chips

    specs = S.input_specs(cfg, cell)
    t0 = time.time()
    with activation_sharding(mesh, SH.activation_rules(policy)):
        if cell.kind == "train":
            state = S.abstract_train_state(cfg)
            state_sh = SH.shardings_for_tree(state, mesh, policy)
            batch_sh = SH.batch_specs(policy, mesh, specs["batch"])
            step = S.make_train_step(
                cfg,
                grad_shardings=state_sh["params"] if args.grad_rs
                else None)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None))
            lowered = jitted.lower(state, specs["batch"])
        elif cell.kind == "prefill":
            params = S.abstract_params(cfg)
            params_sh = SH.shardings_for_tree(params, mesh, policy)
            batch_sh = SH.batch_specs(policy, mesh, specs["batch"])
            step = S.make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params, specs["batch"])
        else:  # decode
            params = S.abstract_params(cfg)
            params_sh = SH.shardings_for_tree(params, mesh, policy)
            cache_sh = SH.cache_specs(policy, mesh, specs["cache"],
                                      cache_shard=args.cache_shard)
            tok_sh = SH.batch_specs(policy, mesh,
                                    {"tokens": specs["tokens"],
                                     "pos": specs["pos"]})
            step = S.make_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, tok_sh["tokens"],
                              tok_sh["pos"]),
                out_shardings=(None, cache_sh))
            lowered = jitted.lower(params, specs["cache"],
                                   specs["tokens"], specs["pos"])
        record["lower_s"] = round(time.time() - t0, 1)

        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        record["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "generated_code_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
    cost = compiled.cost_analysis() or {}
    record["cost"] = {k: cost.get(k) for k in
                      ("flops", "bytes accessed", "transcendentals",
                       "optimal_seconds") if k in cost}
    hlo = compiled.as_text()
    record["collectives"] = collective_bytes(hlo)
    record["hlo_kib"] = len(hlo) // 1024
    record["status"] = "ok"
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name} "
          f"({args.variant}): OK  "
          f"flops={record['cost'].get('flops', 0):.3e}  "
          f"coll={record['collectives']['total']:.3e}B  "
          f"compile={record['compile_s']}s")
    print("  memory:", record.get("memory"))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--policy", default="fsdp_tp",
                    choices=["fsdp_tp", "tp_only"])
    ap.add_argument("--moe-dispatch", default=None,
                    choices=[None, "dense", "sorted", "sorted_local"])
    ap.add_argument("--remat", default=None,
                    choices=[None, "none", "dots", "full"])
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--cache-update", default=None,
                    choices=[None, "onehot", "dus"])
    ap.add_argument("--cache-shard", default="heads",
                    choices=["heads", "seq"])
    ap.add_argument("--grad-rs", action="store_true",
                    help="constrain grads to param shardings "
                         "(reduce-scatter instead of all-reduce)")
    ap.add_argument("--logicnet-ffn", action="store_true",
                    help="swap FFNs for the paper's sparse-quantized "
                         "LogicNet-FFN (the technique cell)")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--fit", action="store_true",
                    help="also compile at scan_unroll=u2 for the "
                         "two-point while-loop cost fit")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose artifact is already ok")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                base = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                if args.variant != "baseline":
                    base += f"__{args.variant}"
                unrolls = [1]
                if args.fit:
                    from repro.configs import get_config as _gc
                    unrolls.append(_gc(arch).fit_unroll)
                for u in unrolls:
                    tag = base + (f"__u{u}" if u > 1 else "")
                    path = os.path.join(args.out, tag + ".json")
                    if args.resume and os.path.exists(path):
                        with open(path) as f:
                            if json.load(f).get("status") in ("ok",
                                                              "skipped"):
                                continue
                    try:
                        rec = run_cell(arch, shape, mp, args,
                                       scan_unroll=u)
                    except Exception as e:  # a failure = a system bug
                        failures += 1
                        rec = {"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "variant": args.variant, "scan_unroll": u,
                               "status": "FAILED", "error": repr(e),
                               "traceback": traceback.format_exc()}
                        print(f"[dryrun] {tag}: FAILED {e!r}")
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=2)
                    if rec.get("status") == "skipped":
                        break  # no point re-running the skip at u2
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
