"""Parse collective traffic out of post-partitioning HLO text.

``cost_analysis()`` has no collective-bytes entry, so we regex the compiled
module: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op contributes its *result* buffer size (shapes in the
SPMD-partitioned module are already per-device).  This approximates wire
bytes per device per step: exact for all-to-all/permute, the standard
ring-factor 2(n-1)/n of an all-reduce is folded into the reported number
via the per-type multipliers below.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# all-reduce moves ~2x the buffer on a ring (reduce-scatter + all-gather);
# the others move ~1x their result.
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64"
                       r"|f64)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(result_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result_text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-collective-type wire bytes (per device) + 'total'."""
    seen_done: set[str] = set()
    out: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        result_text, kind = m.group(1), m.group(2)
        # -done ops restate the -start result; count each pair once.
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if f"{kind}-done" in line:
            continue
        b = _shape_bytes(result_text) * _WIRE_FACTOR[kind]
        out[kind] += b
        counts[kind] += 1
    out["total"] = float(sum(v for k, v in out.items() if k != "total"))
    out.update({f"n_{k}": float(v) for k, v in counts.items()})
    return dict(out)


def op_histogram(hlo_text: str, ops: tuple[str, ...]) -> dict[str, int]:
    """Count occurrences of op kinds (fusion/reshape/transpose audits)."""
    return {op: len(re.findall(rf"\b{re.escape(op)}\(", hlo_text))
            for op in ops}
