"""Production mesh definitions (the brief's MULTI-POD DRY-RUN step 1).

Functions, not module-level constants — importing this module never touches
jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
