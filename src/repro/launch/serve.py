"""Serving launcher: ``python -m repro.launch.serve``.

Two serving stacks behind one CLI:

* ``--lut`` — the LogicNets deployment path: load (or compile) a
  ``repro.engine.CompiledLUTNet`` and drive it through the
  ``repro.serve`` micro-batching tier under closed-loop load, reporting
  steady-state p50/p99 latency, QPS, batch occupancy and the compile-once
  counters (see docs/serving.md).  This is the CLI face of the bench's
  gated ``serving_tier`` section::

      # compile generated fpga4hep model A at level 3 and serve it
      python -m repro.launch.serve --lut

      # serve a saved artifact (e.g. CI's ENGINE_model_a.npz)
      python -m repro.launch.serve --lut --artifact model_a.npz

      # quick smoke (CI / drift tests)
      python -m repro.launch.serve --lut --smoke

      # put the HTTP ingress in front (0 = ephemeral port) and serve
      # until SIGTERM; add a per-tenant row quota
      python -m repro.launch.serve --lut --http 8080 --tenant-quota 500:1000

      # open-loop (Poisson-arrival) load instead of closed-loop
      python -m repro.launch.serve --lut --open-loop 300

  ``--http`` + ``--smoke`` (or ``--open-loop``) drives open-loop load
  *through* a localhost ingress and verifies responses bit-exact — the
  end-to-end path CI's ingress-smoke step runs (see docs/ingress.md).

* default (no ``--lut``) — the big-model demo: mesh-aware batched LM
  decode, params + caches sharded per parallel/sharding.py, decode step
  jitted with in/out shardings (same core as examples/serve_lm.py).
"""

from __future__ import annotations

import argparse
import json
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np


def _run_lm(args: argparse.Namespace) -> None:
    """Mesh-aware batched LM decode demo (the pre-LUT serving loop)."""
    from repro.configs import get_config, get_smoke_config
    from repro.launch import steps as S
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.parallel import sharding as SH
    from repro.parallel.ctx import activation_sharding

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    mesh = make_host_mesh(model=args.model_parallel)
    policy = SH.ShardingPolicy()

    with activation_sharding(mesh, SH.activation_rules(policy)):
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        params_sh = SH.shardings_for_tree(params, mesh, policy)
        params = jax.device_put(params, params_sh)
        cache = M.init_cache(cfg, args.slots, args.cache_len)
        cache_sh = SH.cache_specs(policy, mesh, jax.eval_shape(
            lambda: cache))
        cache = jax.device_put(cache, cache_sh)
        step = jax.jit(S.make_decode_step(cfg),
                       in_shardings=(params_sh, cache_sh, None, None),
                       out_shardings=(None, cache_sh))

        tok = jnp.ones((args.slots, 1), jnp.int32)
        pos = jnp.zeros((args.slots,), jnp.int32)
        t0 = time.perf_counter()
        for _ in range(args.steps):
            logits, cache = step(params, cache, tok, pos)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            pos = pos + 1
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
    print(f"[serve] {cfg.arch_id}: {args.steps} decode steps x "
          f"{args.slots} slots on mesh {dict(mesh.shape)} "
          f"({1e3 * dt / args.steps:.1f} ms/step)")


def _lut_artifact(args: argparse.Namespace):
    """Load ``--artifact`` or compile generated fpga4hep model A."""
    from repro import engine

    if args.artifact:
        net = engine.load(args.artifact)
        print(f"[serve --lut] loaded {args.artifact}: layout={net.layout} "
              f"n_in={net.n_in} n_out={net.n_out} "
              f"table slab {net.vmem_breakdown()['table_slab_bytes']} B "
              f"(compiler runs this process: {engine.compile_runs()})")
        # the artifact does not record its input quantizer width, so the
        # synthetic-code range comes from --input-bw (default 2: valid for
        # every LogicNets config in this repo)
        return net, args.input_bw
    from repro.configs import fpga4hep
    from repro.core import logicnet as LN

    cfg = fpga4hep.model_a()
    model = LN.init(cfg, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (256, cfg.in_features),
                           minval=-1, maxval=3)
    _, model = LN.forward(cfg, model, x, train=True)
    tables = LN.generate_tables(cfg, model)
    net = engine.compile_network(tables, optimize_level=args.optimize_level,
                                 in_features=cfg.in_features,
                                 block_b=args.block_b,
                                 autotune=args.autotune)
    print(f"[serve --lut] compiled generated fpga4hep model A at level "
          f"{args.optimize_level}: layout={net.layout}, table slab "
          f"{net.vmem_breakdown()['table_slab_bytes']} B")
    if args.autotune:
        plan = net.plan
        us = plan.timings_us
        default_us = us.get(plan.default_key)
        print(f"[serve --lut] autotuned over {len(us)} variants: chose "
              f"{plan.variant.key} ({us[plan.variant.key]:.0f} us/call vs "
              f"heuristic {plan.default_key} at {default_us:.0f} us); "
              f"save the artifact to replay this plan with zero search")
    return net, cfg.bw


def _parse_quota(spec: str | None):
    """``--tenant-quota RATE[:BURST]`` -> QuotaConfig (rows/s) or None."""
    from repro import serve

    if spec is None:
        return None
    rate, _, burst = spec.partition(":")
    return serve.QuotaConfig(rate_rows_per_s=float(rate),
                             burst_rows=float(burst) if burst else None)


def _print_report(rep, st: dict) -> None:
    """The operator-facing LoadReport + tier-counter dump."""
    open_loop = rep.n_clients == 0
    if open_loop:
        print(f"[serve --lut] {rep.n_requests} open-loop requests offered "
              f"at {rep.offered_rps:.0f} rps in {rep.wall_s:.2f}s: "
              f"outcomes={rep.outcomes}, goodput={rep.goodput_rps:.0f} rps, "
              f"rejection_rate={rep.rejection_rate:.2f}")
    else:
        print(f"[serve --lut] {rep.n_requests} requests ({rep.rows} rows) "
              f"from {rep.n_clients} closed-loop clients in {rep.wall_s:.2f}s")
    print(f"[serve --lut] latency p50={rep.p50_ms:.2f}ms "
          f"p90={rep.p90_ms:.2f}ms p99={rep.p99_ms:.2f}ms "
          f"mean={rep.mean_ms:.2f}ms; qps={rep.qps:.0f} "
          f"({rep.rows_per_sec:.0f} rows/s)")
    if st:
        print(f"[serve --lut] {st['batches']} batches, occupancy "
              f"{st['batch_occupancy']:.2f} (mean "
              f"{st['mean_batch_rows']:.1f} rows), "
              f"flushes={st['flush_causes']}, {st['n_devices']} device(s)"
              f"{' sharded' if st['sharded'] else ''}")
    for stage in ("queue_wait", "assembly", "device"):
        leg = rep.breakdown.get(stage)
        if leg and leg["count"]:
            print(f"[serve --lut] {stage}: mean={leg['mean_ms']:.2f}ms "
                  f"p50={leg['p50_ms']:.2f}ms p99={leg['p99_ms']:.2f}ms")


def _dump_report(args: argparse.Namespace, rep) -> None:
    if args.report_json:
        with open(args.report_json, "w") as fh:
            json.dump(rep.as_dict(), fh, indent=2, default=str)
        print(f"[serve --lut] load report -> {args.report_json}")


def _run_http(args: argparse.Namespace, net, bw, tier_cfg) -> dict:
    """HTTP ingress mode: one-shot open-loop smoke, or serve to SIGTERM."""
    from repro import serve

    cfg = serve.IngressConfig(port=args.http, quota=_parse_quota(
        args.tenant_quota))
    ing = serve.BackgroundIngress(net, tier_cfg, cfg).start()
    try:
        print(f"[serve --lut] http ingress listening on {ing.url} "
              f"(POST /v1/infer, GET /healthz, GET /metrics)", flush=True)
        if args.smoke or args.open_loop is not None:
            offered = args.open_loop if args.open_loop is not None else 400.0
            rep = serve.run_open_loop(
                url=ing.url, offered_rps=offered,
                n_requests=args.clients * args.requests_per_client,
                rows_min=args.rows_min, rows_max=args.rows_max, bw=bw,
                seed=args.seed, verify_net=net)
            print("[serve --lut] responses verified bit-exact over HTTP")
            _print_report(rep, ing.stats())
            _dump_report(args, rep)
        else:
            stop = threading.Event()

            def _drain(signum, frame):
                print(f"[serve --lut] signal {signum}: draining",
                      flush=True)
                stop.set()

            prev = [signal.signal(s, _drain)
                    for s in (signal.SIGTERM, signal.SIGINT)]
            try:
                while not stop.wait(0.5):
                    pass
            finally:
                for s, h in zip((signal.SIGTERM, signal.SIGINT), prev):
                    signal.signal(s, h)
    finally:
        ing.stop()                      # graceful drain
    return ing.stats()


def _run_lut(args: argparse.Namespace) -> None:
    """Load through the micro-batching tier (optionally via HTTP ingress).

    ``--metrics-json`` dumps in a ``finally`` so an overload run killed
    by SIGTERM still leaves its snapshot (the default SIGTERM action is
    re-pointed at ``SystemExit`` for exactly that reason); the HTTP
    serve-forever mode instead catches SIGTERM for a graceful drain.
    """
    from repro import obs, serve

    def _term(signum, frame):
        raise SystemExit(128 + signum)

    signal.signal(signal.SIGTERM, _term)
    net, bw = _lut_artifact(args)
    if args.smoke:
        args.clients, args.requests_per_client = 4, 4
    tier_cfg = serve.TierConfig(
        max_batch_rows=args.max_batch_rows,
        flush_deadline_s=args.flush_deadline_ms * 1e-3,
        max_queue_rows=args.max_queue_rows,
        request_timeout_s=(None if args.request_timeout_ms is None
                           else args.request_timeout_ms * 1e-3))
    try:
        with obs.PeriodicReporter(interval_s=args.report_every_s):
            if args.http is not None:
                st = _run_http(args, net, bw, tier_cfg)
            elif args.open_loop is not None:
                rep = serve.run_open_loop(
                    net, config=tier_cfg, offered_rps=args.open_loop,
                    n_requests=args.clients * args.requests_per_client,
                    rows_min=args.rows_min, rows_max=args.rows_max, bw=bw,
                    seed=args.seed)
                st = rep.stats
                _print_report(rep, st)
                _dump_report(args, rep)
            else:
                rep = serve.run_closed_loop(
                    net, config=tier_cfg, n_clients=args.clients,
                    n_per_client=args.requests_per_client,
                    rows_min=args.rows_min, rows_max=args.rows_max, bw=bw,
                    seed=args.seed)
                st = rep.stats
                _print_report(rep, st)
                _dump_report(args, rep)
        print(f"[serve --lut] compile-once contract: "
              f"retraces={st['retraces_after_warmup']} "
              f"compiler_runs={st['compiler_runs_after_warmup']} "
              f"after warmup")
        print("[serve --lut]", obs.summary_line())
    finally:
        if args.metrics_json:
            obs.registry().dump_json(args.metrics_json)
            print(f"[serve --lut] metrics snapshot -> {args.metrics_json}",
                  flush=True)
    if st["retraces_after_warmup"] or st["compiler_runs_after_warmup"]:
        raise SystemExit("compile-once contract violated in steady state")


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--lut", action="store_true",
                    help="serve a CompiledLUTNet through the micro-batching "
                    "tier (default: the LM decode demo)")
    # --lut mode
    ap.add_argument("--artifact", default=None, metavar="NPZ",
                    help="saved CompiledLUTNet .npz to serve (default: "
                    "compile generated fpga4hep model A)")
    ap.add_argument("--optimize-level", type=int, default=3,
                    help="truth-table compiler level when compiling")
    ap.add_argument("--block-b", type=int, default=16,
                    help="engine batch bucket (jit block size)")
    ap.add_argument("--autotune", action="store_true",
                    help="when compiling, time every eligible plan variant "
                    "(layout x block_b x pack) and serve the measured "
                    "winner; the tier then buckets on the plan's block_b")
    ap.add_argument("--clients", type=int, default=8,
                    help="closed-loop concurrent clients")
    ap.add_argument("--requests-per-client", type=int, default=16)
    ap.add_argument("--rows-min", type=int, default=1)
    ap.add_argument("--rows-max", type=int, default=8,
                    help="request batch rows are uniform in [min, max]")
    ap.add_argument("--max-batch-rows", type=int, default=None,
                    help="tier size-flush threshold (default: block_b)")
    ap.add_argument("--flush-deadline-ms", type=float, default=2.0,
                    help="tier deadline flush for partial batches")
    ap.add_argument("--max-queue-rows", type=int, default=4096,
                    help="bounded-queue backpressure limit")
    ap.add_argument("--request-timeout-ms", type=float, default=None,
                    help="per-request launch deadline (default: none)")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="put the HTTP ingress in front of the tier on "
                    "this port (0 = ephemeral; the bound port is printed). "
                    "With --smoke/--open-loop: one-shot verified load "
                    "through the ingress; otherwise serve until SIGTERM "
                    "with a graceful drain (see docs/ingress.md)")
    ap.add_argument("--tenant-quota", default=None, metavar="RATE[:BURST]",
                    help="per-tenant token-bucket admission quota in "
                    "rows/s (burst defaults to one second of rate); "
                    "requests over quota get HTTP 429")
    ap.add_argument("--open-loop", type=float, default=None, metavar="RPS",
                    help="use the open-loop Poisson-arrival generator at "
                    "this offered load instead of closed-loop clients "
                    "(total requests stays clients * requests-per-client)")
    ap.add_argument("--report-json", default=None, metavar="PATH",
                    help="dump the LoadReport (latencies, goodput, "
                    "outcome breakdown) as JSON")
    ap.add_argument("--input-bw", type=int, default=2,
                    help="synthetic request code width when serving a "
                    "saved --artifact (codes are uniform in [0, 2**bw); "
                    "compiling instead uses the model's own width)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny load (4 clients x 4 requests) for CI")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the full obs metrics snapshot (tier "
                    "histograms, engine + compiler counters) as JSON on "
                    "exit (see docs/observability.md)")
    ap.add_argument("--report-every-s", type=float, default=5.0,
                    help="periodic one-line stats report interval while "
                    "the load runs (0 disables)")
    # LM mode
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()
    if args.lut:
        _run_lut(args)
    else:
        _run_lm(args)


if __name__ == "__main__":
    main()
