"""Production serving launcher: ``python -m repro.launch.serve``.

Mesh-aware batched decode: params + caches sharded per
parallel/sharding.py, decode step jitted with in/out shardings, a
continuous-batching slot loop on top (same core as examples/serve_lm.py).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.parallel import sharding as SH
from repro.parallel.ctx import activation_sharding


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    mesh = make_host_mesh(model=args.model_parallel)
    policy = SH.ShardingPolicy()

    with activation_sharding(mesh, SH.activation_rules(policy)):
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        params_sh = SH.shardings_for_tree(params, mesh, policy)
        params = jax.device_put(params, params_sh)
        cache = M.init_cache(cfg, args.slots, args.cache_len)
        cache_sh = SH.cache_specs(policy, mesh, jax.eval_shape(
            lambda: cache))
        cache = jax.device_put(cache, cache_sh)
        step = jax.jit(S.make_decode_step(cfg),
                       in_shardings=(params_sh, cache_sh, None, None),
                       out_shardings=(None, cache_sh))

        tok = jnp.ones((args.slots, 1), jnp.int32)
        pos = jnp.zeros((args.slots,), jnp.int32)
        t0 = time.perf_counter()
        for i in range(args.steps):
            logits, cache = step(params, cache, tok, pos)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            pos = pos + 1
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
    print(f"[serve] {cfg.arch_id}: {args.steps} decode steps x "
          f"{args.slots} slots on mesh {dict(mesh.shape)} "
          f"({1e3 * dt / args.steps:.1f} ms/step)")


if __name__ == "__main__":
    main()
