"""Step builders + input_specs: the single source of truth for what gets
jitted, smoke-tested and dry-run-lowered.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that cell — weak-type-correct, shardable, no allocation.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ShapeCell
from repro.models import model as M
from repro.models.config import ModelCfg
from repro.optim.adamw import (AdamWCfg, adamw_update, init_opt_state,
                               logicnet_mask_fn)


def abstract_params(cfg: ModelCfg) -> Any:
    return jax.eval_shape(
        functools.partial(M.init_params, cfg), jax.random.key(0))


def abstract_train_state(cfg: ModelCfg) -> Any:
    params = abstract_params(cfg)
    opt = jax.eval_shape(init_opt_state, params)
    return {"params": params, "opt": opt}


def make_train_state(cfg: ModelCfg, key: jax.Array) -> Any:
    params = M.init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(params)}


def make_train_step(cfg: ModelCfg, opt_cfg: AdamWCfg | None = None,
                    grad_shardings=None):
    """``grad_shardings`` (a pytree of NamedShardings matching params)
    constrains gradients to the parameter layout right after backward —
    turning the DP gradient sync into reduce-scatter + sharded update
    instead of a full all-reduce (§Perf 'grad-rs' optimization)."""
    opt_cfg = opt_cfg or AdamWCfg(lr=3e-4)
    mask_fn = logicnet_mask_fn if cfg.logicnet_ffn is not None else None

    def train_step(state, batch):
        def loss(p):
            return M.loss_fn(p, cfg, batch)

        loss_val, grads = jax.value_and_grad(loss)(state["params"])
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        new_params, new_opt = adamw_update(opt_cfg, state["params"], grads,
                                           state["opt"], mask_fn=mask_fn)
        return {"params": new_params, "opt": new_opt}, loss_val

    return train_step


def make_prefill_step(cfg: ModelCfg):
    def prefill_step(params, batch):
        logits, _ = M.forward(params, cfg, batch, last_only=True)
        return logits[:, -1, :]

    return prefill_step


def make_decode_step(cfg: ModelCfg):
    def serve_step(params, cache, tokens, pos):
        logits, new_cache = M.decode_step(params, cfg, cache, tokens, pos)
        return logits[:, 0, :], new_cache

    return serve_step


# ---------------------------------------------------------------------------
# input_specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelCfg, cell: ShapeCell) -> dict[str, Any]:
    """ShapeDtypeStructs for every model input of the cell.

    train:   {batch: {tokens, labels[, vision_embeds | frames]}}
    prefill: {batch: {tokens[, ...]}}
    decode:  {cache, tokens, pos}
    """
    b, s = cell.global_batch, cell.seq_len
    cdt = jnp.bfloat16
    if cell.kind in ("train", "prefill"):
        batch: dict[str, Any] = {"tokens": _sds((b, s), jnp.int32)}
        if cell.kind == "train":
            batch["labels"] = _sds((b, s), jnp.int32)
        if cfg.vision_tokens > 0:
            batch["vision_embeds"] = _sds(
                (b, cfg.vision_tokens, cfg.d_model), cdt)
        if cfg.enc_dec:
            batch["frames"] = _sds((b, cfg.enc_frames, cfg.d_model), cdt)
        return {"batch": batch}
    # decode: cache sized to seq_len, one new token
    cache = jax.eval_shape(
        functools.partial(M.init_cache, cfg, b, s))
    return {
        "cache": cache,
        "tokens": _sds((b, 1), jnp.int32),
        "pos": _sds((b,), jnp.int32),
    }
