"""Production training launcher: ``python -m repro.launch.train``.

The real pjit path: builds a mesh over available devices, resolves the
sharding rules, jits the train step with in/out shardings, and drives the
fault-tolerant runtime loop (async checkpoints, NaN guard, restart).
On one CPU this degenerates to a 1x1 mesh; on a pod slice the same entry
point shards per parallel/sharding.py.  Smoke configs by default —
--full selects the exact assigned config (hardware-sized).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data import TokenStream
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models.config import LogicNetFFNCfg
from repro.optim.adamw import AdamWCfg, cosine_schedule
from repro.parallel import sharding as SH
from repro.parallel.ctx import activation_sharding
from repro.runtime import TrainLoop, TrainLoopCfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--full", action="store_true",
                    help="exact assigned config (needs a real pod)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--logicnet-ffn", action="store_true")
    ap.add_argument("--grad-rs", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    if args.logicnet_ffn:
        cfg = dataclasses.replace(cfg, logicnet_ffn=LogicNetFFNCfg())

    mesh = make_host_mesh(model=args.model_parallel)
    policy = SH.ShardingPolicy()
    opt = AdamWCfg(lr=args.lr, weight_decay=0.01,
                   schedule=cosine_schedule(warmup=min(20, args.steps // 5),
                                            total=args.steps))

    with activation_sharding(mesh, SH.activation_rules(policy)):
        state = S.make_train_state(cfg, jax.random.PRNGKey(0))
        state_sh = SH.shardings_for_tree(state, mesh, policy)
        state = jax.device_put(state, state_sh)
        step = S.make_train_step(
            cfg, opt,
            grad_shardings=state_sh["params"] if args.grad_rs else None)
        jstep = jax.jit(step, in_shardings=(state_sh, None),
                        out_shardings=(state_sh, None))

        stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                             global_batch=args.global_batch, seed=0,
                             n_hosts=jax.process_count(),
                             host=jax.process_index())

        def batches(i):
            b = stream.batch(i)
            out = {"tokens": jnp.asarray(b["tokens"]),
                   "labels": jnp.asarray(b["labels"])}
            if cfg.vision_tokens > 0:
                out["vision_embeds"] = jnp.zeros(
                    (stream.local_batch, cfg.vision_tokens, cfg.d_model),
                    jnp.bfloat16)
            if cfg.enc_dec:
                out["frames"] = jnp.zeros(
                    (stream.local_batch, cfg.enc_frames, cfg.d_model),
                    jnp.bfloat16)
            return out

        loop = TrainLoop(TrainLoopCfg(ckpt_dir=args.ckpt_dir,
                                      ckpt_every=args.ckpt_every,
                                      async_save=True), jstep, state)
        if args.resume:
            loop.try_restore(
                sharding_fn=lambda path, arr: None)  # host re-shard hook
        loop.run(batches, args.steps)
    first, last = loop.metrics[0][1], loop.metrics[-1][1]
    print(f"[train] {cfg.arch_id}: loss {first:.3f} -> {last:.3f} "
          f"on mesh {dict(mesh.shape)} ({len(jax.devices())} devices)")


if __name__ == "__main__":
    main()
