"""LM model zoo: transformer / MoE / SSM / hybrid / enc-dec / VLM substrate."""
