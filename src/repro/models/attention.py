"""GQA attention: chunked (flash-style) prefill in pure XLA + cached decode.

The chunked path scans KV blocks with an online softmax so (S x S) logits
never materialize — required for the 32k-prefill cells to fit HBM, and it is
what the Pallas flash_attention kernel computes natively on TPU (the pure-XLA
form keeps the 512-device dry-run HLO compact; the kernel is the TPU hot
path).

Supports qk-norm (qwen3), sliding windows incl. gemma3's per-layer
local/global mix (dynamic window values), M-RoPE (qwen2-vl) and
cross-attention (whisper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelCfg
from repro.models.layers import apply_mrope, apply_rope, init_rms, rms_norm

NEG_INF = -1e30


def attn_init(key: jax.Array, cfg: ModelCfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d)
    p = {
        "wq": (jax.random.normal(kq, (d, cfg.n_heads, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, cfg.n_kv_heads, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, cfg.n_kv_heads, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (cfg.n_heads, hd, d)) * s).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms(hd)
        p["k_norm"] = init_rms(hd)
    return p


def _project_qkv(p: dict, cfg: ModelCfg, x: jax.Array, positions: jax.Array,
                 rope: bool = True):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       q_offset, window, causal: bool,
                       chunk: int, kv_len_valid=None,
                       unroll: bool = False) -> jax.Array:
    """Online-softmax over KV chunks.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D).  ``window`` may be a traced
    scalar (gemma3's per-layer local/global mix under scan); 0 = global.
    ``kv_len_valid``: number of valid cache slots (decode); None = all.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = 1.0 / (d ** 0.5)
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, group, d)
    chunk = min(chunk, skv)
    n_chunks = skv // chunk if skv % chunk == 0 else -(-skv // chunk)
    window = jnp.asarray(window, jnp.int32)

    def body(carry, ci):
        acc, m, l = carry
        off = ci * chunk
        kc = jax.lax.dynamic_slice_in_dim(k, off, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v, off, chunk, axis=1)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                       kc.astype(jnp.float32))          # (B,Hkv,G,Sq,C)
        qpos = q_offset + jnp.arange(sq)
        kpos = off + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        mask &= jnp.where(window > 0,
                          kpos[None, :] > qpos[:, None] - window, True)
        if kv_len_valid is not None:
            mask &= kpos[None, :] < kv_len_valid
        else:
            mask &= (kpos[None, :] < skv)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, hkv, group, sq, d), jnp.float32)
    m0 = jnp.full((b, hkv, group, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0),
                                  jnp.arange(n_chunks),
                                  unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


def attn_apply(p: dict, cfg: ModelCfg, x: jax.Array, positions: jax.Array,
               window=0, causal: bool = True) -> jax.Array:
    """Full-sequence (training / prefill) attention."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = _chunked_attention(q, k, v, q_offset=0, window=window,
                             causal=causal, chunk=cfg.attn_chunk,
                             unroll=cfg.attn_unroll)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def attn_decode(p: dict, cfg: ModelCfg, x: jax.Array, cache_k: jax.Array,
                cache_v: jax.Array, pos: jax.Array, window=0
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a KV cache.

    x: (B, 1, D); cache_{k,v}: (B, S_cache, Hkv, hd); pos: (B,) int32 current
    position (number of tokens already in cache).
    """
    positions = pos[:, None]
    if cfg.mrope:
        # decode emits text tokens: all three M-RoPE streams advance together
        positions = jnp.broadcast_to(positions[..., None],
                                     (*positions.shape, 3))
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    if cfg.cache_update == "dus":
        # O(one token) cache write: all rows share the step position
        # (the lowered serve_step shape).  §Perf optimization: the onehot
        # blend below rewrites the WHOLE cache every step.
        zero = jnp.asarray(0, jnp.int32)
        start = (zero, pos[0], zero, zero)
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), start)
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), start)
    else:
        # Per-row cache insert at `pos` via one-hot blend (scatter-free,
        # SPMD-friendly; supports ragged positions for continuous
        # batching).
        oh = jax.nn.one_hot(pos, cache_k.shape[1],
                            dtype=cache_k.dtype)[:, :, None, None]
        cache_k = cache_k * (1 - oh) + oh * k_new.astype(cache_k.dtype)
        cache_v = cache_v * (1 - oh) + oh * v_new.astype(cache_v.dtype)
    out = _chunked_attention(q, cache_k.astype(q.dtype),
                             cache_v.astype(q.dtype),
                             q_offset=pos[0], window=window, causal=True,
                             chunk=cfg.attn_chunk,
                             kv_len_valid=pos[0] + 1,
                             unroll=cfg.attn_unroll)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"]), cache_k, cache_v


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_apply(p: dict, cfg: ModelCfg, x: jax.Array,
                     memory_k: jax.Array, memory_v: jax.Array) -> jax.Array:
    """x: (B, Sq, D) queries; memory_{k,v}: (B, Sm, Hkv, hd) precomputed."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    out = _chunked_attention(q, memory_k, memory_v, q_offset=0, window=0,
                             causal=False, chunk=cfg.attn_chunk,
                             unroll=cfg.attn_unroll)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def cross_memory(p: dict, cfg: ModelCfg, memory: jax.Array):
    """Precompute cross-attention K/V from encoder output."""
    k = jnp.einsum("bsd,dhe->bshe", memory, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", memory, p["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v
