"""Unified model configuration covering every assigned architecture.

One dataclass; family-specific behavior is driven by ``block_pattern`` and
the optional MoE / SSM / enc-dec / VLM sub-configs.  Exact per-arch values
live in ``repro.configs.<arch_id>``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    # 'dense' = GShard one-hot einsum dispatch (baseline);
    # 'sorted' = sort-based ragged dispatch (optimized, §Perf).
    dispatch: str = "dense"
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class LogicNetFFNCfg:
    """Paper integration at LM scale: per-neuron fan-in sparsity +
    activation QAT on the FFN (DESIGN.md §4)."""

    fan_in: int = 16
    bw: int = 4
    max_val: float = 4.0


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    arch_id: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    # 'attn' | 'ssm'; hybrids interleave (e.g. zamba2 shared attn every k).
    block_kind: str = "attn"
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    logicnet_ffn: LogicNetFFNCfg | None = None

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0            # 0 = global everywhere
    local_global_ratio: int = 0        # gemma3: N local per 1 global
    mrope: bool = False                # qwen2-vl 3-section M-RoPE

    # hybrid (zamba2): one *shared* attention block every `attn_every` SSM
    # layers (weight re-use across sites, as in the paper).
    hybrid_attn_every: int = 0

    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500             # frozen whisper encoder length

    # vlm (qwen2-vl): first `vision_tokens` positions come from the stub
    # patch-embedding frontend.
    vision_tokens: int = 0

    # numerics / training
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"                # 'none' | 'full' | 'dots'
    attn_chunk: int = 1024             # KV block for chunked (flash-style)
    act_fn: str = "silu"               # swiglu gate activation

    # Dry-run cost-accounting knobs (XLA cost_analysis counts while-loop
    # bodies ONCE; see launch/dryrun.py): scan_unroll=u makes layer-scan
    # bodies u-wide so a two-point fit recovers true per-step cost;
    # attn_unroll fully unrolls the KV-chunk loop (trip count follows seq
    # len, not layers, so it must be inlined to be counted).
    scan_unroll: int = 1
    attn_unroll: bool = False

    # KV-cache write strategy (§Perf): 'onehot' (baseline; blend rewrites
    # the whole cache — supports ragged per-row positions) vs 'dus'
    # (dynamic_update_slice at pos[0]: O(one token) traffic; rows share a
    # step, the lowered serve_step shape).
    cache_update: str = "onehot"

    @property
    def fit_unroll(self) -> int:
        """Second unroll point u2 for the cost fit (must divide the layer
        scan length: n_layers, or n_sites for hybrids)."""
        length = (self.n_layers // self.hybrid_attn_every
                  if self.is_hybrid else self.n_layers)
        return 3 if length % 2 else 2

    @property
    def scan_length(self) -> int:
        """Trip count of the (outer) layer scan, for the cost fit."""
        return (self.n_layers // self.hybrid_attn_every
                if self.is_hybrid else self.n_layers)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_ssm(self) -> bool:
        return self.block_kind == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.is_ssm and self.hybrid_attn_every > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM state decode)."""
        return self.is_ssm

    def param_count(self) -> int:
        """Approximate parameter count N for MODEL_FLOPS = 6*N*D."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.is_ssm:
            assert self.ssm is not None
            d_in = self.ssm.expand * d
            nh = d_in // self.ssm.head_dim
            per = (d * (2 * d_in + 2 * self.ssm.n_groups * self.ssm.d_state
                        + nh)
                   + d_in * self.ssm.conv_width + d_in * d + 2 * nh)
            total = self.n_layers * per
            if self.is_hybrid:
                attn = (d * hd * (self.n_heads + 2 * self.n_kv_heads)
                        + self.n_heads * hd * d + 3 * d * self.d_ff)
                total += attn  # shared block counted once
            return emb + total
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.moe is not None:
            ffn = self.moe.n_experts * 3 * d * self.d_ff + d * self.moe.n_experts
        else:
            ffn = 3 * d * self.d_ff
        layers = self.n_layers * (attn + ffn)
        if self.enc_dec:
            # encoder layers (self-attn + ffn) + decoder cross-attn
            layers += self.n_enc_layers * (attn + 3 * d * self.d_ff)
            layers += self.n_layers * attn
        return emb + layers

    def active_param_count(self) -> int:
        """N_active for MoE MODEL_FLOPS accounting."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = self.n_layers * self.moe.n_experts * 3 * d * self.d_ff
        active = self.n_layers * self.moe.top_k * 3 * d * self.d_ff
        return full - all_experts + active
