"""Shared model building blocks: norms, RoPE (+M-RoPE), embeddings, FFN."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import LogicNetFFNCfg


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(dtype)


def init_rms(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                           # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, int, int] = (16, 24, 24)) -> jax.Array:
    """Qwen2-VL M-RoPE: 3 position streams (t, h, w) over head_dim sections.

    x: (B, S, H, D); positions: (B, S, 3) int32.  ``sections`` are per-stream
    half-dims summing to D/2 (scaled for small head dims).
    """
    d = x.shape[-1]
    half = d // 2
    total = sum(sections)
    sec = [max(1, s * half // total) for s in sections]
    sec[-1] = half - sec[0] - sec[1]
    freqs = rope_freqs(d, theta)                           # (D/2,)
    # stream id per frequency slot
    stream = jnp.concatenate([
        jnp.full((sec[0],), 0), jnp.full((sec[1],), 1),
        jnp.full((sec[2],), 2)]).astype(jnp.int32)
    pos = jnp.take_along_axis(
        positions, stream[None, None, :].repeat(positions.shape[1], 1),
        axis=2).astype(jnp.float32)                        # (B, S, D/2)
    angles = pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU FFN (+ LogicNet-FFN, the paper's technique at LM scale)
# ---------------------------------------------------------------------------

def ffn_init(key: jax.Array, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / jnp.sqrt(d_model)
    s_out = 1.0 / jnp.sqrt(d_ff)
    return {
        "wi_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "wi_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }


def ffn_apply(p: dict, x: jax.Array, act_fn: str = "silu") -> jax.Array:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act_fn]
    h = act(x @ p["wi_gate"]) * (x @ p["wi_up"])
    return h @ p["wo"]


def logicnet_ffn_init(key: jax.Array, d_model: int, d_ff: int,
                      cfg: LogicNetFFNCfg, dtype, seed: int = 0) -> dict:
    """FFN with per-neuron fan-in masks + activation fake-quant (DESIGN §4).

    The trainable half of LogicNets applied at scale: masks bound each
    hidden neuron's fan-in; activations are quantized with an STE.  (Truth-
    table conversion stays gated on fan_in*bw <= 24 bits.)
    """
    from repro.core.sparsity import apriori_mask
    p = ffn_init(key, d_model, d_ff, dtype)
    p["mask_in"] = apriori_mask(seed, d_model, d_ff,
                                min(cfg.fan_in, d_model)).astype(dtype)
    p["mask_out"] = apriori_mask(seed + 1, d_ff, d_model,
                                 min(cfg.fan_in, d_ff)).astype(dtype)
    return p


def logicnet_ffn_apply(p: dict, x: jax.Array, cfg: LogicNetFFNCfg,
                       act_fn: str = "silu") -> jax.Array:
    from repro.core.quantize import QuantizerCfg, quantize
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act_fn]
    q = QuantizerCfg(cfg.bw, cfg.max_val)
    xq = quantize(q, x.astype(jnp.float32)).value.astype(x.dtype)
    h = act(xq @ (p["wi_gate"] * p["mask_in"])) * (xq @ (p["wi_up"]
                                                         * p["mask_in"]))
    hq = quantize(q, h.astype(jnp.float32)).value.astype(x.dtype)
    return hq @ (p["wo"] * p["mask_out"])


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def embed_init(key: jax.Array, vocab: int, d_model: int, dtype,
               tie: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (vocab, d_model)) * 0.02).astype(dtype)}
    if not tie:
        p["head"] = (jax.random.normal(k2, (vocab, d_model))
                     * 0.02).astype(dtype)
    return p


def embed_lookup(p: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    return p["tok"][tokens].astype(compute_dtype)


def lm_logits(p: dict, h: jax.Array, compute_dtype) -> jax.Array:
    w = p.get("head", p["tok"]).astype(compute_dtype)
    return jnp.einsum("bsd,vd->bsv", h, w)
