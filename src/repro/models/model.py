"""Unified model: init / forward / loss / prefill / decode for every arch.

Layer stacks are scanned (``jax.lax.scan`` over stacked params) so the HLO
stays compact for the 512-device dry-run; hybrids scan super-layers
(zamba2: shared attention block + K mamba layers).  Remat policy per config.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as ATT
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelCfg
from repro.models.layers import (embed_init, embed_lookup, ffn_apply,
                                 ffn_init, init_rms, lm_logits,
                                 logicnet_ffn_apply, logicnet_ffn_init,
                                 rms_norm)
from repro.parallel.ctx import constrain


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def _cast_weights(p, cdt):
    """Matrix params to the compute dtype; 1-D leaves (norm scales, biases,
    a_log, ...) stay fp32 for numerics."""
    return jax.tree.map(
        lambda a: a.astype(cdt) if a.ndim >= 2 else a, p)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Layer windows (gemma3 local:global mix)
# ---------------------------------------------------------------------------

def layer_windows(cfg: ModelCfg) -> jnp.ndarray:
    """Per-layer sliding window: 0 = global. gemma3: N locals then 1 global."""
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio + 1
        idx = jnp.arange(cfg.n_layers)
        return jnp.where((idx % r) == (r - 1), 0, cfg.sliding_window)
    return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _decoder_layer_init(key: jax.Array, cfg: ModelCfg, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"ln1": init_rms(cfg.d_model), "ln2": init_rms(cfg.d_model),
         "attn": ATT.attn_init(k1, cfg, dtype)}
    if cfg.moe is not None:
        p["moe"] = MOE.moe_init(k2, cfg, dtype)
    elif cfg.logicnet_ffn is not None:
        p["ffn"] = logicnet_ffn_init(k2, cfg.d_model, cfg.d_ff,
                                     cfg.logicnet_ffn, dtype)
    else:
        p["ffn"] = ffn_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _stack_init(key: jax.Array, n: int, fn) -> dict:
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_params(cfg: ModelCfg, key: jax.Array) -> dict[str, Any]:
    dtype = _dtype(cfg.param_dtype)
    ke, kl, ks, kf = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": embed_init(ke, cfg.vocab, cfg.d_model, dtype,
                            cfg.tie_embeddings),
        "final_norm": init_rms(cfg.d_model),
    }
    if cfg.is_ssm:
        params["ssm_layers"] = _stack_init(
            kl, cfg.n_layers, lambda k: dict(
                ln=init_rms(cfg.d_model),
                ssm=SSM.ssm_init(k, cfg, dtype)))
        if cfg.is_hybrid:
            params["shared_attn"] = _decoder_layer_init(ks, cfg, dtype)
    elif cfg.enc_dec:
        params["pos_emb_enc"] = (jax.random.normal(
            ks, (cfg.enc_frames, cfg.d_model)) * 0.01).astype(dtype)
        params["enc_layers"] = _stack_init(
            kl, cfg.n_enc_layers, lambda k: _enc_layer_init(k, cfg, dtype))
        params["dec_layers"] = _stack_init(
            kf, cfg.n_layers, lambda k: _dec_xattn_layer_init(k, cfg, dtype))
        params["enc_final_norm"] = init_rms(cfg.d_model)
    else:
        params["layers"] = _stack_init(
            kl, cfg.n_layers, lambda k: _decoder_layer_init(k, cfg, dtype))
    return params


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_rms(cfg.d_model), "ln2": init_rms(cfg.d_model),
            "attn": ATT.attn_init(k1, cfg, dtype),
            "ffn": ffn_init(k2, cfg.d_model, cfg.d_ff, dtype)}


def _dec_xattn_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_rms(cfg.d_model), "ln2": init_rms(cfg.d_model),
            "ln3": init_rms(cfg.d_model),
            "attn": ATT.attn_init(k1, cfg, dtype),
            "xattn": ATT.attn_init(k2, cfg, dtype),
            "ffn": ffn_init(k3, cfg.d_model, cfg.d_ff, dtype)}


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _attn_block(p: dict, cfg: ModelCfg, h: jax.Array, positions, window):
    p = _cast_weights(p, _dtype(cfg.compute_dtype))
    h = constrain(h, ("act_batch", None, "act_embed"))
    a = ATT.attn_apply(p["attn"], cfg, rms_norm(h, p["ln1"], cfg.norm_eps),
                       positions, window=window)
    h = h + a
    hn = rms_norm(h, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        f, aux = MOE.moe_apply(p["moe"], cfg, hn)
    elif cfg.logicnet_ffn is not None:
        f, aux = logicnet_ffn_apply(p["ffn"], hn, cfg.logicnet_ffn), 0.0
    else:
        f, aux = ffn_apply(p["ffn"], hn, cfg.act_fn), 0.0
    return h + f, aux


def _forward_decoder(params, cfg: ModelCfg, h, positions):
    windows = layer_windows(cfg)

    def body(carry, xs):
        h, aux = carry
        layer_p, window = xs
        h, a = _attn_block(layer_p, cfg, h, positions, window)
        return (h, aux + a), None

    body = _remat(body, cfg.remat)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.asarray(0.0, jnp.float32)),
                               (params["layers"], windows),
                               unroll=cfg.scan_unroll)
    return h, aux


def _n_sites(cfg: ModelCfg) -> int:
    assert cfg.n_layers % cfg.hybrid_attn_every == 0, \
        "hybrid stacks scan super-layers: n_layers % attn_every == 0"
    return cfg.n_layers // cfg.hybrid_attn_every


def _forward_ssm(params, cfg: ModelCfg, h, positions):
    cdt = _dtype(cfg.compute_dtype)

    def ssm_body(h, layer_p):
        layer_p = _cast_weights(layer_p, cdt)
        h = h + SSM.ssm_apply(layer_p["ssm"], cfg,
                              rms_norm(h, layer_p["ln"], cfg.norm_eps))
        return h, None

    if not cfg.is_hybrid:
        body = _remat(ssm_body, cfg.remat)
        h, _ = jax.lax.scan(body, h, params["ssm_layers"],
                            unroll=cfg.scan_unroll)
        return h, 0.0

    # zamba2 super-layers: [shared attn block, K mamba layers] x n_sites;
    # the attention block's weights are re-used at every site (parameter
    # sharing, as in the paper).  Remat wraps ONLY the super-layer body —
    # nesting checkpoint around the inner scan too would recompute the
    # mamba layers twice in backward and blows up partitioner compile time.
    k = cfg.hybrid_attn_every
    sites = _n_sites(cfg)
    stacked = jax.tree.map(
        lambda a: a.reshape(sites, k, *a.shape[1:]), params["ssm_layers"])

    def super_body(h, site_layers):
        h, _ = _attn_block(params["shared_attn"], cfg, h, positions,
                           window=0)
        h, _ = jax.lax.scan(ssm_body, h, site_layers, unroll=k)
        return h, None

    super_body = _remat(super_body, cfg.remat)
    h, _ = jax.lax.scan(super_body, h, stacked, unroll=cfg.scan_unroll)
    return h, 0.0


def _forward_encoder(params, cfg: ModelCfg, frames):
    h = frames + params["pos_emb_enc"][None, :frames.shape[1], :]
    positions = jnp.broadcast_to(jnp.arange(frames.shape[1])[None],
                                 frames.shape[:2])

    def body(h, layer_p):
        layer_p = _cast_weights(layer_p, _dtype(cfg.compute_dtype))
        hn = rms_norm(h, layer_p["ln1"], cfg.norm_eps)
        h = h + ATT.attn_apply(layer_p["attn"], cfg, hn, positions,
                               window=0, causal=False)
        hn = rms_norm(h, layer_p["ln2"], cfg.norm_eps)
        h = h + ffn_apply(layer_p["ffn"], hn, cfg.act_fn)
        return h, None

    body = _remat(body, cfg.remat)
    h, _ = jax.lax.scan(body, h, params["enc_layers"],
                        unroll=cfg.scan_unroll)
    return rms_norm(h, params["enc_final_norm"], cfg.norm_eps)


def _forward_encdec(params, cfg: ModelCfg, h, positions, frames):
    memory = _forward_encoder(params, cfg, frames)

    def body(carry, layer_p):
        h = carry
        layer_p = _cast_weights(layer_p, _dtype(cfg.compute_dtype))
        hn = rms_norm(h, layer_p["ln1"], cfg.norm_eps)
        h = h + ATT.attn_apply(layer_p["attn"], cfg, hn, positions,
                               window=0, causal=True)
        hn = rms_norm(h, layer_p["ln2"], cfg.norm_eps)
        mk, mv = ATT.cross_memory(layer_p["xattn"], cfg, memory)
        h = h + ATT.cross_attn_apply(layer_p["xattn"], cfg, hn, mk, mv)
        hn = rms_norm(h, layer_p["ln3"], cfg.norm_eps)
        h = h + ffn_apply(layer_p["ffn"], hn, cfg.act_fn)
        return h, None

    body = _remat(body, cfg.remat)
    h, _ = jax.lax.scan(body, h, params["dec_layers"],
                        unroll=cfg.scan_unroll)
    return h, 0.0


def _positions(cfg: ModelCfg, tokens: jax.Array) -> jax.Array:
    b, s = tokens.shape
    seq = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if not cfg.mrope:
        return seq
    # Qwen2-VL M-RoPE stub: vision tokens get (t=0, h, w) grid positions,
    # text tokens sequential in all three streams.
    v = cfg.vision_tokens
    side = max(1, int(v ** 0.5))
    t_pos = jnp.where(seq < v, 0, seq - v + side)
    h_pos = jnp.where(seq < v, seq // side, seq - v + side)
    w_pos = jnp.where(seq < v, seq % side, seq - v + side)
    return jnp.stack([t_pos, h_pos, w_pos], axis=-1)


def forward(params, cfg: ModelCfg, batch: dict[str, jax.Array],
            last_only: bool = False) -> tuple[jax.Array, jax.Array]:
    """batch: tokens (B,S) [+ vision_embeds | frames] -> (logits, aux).

    ``last_only`` computes the LM head on the final position only (the
    serving-prefill shape: the head matmul on 1 token, not S).
    """
    cdt = _dtype(cfg.compute_dtype)
    tokens = batch["tokens"]
    h = embed_lookup(params["embed"], tokens, cdt)
    if cfg.vision_tokens > 0 and "vision_embeds" in batch:
        v = cfg.vision_tokens
        h = jnp.concatenate(
            [batch["vision_embeds"].astype(cdt), h[:, v:, :]], axis=1)
    positions = _positions(cfg, tokens)
    h = constrain(h, ("act_batch", None, "act_embed"))
    if cfg.is_ssm:
        h, aux = _forward_ssm(params, cfg, h, positions)
    elif cfg.enc_dec:
        h, aux = _forward_encdec(params, cfg, h, positions,
                                 batch["frames"].astype(cdt))
    else:
        h, aux = _forward_decoder(params, cfg, h, positions)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if last_only:
        h = h[:, -1:, :]
    logits = lm_logits(params["embed"], h, cdt)
    logits = constrain(logits, ("act_batch", None, "act_vocab"))
    return logits, aux


def loss_fn(params, cfg: ModelCfg, batch: dict[str, jax.Array]
            ) -> jax.Array:
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    return nll + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode (serving): KV/SSM caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelCfg, batch: int, max_seq: int) -> dict:
    hd = cfg.resolved_head_dim
    cache: dict[str, Any] = {}
    if cfg.is_ssm:
        one = SSM.ssm_decode_state(cfg, batch)
        cache["ssm"] = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers, *a.shape), a.dtype), one)
        if cfg.is_hybrid:
            n_sites = _n_sites(cfg)
            cache["shared_k"] = jnp.zeros(
                (n_sites, batch, max_seq, cfg.n_kv_heads, hd), jnp.bfloat16)
            cache["shared_v"] = jnp.zeros_like(cache["shared_k"])
    else:
        n = cfg.n_layers
        cache["k"] = jnp.zeros((n, batch, max_seq, cfg.n_kv_heads, hd),
                               jnp.bfloat16)
        cache["v"] = jnp.zeros_like(cache["k"])
        if cfg.enc_dec:
            cache["mem_k"] = jnp.zeros(
                (n, batch, cfg.enc_frames, cfg.n_kv_heads, hd), jnp.bfloat16)
            cache["mem_v"] = jnp.zeros_like(cache["mem_k"])
    return cache


def decode_step(params, cfg: ModelCfg, cache: dict, tokens: jax.Array,
                pos: jax.Array) -> tuple[jax.Array, dict]:
    """One token for every sequence: tokens (B, 1), pos (B,)."""
    cdt = _dtype(cfg.compute_dtype)
    h = embed_lookup(params["embed"], tokens, cdt)
    h = constrain(h, ("act_batch", None, "act_embed"))
    windows = layer_windows(cfg)

    if cfg.is_ssm:
        def ssm_body(h, xs):
            layer_p, ssm_state = xs
            layer_p = _cast_weights(layer_p, _dtype(cfg.compute_dtype))
            hn = rms_norm(h, layer_p["ln"], cfg.norm_eps)
            y, new_state = SSM.ssm_decode(layer_p["ssm"], cfg, hn, ssm_state)
            return h + y, new_state

        if not cfg.is_hybrid:
            h, new_states = jax.lax.scan(
                ssm_body, h, (params["ssm_layers"], cache["ssm"]),
                unroll=cfg.scan_unroll)
            new_cache = dict(cache, ssm=new_states)
        else:
            k = cfg.hybrid_attn_every
            sites = _n_sites(cfg)
            stacked = jax.tree.map(
                lambda a: a.reshape(sites, k, *a.shape[1:]),
                (params["ssm_layers"], cache["ssm"]))

            def super_body(h, xs):
                site_layers, ck, cv = xs
                sp = _cast_weights(params["shared_attn"],
                                   _dtype(cfg.compute_dtype))
                hn = rms_norm(h, sp["ln1"], cfg.norm_eps)
                a, nk, nv = ATT.attn_decode(sp["attn"], cfg, hn, ck, cv,
                                            pos)
                h = h + a
                hn = rms_norm(h, sp["ln2"], cfg.norm_eps)
                h = h + ffn_apply(sp["ffn"], hn, cfg.act_fn)
                h, new_states = jax.lax.scan(ssm_body, h, site_layers,
                                             unroll=k)
                return h, (new_states, nk, nv)

            h, (new_states, nk, nv) = jax.lax.scan(
                super_body, h,
                (stacked, cache["shared_k"], cache["shared_v"]),
                unroll=cfg.scan_unroll)
            new_cache = dict(
                cache,
                ssm=jax.tree.map(
                    lambda a: a.reshape(cfg.n_layers, *a.shape[2:]),
                    new_states),
                shared_k=nk, shared_v=nv)
    else:
        def body(carry, xs):
            h = carry
            layer_p, ck, cv, window, *xtra = xs
            layer_p = _cast_weights(layer_p, _dtype(cfg.compute_dtype))
            hn = rms_norm(h, layer_p["ln1"], cfg.norm_eps)
            a, nk, nv = ATT.attn_decode(layer_p["attn"], cfg, hn, ck, cv,
                                        pos, window=window)
            h = h + a
            if cfg.enc_dec:
                mk, mv = xtra
                hn = rms_norm(h, layer_p["ln2"], cfg.norm_eps)
                h = h + ATT.cross_attn_apply(layer_p["xattn"], cfg, hn,
                                             mk.astype(h.dtype),
                                             mv.astype(h.dtype))
                hn = rms_norm(h, layer_p["ln3"], cfg.norm_eps)
                h = h + ffn_apply(layer_p["ffn"], hn, cfg.act_fn)
            else:
                hn = rms_norm(h, layer_p["ln2"], cfg.norm_eps)
                if cfg.moe is not None:
                    f, _ = MOE.moe_apply(layer_p["moe"], cfg, hn)
                elif cfg.logicnet_ffn is not None:
                    f = logicnet_ffn_apply(layer_p["ffn"], hn,
                                           cfg.logicnet_ffn)
                else:
                    f = ffn_apply(layer_p["ffn"], hn, cfg.act_fn)
                h = h + f
            return h, (nk, nv)

        layer_params = params.get("dec_layers", params.get("layers"))
        xs = [layer_params, cache["k"], cache["v"], windows]
        if cfg.enc_dec:
            xs += [cache["mem_k"], cache["mem_v"]]
        h, (nk, nv) = jax.lax.scan(body, h, tuple(xs),
                                   unroll=cfg.scan_unroll)
        new_cache = dict(cache, k=nk, v=nv)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params["embed"], h, _dtype(cfg.compute_dtype))
    logits = constrain(logits, ("act_batch", None, "act_vocab"))
    return logits, new_cache
