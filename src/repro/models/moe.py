"""Mixture-of-Experts FFN: top-k router + two dispatch strategies.

* ``dense``  — GShard-style one-hot dispatch/combine einsums with a capacity
  limit.  Paper-era baseline: simple, SPMD-friendly, but the dispatch
  einsums burn tokens*experts*capacity*d_model FLOPs — visible in the
  roofline's MODEL_FLOPS/HLO_FLOPs ratio (that waste is the point of
  recording it).
* ``sorted`` — argsort-based ragged dispatch: tokens are sorted by expert,
  gathered into per-expert slabs, processed, and scattered back.  The
  §Perf hillclimb for the MoE cells.

Experts are sharded over the 'model' mesh axis (EP); XLA inserts the
all-to-all / all-gather pattern from the shardings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelCfg


def moe_init(key: jax.Array, cfg: ModelCfg, dtype) -> dict:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    kr, kg, ku, ko = jax.random.split(key, 4)
    s_in, s_out = 1.0 / jnp.sqrt(d), 1.0 / jnp.sqrt(f)
    return {
        "router": (jax.random.normal(kr, (d, e)) * s_in).astype(jnp.float32),
        "wi_gate": (jax.random.normal(kg, (e, d, f)) * s_in).astype(dtype),
        "wi_up": (jax.random.normal(ku, (e, d, f)) * s_in).astype(dtype),
        "wo": (jax.random.normal(ko, (e, f, d)) * s_out).astype(dtype),
    }


def _router(p: dict, x: jax.Array, cfg: ModelCfg):
    """Softmax-after-topk routing (qwen3/olmoe style)."""
    logits = x.astype(jnp.float32) @ p["router"]           # (B, S, E)
    topv, topi = jax.lax.top_k(logits, cfg.moe.top_k)      # (B, S, K)
    weights = jax.nn.softmax(topv, axis=-1)
    # Aux load-balancing loss (Switch): E * sum_e f_e * p_e.
    probs = jax.nn.softmax(logits, axis=-1)
    e = cfg.moe.n_experts
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)    # (B,S,K,E)
    frac = onehot.sum(2).reshape(-1, e).mean(0)
    aux = e * jnp.sum(frac * probs.reshape(-1, e).mean(0))
    return topi, weights, aux


def _expert_ffn(p: dict, xs: jax.Array, act) -> jax.Array:
    """xs: (E, C, D) per-expert token slabs -> (E, C, D)."""
    h = act(jnp.einsum("ecd,edf->ecf", xs, p["wi_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xs, p["wi_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


GROUP_TOKENS = 1024  # GShard group size: bounds the (G_s, E, C) tensors


def moe_apply_dense(p: dict, cfg: ModelCfg, x: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """GShard dense dispatch: grouped one-hot einsums with capacity.

    Tokens are split into groups of GROUP_TOKENS; each group dispatches
    independently into (E, C_g) buffers.  Dispatch+combine cost
    ~2 * cf * G_s / (3 * d_ff) of the expert matmuls — the measurable
    paper-era overhead the sorted path removes.
    """
    act = jax.nn.silu
    b, s, d = x.shape
    k = cfg.moe.top_k
    e = cfg.moe.n_experts
    tokens = b * s
    topi, weights, aux = _router(p, x, cfg)

    gs = min(GROUP_TOKENS, tokens)
    n_g = tokens // gs
    assert tokens % gs == 0, (tokens, gs)
    cap = max(1, int(cfg.moe.capacity_factor * gs * k / e))

    flat_i = topi.reshape(n_g, gs, k)
    flat_w = weights.reshape(n_g, gs, k).astype(x.dtype)
    onehot = jax.nn.one_hot(flat_i, e, dtype=jnp.float32)  # (G, S, K, E)
    # position of each (token, k) within its expert's per-group buffer
    pos = jnp.cumsum(onehot.reshape(n_g, gs * k, e), axis=1) - 1
    pos = pos.reshape(n_g, gs, k, e)
    keep = (pos < cap) & (onehot > 0)
    sel = jnp.where(keep, onehot, 0.0).astype(x.dtype)     # (G, S, K, E)
    pos_sel = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)  # (G, S, K)
    cap_oh = jax.nn.one_hot(jnp.clip(pos_sel, 0, cap - 1), cap,
                            dtype=x.dtype)                 # (G, S, K, C)
    dispatch = jnp.einsum("gske,gskc->gsec", sel, cap_oh)  # (G, S, E, C)
    combine = jnp.einsum("gsk,gske,gskc->gsec", flat_w, sel, cap_oh)
    xg = x.reshape(n_g, gs, d)
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
    expert_in = expert_in.reshape(e, n_g * cap, d)
    expert_out = _expert_ffn(p, expert_in, act)            # (E, G*C, D)
    expert_out = expert_out.reshape(e, n_g, cap, d)
    out = jnp.einsum("gsec,egcd->gsd", combine, expert_out)
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_apply_sorted(p: dict, cfg: ModelCfg, x: jax.Array
                     ) -> tuple[jax.Array, jax.Array]:
    """Sort-based ragged dispatch, GLOBAL variant (§Perf, kept for the
    record: the global argsort forces a cross-shard resharding of every
    (token, k) pair — measured 9.6x collective blow-up vs dense at the
    235B/train_4k cell.  Use 'sorted_local' instead.)
    """
    act = jax.nn.silu
    b, s, d = x.shape
    k = cfg.moe.top_k
    e = cfg.moe.n_experts
    tokens = b * s
    cap = max(1, int(cfg.moe.capacity_factor * tokens * k / e))
    topi, weights, aux = _router(p, x, cfg)

    flat_i = topi.reshape(tokens * k)                      # expert ids
    flat_w = weights.reshape(tokens * k)
    tok_id = jnp.repeat(jnp.arange(tokens), k)
    order = jnp.argsort(flat_i)                            # stable
    sorted_e = flat_i[order]
    sorted_t = tok_id[order]
    sorted_w = flat_w[order]
    # rank within expert group
    same = jnp.cumsum(jax.nn.one_hot(sorted_e, e, dtype=jnp.int32),
                      axis=0)
    rank = jnp.take_along_axis(same, sorted_e[:, None], axis=1)[:, 0] - 1
    keep = rank < cap
    slot = jnp.clip(sorted_e * cap + rank, 0, e * cap - 1)
    xf = x.reshape(tokens, d)
    slab = jnp.zeros((e * cap, d), x.dtype)
    slab = slab.at[slot].add(jnp.where(keep[:, None], xf[sorted_t], 0))
    expert_out = _expert_ffn(p, slab.reshape(e, cap, d), act)
    flat_out = expert_out.reshape(e * cap, d)
    contrib = jnp.where(keep[:, None], flat_out[slot]
                        * sorted_w[:, None].astype(x.dtype), 0)
    out = jnp.zeros((tokens, d), x.dtype).at[sorted_t].add(contrib)
    return out.reshape(b, s, d), aux


def moe_apply_sorted_local(p: dict, cfg: ModelCfg, x: jax.Array
                           ) -> tuple[jax.Array, jax.Array]:
    """Sort-based ragged dispatch, GROUP-LOCAL (§Perf optimized path).

    Tokens keep the dense path's GROUP_TOKENS grouping (groups stay on
    their data shard), and the (token,k)->slot sort runs *within* each
    group — no cross-shard resharding; only the expert slabs travel over
    the EP axis, exactly like the dense path, but the O(S·E·C·d) one-hot
    dispatch/combine einsums are replaced by O(S·k·d) gathers."""
    act = jax.nn.silu
    b, s, d = x.shape
    k = cfg.moe.top_k
    e = cfg.moe.n_experts
    tokens = b * s
    gs = min(GROUP_TOKENS, tokens)
    n_g = tokens // gs
    assert tokens % gs == 0, (tokens, gs)
    cap = max(1, int(cfg.moe.capacity_factor * gs * k / e))
    topi, weights, aux = _router(p, x, cfg)

    flat_i = topi.reshape(n_g, gs * k)                     # expert ids
    flat_w = weights.reshape(n_g, gs * k).astype(x.dtype)
    tok_id = jnp.broadcast_to(
        jnp.repeat(jnp.arange(gs), k)[None], (n_g, gs * k))
    order = jnp.argsort(flat_i, axis=1, stable=True)       # per-group sort
    sorted_e = jnp.take_along_axis(flat_i, order, axis=1)
    sorted_t = jnp.take_along_axis(tok_id, order, axis=1)
    sorted_w = jnp.take_along_axis(flat_w, order, axis=1)
    # rank within expert, per group
    same = jnp.cumsum(jax.nn.one_hot(sorted_e, e, dtype=jnp.int32), axis=1)
    rank = jnp.take_along_axis(same, sorted_e[:, :, None],
                               axis=2)[:, :, 0] - 1
    keep = rank < cap
    slot = jnp.clip(sorted_e * cap + rank, 0, e * cap - 1)
    xg = x.reshape(n_g, gs, d)
    gathered = jnp.take_along_axis(
        xg, sorted_t[:, :, None], axis=1)                  # (G, S*k, d)
    gathered = jnp.where(keep[:, :, None], gathered, 0)
    slab = jnp.zeros((n_g, e * cap, d), x.dtype)
    slab = jax.vmap(lambda sl, so, g: sl.at[so].add(g))(slab, slot,
                                                        gathered)
    # Keep the group axis through the expert einsums: g stays on its data
    # shard, e contracts against model-sharded expert weights — the
    # transpose/reshape variant that merged (g, cap) forced a global
    # reshard of the slab (measured; see EXPERIMENTS §Perf H1b).
    slab = slab.reshape(n_g, e, cap, d)
    h = act(jnp.einsum("gecd,edf->gecf", slab, p["wi_gate"])) \
        * jnp.einsum("gecd,edf->gecf", slab, p["wi_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"])
    flat_out = expert_out.reshape(n_g, e * cap, d)
    back = jnp.take_along_axis(flat_out, slot[:, :, None], axis=1)
    contrib = jnp.where(keep[:, :, None],
                        back * sorted_w[:, :, None], 0)
    out = jnp.zeros((n_g, gs, d), x.dtype)
    out = jax.vmap(lambda o, t, c: o.at[t].add(c))(out, sorted_t, contrib)
    return out.reshape(b, s, d), aux


def moe_apply(p: dict, cfg: ModelCfg, x: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    if cfg.moe.dispatch == "sorted":
        return moe_apply_sorted(p, cfg, x)
    if cfg.moe.dispatch == "sorted_local":
        return moe_apply_sorted_local(p, cfg, x)
    return moe_apply_dense(p, cfg, x)
