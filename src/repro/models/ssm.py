"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute inside length-``chunk`` blocks, linear state passing between blocks
(a lax.scan).  Decode is the O(1)-per-token recurrence on the (H, P, N)
state — what makes the long_500k cell feasible for mamba2/zamba2.

Block layout (mamba2): in_proj -> [z | x | B | C | dt]; short causal
depthwise conv on (x, B, C); SSD core; gated RMSNorm; out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelCfg
from repro.models.layers import init_rms, rms_norm


def _dims(cfg: ModelCfg):
    ssm = cfg.ssm
    d_in = ssm.expand * cfg.d_model
    n_heads = d_in // ssm.head_dim
    conv_dim = d_in + 2 * ssm.n_groups * ssm.d_state
    return d_in, n_heads, conv_dim


def ssm_init(key: jax.Array, cfg: ModelCfg, dtype) -> dict:
    ssm = cfg.ssm
    d = cfg.d_model
    d_in, n_heads, conv_dim = _dims(cfg)
    proj_dim = 2 * d_in + 2 * ssm.n_groups * ssm.d_state + n_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d)
    return {
        "in_proj": (jax.random.normal(k1, (d, proj_dim)) * s).astype(dtype),
        "conv_w": (jax.random.normal(k2, (ssm.conv_width, conv_dim))
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": init_rms(d_in),
        "out_proj": (jax.random.normal(k4, (d_in, d))
                     / jnp.sqrt(d_in)).astype(dtype),
    }


def _split_proj(cfg: ModelCfg, zxbcdt: jax.Array):
    ssm = cfg.ssm
    d_in, n_heads, _ = _dims(cfg)
    gn = ssm.n_groups * ssm.d_state
    z, x, b, c, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, x, b, c, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq; x (B, S, C), w (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pad[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
    return jax.nn.silu(out + b.astype(x.dtype))


def _segsum(a: jax.Array) -> jax.Array:
    """(..., L) -> (..., L, L) lower-triangular segment sums."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    # S[i, j] = sum_{j < k <= i} a_k = cs[i] - cs[j]
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
                chunk: int, init_state: jax.Array | None = None):
    """SSD scan (mamba2 Algorithm 1, chunked).

    x: (B, S, H, P) pre-scaled by dt; a: (B, S, H) = dt * A (negative);
    b, c: (B, S, G, N); heads grouped over G.  Returns (y, final_state).
    """
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g
    xf = x.astype(jnp.float32).reshape(bs, nc, chunk, h, p)
    af = a.astype(jnp.float32).reshape(bs, nc, chunk, h).transpose(0, 3, 1, 2)
    bf = b.astype(jnp.float32).reshape(bs, nc, chunk, g, n)
    cf = c.astype(jnp.float32).reshape(bs, nc, chunk, g, n)
    bfh = jnp.repeat(bf, rep, axis=3)                     # (B,NC,L,H,N)
    cfh = jnp.repeat(cf, rep, axis=3)

    a_cs = jnp.cumsum(af, axis=-1)                        # (B,H,NC,L)
    # 1. intra-chunk (quadratic inside the chunk)
    ll = jnp.exp(_segsum(af))                             # (B,H,NC,L,L)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        cfh, bfh, ll, xf)
    # 2. per-chunk end states
    decay = jnp.exp(a_cs[..., -1:] - a_cs)                # (B,H,NC,L)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", bfh, decay, xf)
    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(a_cs[..., -1])                  # (B,H,NC)

    def scan_fn(carry, inp):
        st, dec = inp                                     # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                                 # emit previous

    init = (jnp.zeros((bs, h, p, n), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,NC,H,P,N)
    # 4. state -> output within chunk
    state_decay = jnp.exp(a_cs)                           # (B,H,NC,L)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", cfh, prev_states,
                       state_decay)
    y = (y_diag + y_off).reshape(bs, s, h, p)
    return y.astype(x.dtype), final


def ssm_apply(p: dict, cfg: ModelCfg, u: jax.Array) -> jax.Array:
    """Full-sequence mamba2 block; u: (B, S, D)."""
    ssm = cfg.ssm
    d_in, n_heads, conv_dim = _dims(cfg)
    z, x, b, c, dt = _split_proj(cfg, u @ p["in_proj"])
    xbc = _causal_conv(jnp.concatenate([x, b, c], axis=-1),
                       p["conv_w"], p["conv_b"])
    x, b, c = jnp.split(xbc, [d_in, d_in + ssm.n_groups * ssm.d_state],
                        axis=-1)
    bs, s, _ = x.shape
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    xh = x.reshape(bs, s, n_heads, ssm.head_dim)
    a = -jnp.exp(p["a_log"])[None, None, :] * dt                 # (B,S,H)
    bg = b.reshape(bs, s, ssm.n_groups, ssm.d_state)
    cg = c.reshape(bs, s, ssm.n_groups, ssm.d_state)
    y, _ = ssd_chunked(xh * dt[..., None].astype(xh.dtype), a, bg, cg,
                       min(ssm.chunk, s))
    y = y + xh * p["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(bs, s, d_in) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# Decode: O(1) state recurrence
# ---------------------------------------------------------------------------

def ssm_decode_state(cfg: ModelCfg, batch: int):
    """Zero decode state: (ssd state, conv ring buffer)."""
    ssm = cfg.ssm
    d_in, n_heads, conv_dim = _dims(cfg)
    return {
        "ssd": jnp.zeros((batch, n_heads, ssm.head_dim, ssm.d_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, ssm.conv_width - 1, conv_dim),
                          jnp.float32),
    }


def ssm_decode(p: dict, cfg: ModelCfg, u: jax.Array, state: dict
               ) -> tuple[jax.Array, dict]:
    """One-token step; u: (B, 1, D)."""
    ssm = cfg.ssm
    d_in, n_heads, conv_dim = _dims(cfg)
    z, x, b, c, dt = _split_proj(cfg, u @ p["in_proj"])
    xbc = jnp.concatenate([x, b, c], axis=-1)[:, 0, :]    # (B, conv_dim)
    # conv ring buffer
    hist = jnp.concatenate([state["conv"],
                            xbc[:, None, :].astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", hist,
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:, :]
    x, b, c = jnp.split(conv_out, [d_in, d_in + ssm.n_groups * ssm.d_state],
                        axis=-1)
    bs = x.shape[0]
    dt = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32)
                         + p["dt_bias"])                  # (B,H)
    xh = x.reshape(bs, n_heads, ssm.head_dim).astype(jnp.float32)
    a = -jnp.exp(p["a_log"])[None, :] * dt                # (B,H)
    rep = n_heads // ssm.n_groups
    bg = jnp.repeat(b.reshape(bs, ssm.n_groups, ssm.d_state), rep, axis=1)
    cg = jnp.repeat(c.reshape(bs, ssm.n_groups, ssm.d_state), rep, axis=1)
    da = jnp.exp(a)                                       # (B,H)
    new_ssd = state["ssd"] * da[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xh * dt[..., None], bg)
    y = jnp.einsum("bhpn,bhn->bhp", new_ssd, cg)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bs, 1, d_in).astype(u.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"ssd": new_ssd, "conv": new_conv}
