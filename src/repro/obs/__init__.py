"""Unified observability for the serving stack (metrics + span tracing).

One dependency-free substrate shared by the whole pipeline — the
micro-batching tier (``repro.serve``), the compile-once engine
(``repro.engine``) and the truth-table compiler (``repro.compile``) all
record into the process-default :class:`Registry`, so a single
``obs.registry().snapshot()`` (or ``render_prometheus()``) answers both
"where did this request's latency go?" (queue-wait / assembly / device
histograms fed by per-request :class:`Span` traces) and "which compile
pass got slower?" (per-pass timing counters).  See
docs/observability.md for the full metric table and the span lifecycle.
"""

from repro.obs.metrics import (DEFAULT_TIME_BUCKETS, Counter, Family, Gauge,
                               Histogram, Registry, REGISTRY, registry)
from repro.obs.report import PeriodicReporter, summary_line
from repro.obs.trace import REQUEST_STAGES, Span

__all__ = ["Counter", "DEFAULT_TIME_BUCKETS", "Family", "Gauge",
           "Histogram", "PeriodicReporter", "REGISTRY", "REQUEST_STAGES",
           "Registry", "Span", "registry", "summary_line"]
