"""Dependency-free metrics primitives: counters, gauges, histograms.

The serving stack (tier -> engine -> compiler) needs stage-level
visibility — "where did this request's latency go?", "which compile pass
got slower?" — without pulling a metrics client into a package whose
runtime dependencies are jax + numpy.  This module is the substrate:

* :class:`Counter` — monotonically increasing float (``_total`` names);
* :class:`Gauge` — a settable level (queue depth, steady-state deltas);
* :class:`Histogram` — fixed bucket edges chosen at registration time
  (so two snapshots are always mergeable/comparable), plus ``sum`` and
  ``count``; ``quantile()`` gives the standard linearly-interpolated
  bucket estimate;
* labeled families — ``registry.counter("serve_flush_total",
  labels=("tier", "cause"))`` returns a :class:`Family` whose
  ``labels(tier="0", cause="size")`` children are created on first use
  and cached;
* :class:`Registry` — the name -> metric table with an atomic
  ``snapshot()`` (JSON-ready dict) and Prometheus-style
  ``render_prometheus()`` text exposition.

Thread-safety: metric mutation happens on the asyncio loop *and* in the
tier's executor threads, so every metric guards its state with its own
``threading.Lock`` and ``Registry.snapshot()`` reads each metric under
that lock — a snapshot never observes a histogram whose ``count`` and
bucket counts disagree.  The hot path stays a few lock-guarded float
adds: no allocation, no rendering, no I/O (the regression test in
tests/test_obs.py counts the per-request metric operations).
"""

from __future__ import annotations

import bisect
import json
import re
import threading

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# serving latencies live in the 100us..1s decades on CPU/interpret and
# sub-ms on TPU; the default edges cover both with ~2-2.5x spacing
DEFAULT_TIME_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5)


def _fmt(v: float) -> str:
    """Prometheus number formatting: integral values without the '.0'."""
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class Counter:
    """A monotonically increasing value.  ``inc(n)`` with ``n >= 0``."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counters only go up (inc by {n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _snapshot(self) -> float:
        return self.value


class Gauge:
    """A value that can go up and down (queue depth, contract deltas)."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _snapshot(self) -> float:
        return self.value


class Histogram:
    """Fixed-bucket histogram: per-bucket counts + sum + count.

    ``edges`` are the inclusive upper bounds of the finite buckets (must
    be strictly increasing); one overflow (+Inf) bucket is implicit.
    ``observe(v)`` costs one bisect + two adds under the metric lock.
    """

    __slots__ = ("_lock", "edges", "_counts", "_sum", "_count")

    def __init__(self, edges=DEFAULT_TIME_BUCKETS) -> None:
        edges = tuple(float(e) for e in edges)
        if not edges:
            raise ValueError("histogram needs at least one bucket edge")
        if any(b <= a for a, b in zip(edges, edges[1:])):
            raise ValueError(f"bucket edges must strictly increase: {edges}")
        self._lock = threading.Lock()
        self.edges = edges
        self._counts = [0] * (len(edges) + 1)   # +1: the +Inf bucket
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.edges, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def _snapshot(self) -> dict:
        with self._lock:
            return {"buckets": list(self.edges),
                    "counts": list(self._counts),
                    "sum": self._sum, "count": self._count}

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile estimate (0 <= q <= 1).

        The standard Prometheus ``histogram_quantile`` scheme: find the
        bucket holding the q-th observation and interpolate linearly
        inside it.  Returns ``nan`` on an empty histogram; an estimate
        landing in the +Inf bucket clamps to the largest finite edge.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        snap = self._snapshot()
        total = snap["count"]
        if total == 0:
            return float("nan")
        rank = q * total
        cum = 0.0
        for i, c in enumerate(snap["counts"]):
            cum += c
            if cum >= rank and c > 0:
                if i >= len(self.edges):          # +Inf bucket
                    return self.edges[-1]
                lo = 0.0 if i == 0 else self.edges[i - 1]
                hi = self.edges[i]
                return lo + (hi - lo) * (1.0 - (cum - rank) / c)
        return self.edges[-1]

    def mean(self) -> float:
        snap = self._snapshot()
        return snap["sum"] / snap["count"] if snap["count"] else float("nan")


class Family:
    """A labeled metric family: one child metric per label-value tuple."""

    __slots__ = ("_lock", "label_names", "_make", "_children")

    def __init__(self, label_names: tuple[str, ...], make) -> None:
        self._lock = threading.Lock()
        self.label_names = label_names
        self._make = make
        self._children: dict[tuple[str, ...], object] = {}

    def labels(self, **labels):
        """The child metric for this label set (created on first use)."""
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"expected labels {self.label_names}, got {tuple(labels)}")
        key = tuple(str(labels[k]) for k in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make())
        return child

    def _series(self) -> list[tuple[tuple[str, ...], object]]:
        with self._lock:
            return sorted(self._children.items())


class Registry:
    """Name -> metric table with atomic snapshot + text exposition.

    Registration is idempotent: asking for an existing name returns the
    existing metric, but re-registering under a different type, label
    set or bucket edges raises (two call sites silently disagreeing on a
    metric's meaning is the bug this catches).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[str, dict] = {}   # name -> entry

    # -- registration -------------------------------------------------------

    def _register(self, name: str, mtype: str, help_: str,
                  labels: tuple[str, ...], make):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labels:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        with self._lock:
            entry = self._metrics.get(name)
            if entry is not None:
                if entry["type"] != mtype or entry["labels"] != labels:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{entry['type']}{entry['labels']}; cannot "
                        f"re-register as {mtype}{labels}")
                if (mtype == "histogram" and not labels
                        and entry["metric"].edges != make().edges):
                    raise ValueError(
                        f"histogram {name!r} already registered with "
                        f"different bucket edges")
                return entry["metric"]
            metric = Family(labels, make) if labels else make()
            self._metrics[name] = {"type": mtype, "help": help_,
                                   "labels": labels, "metric": metric}
            return metric

    def counter(self, name: str, help: str = "",
                labels: tuple[str, ...] = ()) -> Counter | Family:
        return self._register(name, "counter", help, tuple(labels), Counter)

    def gauge(self, name: str, help: str = "",
              labels: tuple[str, ...] = ()) -> Gauge | Family:
        return self._register(name, "gauge", help, tuple(labels), Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_TIME_BUCKETS,
                  labels: tuple[str, ...] = ()) -> Histogram | Family:
        edges = tuple(float(b) for b in buckets)
        return self._register(name, "histogram", help, tuple(labels),
                              lambda: Histogram(edges))

    def get(self, name: str):
        """The registered metric (or Family) under ``name``; None if
        absent — readers (stats bridges, tests) use this so a read never
        implicitly registers."""
        with self._lock:
            entry = self._metrics.get(name)
            return entry["metric"] if entry else None

    # -- export -------------------------------------------------------------

    def _entries(self) -> list[tuple[str, dict]]:
        with self._lock:
            return sorted(self._metrics.items())

    def snapshot(self) -> dict:
        """JSON-ready view of every metric: ``{name: {type, help,
        label_names, series: [{labels, value|buckets...}]}}``.

        Each *series* is read under its metric's lock, so any single
        metric is internally consistent (histogram ``count`` == sum of
        its bucket counts) even while other threads keep incrementing.
        """
        out: dict = {}
        for name, entry in self._entries():
            metric, labels = entry["metric"], entry["labels"]
            if labels:
                series = [
                    {"labels": dict(zip(labels, key)),
                     **self._value_dict(entry["type"], child)}
                    for key, child in metric._series()]
            else:
                series = [{"labels": {},
                           **self._value_dict(entry["type"], metric)}]
            out[name] = {"type": entry["type"], "help": entry["help"],
                         "label_names": list(labels), "series": series}
        return out

    @staticmethod
    def _value_dict(mtype: str, metric) -> dict:
        snap = metric._snapshot()
        return snap if mtype == "histogram" else {"value": snap}

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (v0.0.4) of the registry."""
        lines: list[str] = []
        for name, entry in sorted(self.snapshot().items()):
            if entry["help"]:
                lines.append(f"# HELP {name} {entry['help']}")
            lines.append(f"# TYPE {name} {entry['type']}")
            for series in entry["series"]:
                lbl = series["labels"]
                if entry["type"] == "histogram":
                    cum = 0
                    for edge, c in zip(series["buckets"], series["counts"]):
                        cum += c
                        lines.append(
                            f"{name}_bucket"
                            f"{_label_str({**lbl, 'le': _fmt(edge)})} {cum}")
                    cum += series["counts"][-1]
                    lines.append(
                        f"{name}_bucket"
                        f"{_label_str({**lbl, 'le': '+Inf'})} {cum}")
                    lines.append(
                        f"{name}_sum{_label_str(lbl)} "
                        f"{_fmt(series['sum'])}")
                    lines.append(
                        f"{name}_count{_label_str(lbl)} {series['count']}")
                else:
                    lines.append(
                        f"{name}{_label_str(lbl)} {_fmt(series['value'])}")
        return "\n".join(lines) + "\n"

    def dump_json(self, path: str) -> str:
        """Write ``snapshot()`` as JSON (the ``--metrics-json`` payload)."""
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")
        return path


def _label_str(labels: dict) -> str:
    if not labels:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + body + "}"


# the process-default registry: the serving tier, engine and compiler all
# record here so one snapshot covers the whole stack (tests needing
# isolation construct their own Registry)
REGISTRY = Registry()


def registry() -> Registry:
    """The process-default :class:`Registry`."""
    return REGISTRY
