"""Operator-facing exporters: the one-line reporter and summary text.

``summary_line(registry)`` compresses the serving stack's metrics into a
single log line (requests, batches, stage latencies, compile-once
counters); :class:`PeriodicReporter` prints it from a daemon thread every
``interval_s`` while a load run is in flight — the ``python -m
repro.launch.serve --lut`` CLI starts one so long-running serves are not
silent between start and the final report.
"""

from __future__ import annotations

import sys
import threading

from repro.obs.metrics import Registry, registry as default_registry


def _sum_series(snapshot: dict, name: str, field: str = "value") -> float:
    entry = snapshot.get(name)
    if not entry:
        return 0.0
    return sum(s.get(field, 0.0) for s in entry["series"])


def _hist_totals(snapshot: dict, name: str) -> tuple[int, float]:
    entry = snapshot.get(name)
    if not entry:
        return 0, 0.0
    return (int(sum(s["count"] for s in entry["series"])),
            sum(s["sum"] for s in entry["series"]))


def summary_line(reg: Registry | None = None) -> str:
    """One line of the serving stack's state, for periodic logging."""
    snap = (reg or default_registry()).snapshot()
    requests = _sum_series(snap, "serve_requests_total")
    rows = _sum_series(snap, "serve_rows_total")
    batches = _sum_series(snap, "serve_batches_total")
    parts = [f"requests={requests:.0f}", f"rows={rows:.0f}",
             f"batches={batches:.0f}"]
    for label, name in (("queue_wait", "serve_queue_wait_seconds"),
                        ("device", "serve_device_seconds")):
        n, total = _hist_totals(snap, name)
        if n:
            parts.append(f"{label}_mean={total / n * 1e3:.2f}ms")
    retr = _sum_series(snap, "serve_retraces_after_warmup")
    cruns = _sum_series(snap, "serve_compiler_runs_after_warmup")
    parts.append(f"retraces={retr:.0f}")
    parts.append(f"compiler_runs={cruns:.0f}")
    return "[obs] " + " ".join(parts)


class PeriodicReporter:
    """Daemon thread printing :func:`summary_line` every ``interval_s``.

    Start/stop explicitly or use as a context manager; ``stop()`` joins
    the thread, so nothing prints after it returns.  A non-positive
    interval disables the thread entirely (the CLI's ``--report-every-s
    0``).
    """

    def __init__(self, interval_s: float = 5.0,
                 reg: Registry | None = None, stream=None) -> None:
        self.interval_s = interval_s
        self._reg = reg or default_registry()
        self._stream = stream if stream is not None else sys.stderr
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def start(self) -> "PeriodicReporter":
        if self.interval_s > 0 and self._thread is None:
            self._thread = threading.Thread(
                target=self._run, name="obs-reporter", daemon=True)
            self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            print(summary_line(self._reg), file=self._stream, flush=True)

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "PeriodicReporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
