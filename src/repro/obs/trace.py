"""Lightweight request-span tracing: where did this request's latency go?

A :class:`Span` is a named sequence of monotonic timestamps
(``time.perf_counter``): created at the first stage, ``mark(stage)``
appends one, and the finished span yields per-stage durations.  The
serving tier attaches one span to every request over its lifecycle::

    enqueue -> flush -> dispatch -> done
      |queue wait|assembly|device time|

* **queue wait** (``enqueue -> flush``) — time spent queued before the
  batcher's flush decision took the request into a batch;
* **assembly** (``flush -> dispatch``) — batch concatenation + executor
  hand-off, host-side work on the batch path;
* **device time** (``dispatch -> done``) — the padded batch inside the
  (possibly sharded) jitted forward, result included.

Marking costs one ``perf_counter()`` call and a list append — cheap
enough to stay on unconditionally (the hot-path regression test in
tests/test_obs.py bounds the per-request metric work).  Finished spans
feed stage histograms in the metrics registry; the tier keeps the last
few in a ring for debugging (``ServingTier.recent_spans()``).
"""

from __future__ import annotations

import time

# the serving tier's request lifecycle, in order (docs/observability.md
# documents the derived stage durations)
REQUEST_STAGES = ("enqueue", "flush", "dispatch", "done")


class Span:
    """An ordered list of (stage, monotonic timestamp) marks."""

    __slots__ = ("name", "marks")

    def __init__(self, name: str, first_stage: str = "enqueue",
                 t: float | None = None) -> None:
        self.name = name
        self.marks: list[tuple[str, float]] = [
            (first_stage, time.perf_counter() if t is None else t)]

    def mark(self, stage: str, t: float | None = None) -> None:
        """Record ``stage`` at ``t`` (default: now).  Out-of-order
        timestamps are accepted — the batcher stamps whole batches with
        shared times — but stages must be unique within one span."""
        self.marks.append((stage, time.perf_counter() if t is None else t))

    def duration(self, a: str, b: str) -> float:
        """Seconds from stage ``a`` to stage ``b`` (KeyError if absent)."""
        times = dict(self.marks)
        return times[b] - times[a]

    def durations(self) -> dict[str, float]:
        """``{"stage_a->stage_b": seconds}`` between consecutive marks."""
        return {f"{a}->{c}": t2 - t1
                for (a, t1), (c, t2) in zip(self.marks, self.marks[1:])}

    @property
    def total(self) -> float:
        """Seconds from the first mark to the last."""
        return self.marks[-1][1] - self.marks[0][1]

    def as_dict(self) -> dict:
        return {"name": self.name,
                "stages": [s for s, _ in self.marks],
                "durations": self.durations(),
                "total": self.total}

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        legs = " ".join(f"{k}={v * 1e3:.2f}ms"
                        for k, v in self.durations().items())
        return f"<Span {self.name} {legs}>"
