"""Training substrate: AdamW (masked, mixed-precision), schedules,
gradient accumulation and compression."""
