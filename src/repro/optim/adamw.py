"""AdamW from scratch: pytree-native, mask-aware, mixed-precision.

* m/v moments in fp32 regardless of param dtype (bf16-safe).
* Path-based policies instead of parallel trees (no structure headaches):
  - ``freeze_fn(path) -> bool``       : leaf gets no update.  Default
    freezes any leaf whose path mentions 'mask' — LogicNets fan-in masks
    live inside the param tree and must never be optimized.
  - ``mask_fn(path, params) -> array | None`` : binary mask applied to the
    leaf's gradient *and* post-update value, keeping pruned weights exactly
    zero (the per-neuron sparsity invariant survives training).
* Global-norm clipping; decoupled weight decay; any schedule fn.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.tree_util import tree_map_with_path, keystr


@dataclasses.dataclass(frozen=True)
class AdamWCfg:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    schedule: Callable[[jax.Array], jax.Array] | None = None


def default_freeze(path: str) -> bool:
    return "mask" in path


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWCfg, params: Any, grads: Any, state: dict,
                 mask_fn: Callable[[str, Any], Any] | None = None,
                 freeze_fn: Callable[[str], bool] = default_freeze,
                 ) -> tuple[Any, dict]:
    step = state["step"] + 1
    lr = cfg.lr if cfg.schedule is None else cfg.lr * cfg.schedule(step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm > 0 else jnp.asarray(1.0)

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        spath = keystr(path)
        if freeze_fn(spath):
            return p, m, v
        mask = mask_fn(spath, params) if mask_fn is not None else None
        g = g.astype(jnp.float32) * scale
        if mask is not None:
            g = g * mask.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        if mask is not None:
            new_p = new_p * mask.astype(jnp.float32)
        return new_p.astype(p.dtype), m, v

    out = tree_map_with_path(upd, params, grads, state["m"], state["v"])
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is3)
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is3)
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return fn


def logicnet_mask_fn(path: str, params: Any):
    """Mask rule for LM-scale LogicNet-FFN layers: weight leaves named
    wi_gate/wi_up/wo with sibling masks get the sibling mask applied."""
    import re
    m = re.search(r"(.*)\['(wi_gate|wi_up|wo)'\]$", path)
    if m is None:
        return None
    # Resolve the sibling mask in the params tree.
    prefix, leaf = m.group(1), m.group(2)
    keys = re.findall(r"\['([^']+)'\]", prefix)
    node = params
    for k in keys:
        node = node[k]
    if not isinstance(node, dict):
        return None
    name = "mask_out" if leaf == "wo" else "mask_in"
    return node.get(name)
