"""Gradient compression for the cross-pod axis (beyond-paper, optional).

int8 quantization with per-leaf scales and error feedback: the quantization
residual is carried to the next step so compression bias vanishes in
expectation (1-bit-Adam-style argument).  Applied before the DP reduction
when enabled; the paper itself notes gradient quantization "saves on
communication cost in distributed training" (§1.2.1) while warning about
convergence — error feedback is the standard mitigation, and the parity
test (tests/test_optim.py) checks convergence on a small model.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(grads: Any, err: Any
                                 ) -> tuple[Any, Any]:
    """Returns (decompressed grads as seen post-allreduce, new error state).

    The int8 round-trip models what the wire carries; the residual feeds
    back into the next step's gradient.
    """
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = compress_int8(g)
        deq = decompress_int8(q, s)
        return deq, g - deq

    out = jax.tree.map(one, grads, err)
    is2 = lambda x: isinstance(x, tuple) and len(x) == 2
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=is2)
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=is2)
    return deq, new_err
