"""Distribution layer: logical-axis sharding rules + activation contexts."""
