"""Activation-sharding context.

Model code annotates activations with *logical* axis names
(``constrain(x, ("act_batch", None, "act_vocab"))``); the launch layer
activates a mesh + rule set that maps logical names to mesh axes.  With no
active context the calls are no-ops, so the same model code runs single-
device smoke tests and 512-device dry-runs unchanged.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict[str, tuple | str | None]):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, rules)
    try:
        yield
    finally:
        _STATE.ctx = prev


def current() -> tuple[Mesh, dict] | None:
    return getattr(_STATE, "ctx", None)


def constrain(x: jax.Array, logical: tuple) -> jax.Array:
    ctx = current()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = []
    for name in logical:
        axes = rules.get(name) if name is not None else None
        spec.append(axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
