"""Sharding rules: param-path regexes -> PartitionSpecs, shape-validated.

Policy tokens per tensor dimension:
  'fsdp' -> the data(-and-pod) axes: ZeRO-3 style weight sharding; XLA
            inserts per-layer all-gathers and grad reduce-scatters.
  'tp'   -> the model axis: tensor/expert parallelism.
  None   -> replicated.

Rules are *candidates*: at resolution each dim's axes are dropped unless
the dim is divisible by the axis product (e.g. 4 KV heads cannot shard a
16-way model axis -> replicated, the standard GQA fallback).  This is what
lets one rule set serve 10 architectures x arbitrary meshes.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import tree_map_with_path, keystr


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Maps rule tokens to mesh axes."""

    fsdp: tuple[str, ...] = ("data",)
    tp: tuple[str, ...] = ("model",)
    # 'fsdp_tp' shards weights over both; 'tp_only' replicates over data
    # (pure TP — a §Perf comparison point).
    mode: str = "fsdp_tp"

    def axes_for(self, token) -> tuple[str, ...] | None:
        if token is None:
            return None
        if token == "tp":
            return self.tp
        if token == "fsdp":
            return None if self.mode == "tp_only" else self.fsdp
        if token == "dp":
            return self.fsdp
        raise ValueError(token)


def multi_pod_policy(mode: str = "fsdp_tp") -> ShardingPolicy:
    return ShardingPolicy(fsdp=("pod", "data"), tp=("model",), mode=mode)


# (path regex, per-dim tokens).  Stacked layer params carry a leading
# layer dim (always None).  First match wins.
PARAM_RULES: list[tuple[str, tuple]] = [
    # embeddings: vocab over tp (sharded logits), d_model over fsdp
    (r"\['embed'\]\['(tok|head)'\]$", ("tp", "fsdp")),
    (r"\['pos_emb_enc'\]$", (None, "fsdp")),
    # attention
    (r"\['attn'\]\['wq'\]$", (None, "fsdp", "tp", None)),
    (r"\['attn'\]\['w[kv]'\]$", (None, "fsdp", "tp", None)),
    (r"\['attn'\]\['wo'\]$", (None, "tp", None, "fsdp")),
    (r"\['(xattn)'\]\['wq'\]$", (None, "fsdp", "tp", None)),
    (r"\['(xattn)'\]\['w[kv]'\]$", (None, "fsdp", "tp", None)),
    (r"\['(xattn)'\]\['wo'\]$", (None, "tp", None, "fsdp")),
    (r"\['(q_norm|k_norm)'\]$", None),                     # tiny: replicate
    # dense FFN (LogicNet-FFN masks shard like their weights — replicating
    # them cost 16 GiB/chip at the qwen3-1.7b technique cell, §Perf HC3)
    (r"\['ffn'\]\['wi_(gate|up)'\]$", (None, "fsdp", "tp")),
    (r"\['ffn'\]\['mask_in'\]$", (None, "fsdp", "tp")),
    (r"\['ffn'\]\['wo'\]$", (None, "tp", "fsdp")),
    (r"\['ffn'\]\['mask_out'\]$", (None, "tp", "fsdp")),
    # MoE: experts over tp (EP), d_model over fsdp
    (r"\['moe'\]\['router'\]$", (None, "fsdp", None)),
    (r"\['moe'\]\['wi_(gate|up)'\]$", (None, "tp", "fsdp", None)),
    (r"\['moe'\]\['wo'\]$", (None, "tp", None, "fsdp")),
    # SSM
    (r"\['ssm'\]\['in_proj'\]$", (None, "fsdp", "tp")),
    (r"\['ssm'\]\['conv_w'\]$", (None, None, "tp")),
    (r"\['ssm'\]\['conv_b'\]$", (None, "tp")),
    (r"\['ssm'\]\['out_proj'\]$", (None, "tp", "fsdp")),
    (r"\['ssm'\]\['(a_log|d_skip|dt_bias|norm)'\]$", None),
    # shared (unstacked) hybrid attention block: same but no layer dim
    (r"\['shared_attn'\].*\['wq'\]$", ("fsdp", "tp", None)),
    (r"\['shared_attn'\].*\['w[kv]'\]$", ("fsdp", "tp", None)),
    (r"\['shared_attn'\].*\['wo'\]$", ("tp", None, "fsdp")),
    (r"\['shared_attn'\]\['ffn'\]\['wi_(gate|up)'\]$", ("fsdp", "tp")),
    (r"\['shared_attn'\]\['ffn'\]\['wo'\]$", ("tp", "fsdp")),
    # norms and anything small: replicated
    (r".*", None),
]


def _mesh_axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def resolve_spec(shape: tuple[int, ...], tokens, policy: ShardingPolicy,
                 mesh: Mesh) -> P:
    """Validated PartitionSpec: drop axes that don't divide the dim."""
    if tokens is None:
        return P()
    # Right-align tokens to the shape (stacked layer params gained a
    # leading layer axis relative to the per-layer rule).
    tokens = tuple(tokens)
    if len(tokens) < len(shape):
        tokens = (None,) * (len(shape) - len(tokens)) + tokens
    elif len(tokens) > len(shape):
        tokens = tokens[-len(shape):]
    spec = []
    for dim, tok in zip(shape, tokens):
        axes = policy.axes_for(tok)
        if axes is None or dim % _mesh_axis_size(mesh, axes) != 0:
            spec.append(None)
        else:
            spec.append(axes if len(axes) > 1 else axes[0])
    return P(*spec)


def spec_for_path(path: str, shape: tuple[int, ...],
                  policy: ShardingPolicy, mesh: Mesh) -> P:
    for pattern, tokens in PARAM_RULES:
        if re.search(pattern, path):
            return resolve_spec(shape, tokens, policy, mesh)
    return P()


def shardings_for_tree(tree: Any, mesh: Mesh,
                       policy: ShardingPolicy) -> Any:
    """Pytree of NamedShardings for a pytree of arrays/ShapeDtypeStructs."""
    def one(path, leaf):
        spec = spec_for_path(keystr(path), tuple(leaf.shape), policy, mesh)
        return NamedSharding(mesh, spec)
    return tree_map_with_path(one, tree)


# ---------------------------------------------------------------------------
# Batch / cache / activation shardings
# ---------------------------------------------------------------------------

def batch_specs(policy: ShardingPolicy, mesh: Mesh,
                batch_shapes: Any) -> Any:
    """Inputs: leading batch dim over dp axes (dropped if not divisible)."""
    def one(path, leaf):
        dims = len(leaf.shape)
        tokens = ("dp",) + (None,) * (dims - 1)
        return NamedSharding(mesh,
                             resolve_spec(leaf.shape, tokens, policy, mesh))
    return tree_map_with_path(one, batch_shapes)


def cache_specs(policy: ShardingPolicy, mesh: Mesh, cache_shapes: Any,
                cache_shard: str = "heads") -> Any:
    """KV/SSM caches: (L, B, S, H, D)-style.

    cache_shard='heads' (baseline): batch over dp, kv-heads over tp (with
    the GQA divisibility fallback -> replicated when kv_heads < tp size).
    cache_shard='seq' (§Perf 'seqshard'): shard the sequence dim over tp —
    always divisible, removes the kv-head replication that put a 32k x
    batch-128 cache at 56 GiB/chip.  Attention over a seq-sharded cache
    becomes a partial-softmax + all-reduce (flash-style distributed
    attention), which XLA inserts from the shardings.
    Batch=1 long-decode cells always fall back to seq sharding.
    """
    def one(path, leaf):
        shape = leaf.shape
        ps = keystr(path)
        dims = len(shape)
        if "ssm" in ps:
            # (L, B, H, P, N) state / (L, B, W, C) conv: heads/channels tp
            tokens = (None, "dp") + (("tp",) + (None,) * (dims - 3)
                                     if dims >= 3 else ())
        else:
            # (L/sites, B, S, Hkv, hd)
            tokens = [None, "dp", None, "tp", None][:dims]
            dp_size = _mesh_axis_size(mesh, policy.fsdp)
            if cache_shard == "seq" or shape[1] % dp_size != 0:
                tokens[2] = "tp"
                tokens[3] = None
            tokens = tuple(tokens)
        return NamedSharding(mesh,
                             resolve_spec(shape, tokens, policy, mesh))
    return tree_map_with_path(one, cache_shapes)


def activation_rules(policy: ShardingPolicy) -> dict[str, Any]:
    """Logical activation axis names -> mesh axes (parallel.ctx rules)."""
    fsdp = policy.fsdp
    return {
        "act_batch": fsdp if len(fsdp) > 1 else fsdp[0],
        "act_embed": None,
        "act_vocab": policy.tp[0] if len(policy.tp) == 1 else policy.tp,
        "act_heads": policy.tp[0] if len(policy.tp) == 1 else policy.tp,
    }
