"""Fault-tolerant training runtime."""

from repro.runtime.loop import TrainLoop, TrainLoopCfg  # noqa: F401
