"""Fault-tolerant train loop: checkpoint/restart, NaN guard, elastic restore.

What runs here (and is unit-tested on CPU):
  * periodic async checkpoints (keep-k, atomic) + exact restore of
    (params, opt state, step) — restart resumes bit-identically;
  * NaN/inf step guard: a bad step is *skipped* (state not committed) and
    counted; too many consecutive bad steps aborts to last checkpoint;
  * deterministic per-host data sharding keyed by (seed, step, host) — a
    restarted or re-sharded job never replays/skips data;
  * elastic restore: checkpoints are mesh-agnostic host arrays; restoring
    onto a different device count re-shards via the sharding_fn hook.

What can only be described here (no fleet on this container), and how the
design covers it:
  * node failure: single-controller SPMD fails the step; the operator (or
    a supervisor like borg/k8s) restarts the job, which calls
    ``restore_latest`` — bounded loss = checkpoint interval;
  * stragglers: the step is a global barrier; mitigation = (a) async
    checkpoint writes off the critical path (implemented), (b) the
    microbatch grain is per-host so a hot spare replacing a slow host
    changes nothing semantically (data is host-indexed, not rank-pinned),
    (c) gradient compression (optim/compress.py) shrinks the cross-pod
    reduction that magnifies jitter.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointManager

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class TrainLoopCfg:
    ckpt_dir: str
    ckpt_every: int = 100
    keep: int = 3
    async_save: bool = True
    max_bad_steps: int = 10


class TrainLoop:
    """Drives step_fn over a data stream with checkpoint/restart."""

    def __init__(self, cfg: TrainLoopCfg,
                 step_fn: Callable[[Any, dict], tuple[Any, jax.Array]],
                 state: Any):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = state
        self.step = 0
        self.bad_steps = 0
        self.metrics: list[tuple[int, float]] = []
        self.mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep,
                                     async_save=cfg.async_save)

    def try_restore(self, sharding_fn=None) -> bool:
        out = self.mgr.restore_latest(
            {"state": self.state, "step": np.asarray(self.step)},
            sharding_fn)
        if out is None:
            return False
        ckpt_step, tree = out
        self.state = tree["state"]
        self.step = int(tree["step"])
        log.info("restored checkpoint at step %d", self.step)
        return True

    def run(self, batches: Callable[[int], dict], n_steps: int) -> Any:
        while self.step < n_steps:
            batch = batches(self.step)
            new_state, loss = self.step_fn(self.state, batch)
            loss_val = float(jax.device_get(loss))
            if not np.isfinite(loss_val):
                # Skip the step: do not commit state. Deterministic data
                # means a post-restart replay hits the same batch, so we
                # also advance past it.
                self.bad_steps += 1
                log.warning("non-finite loss at step %d (%d consecutive)",
                            self.step, self.bad_steps)
                if self.bad_steps >= self.cfg.max_bad_steps:
                    raise FloatingPointError(
                        f"{self.bad_steps} consecutive non-finite steps; "
                        "restore from checkpoint and lower lr")
                self.step += 1
                continue
            self.bad_steps = 0
            self.state = new_state
            self.metrics.append((self.step, loss_val))
            self.step += 1
            if self.step % self.cfg.ckpt_every == 0:
                self.mgr.save(self.step,
                              {"state": self.state,
                               "step": np.asarray(self.step)})
        self.mgr.wait()
        return self.state
