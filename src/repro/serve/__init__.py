"""Async micro-batching serving tier for compiled LUT networks.

``repro.engine`` produces the artifact (compile once, save/load, zero
steady-state re-traces); ``repro.serve`` is the request side — a
continuous queue that coalesces concurrent requests into
``block_b``-bucketed batches, shards the batch axis across devices with
``jax.sharding`` when more than one device exists, applies bounded-queue
backpressure and per-request timeouts, and degrades gracefully to a plain
single-device engine call.  See docs/serving.md for the lifecycle and
knobs, ``python -m repro.launch.serve --lut`` for the CLI front-end, and
the bench's ``serving_tier`` section for the gated p50/p99/QPS numbers.
"""

from repro.serve.loadgen import (LoadReport, make_requests,
                                 run_closed_loop)
from repro.serve.tier import (RequestTimeout, ServingTier, TierClosed,
                              TierConfig, TierError, TierOverloaded,
                              run_requests, serve_once)

__all__ = ["LoadReport", "RequestTimeout", "ServingTier", "TierClosed",
           "TierConfig", "TierError", "TierOverloaded", "make_requests",
           "run_closed_loop", "run_requests", "serve_once"]
