"""Async micro-batching serving tier for compiled LUT networks.

``repro.engine`` produces the artifact (compile once, save/load, zero
steady-state re-traces); ``repro.serve`` is the request side — a
continuous queue that coalesces concurrent requests into
``block_b``-bucketed batches, shards the batch axis across devices with
``jax.sharding`` when more than one device exists, applies bounded-queue
backpressure and per-request timeouts, and degrades gracefully to a plain
single-device engine call.  :class:`HttpIngress` puts a network front
door on the tier (JSON / raw-int8 over HTTP, per-tenant token-bucket
quotas, typed 429/503/408 mappings, ``/metrics`` + ``/healthz``), and
the load generators measure it both closed-loop (steady state) and
open-loop (Poisson arrivals — behavior *under overload*).  See
docs/serving.md and docs/ingress.md for the lifecycle and knobs,
``python -m repro.launch.serve --lut`` for the CLI front-end, and the
bench's ``serving_tier`` / ``ingress`` sections for the gated numbers.
"""

from repro.serve.ingress import (BackgroundIngress, HttpClientPool,
                                 HttpIngress, IngressConfig, QuotaConfig,
                                 QuotaExceeded, TokenBucket, http_infer)
from repro.serve.loadgen import (LoadReport, make_requests,
                                 poisson_arrivals, run_closed_loop,
                                 run_open_loop)
from repro.serve.tier import (RequestTimeout, ServingTier, TierClosed,
                              TierConfig, TierError, TierOverloaded,
                              run_requests, serve_once)

__all__ = ["BackgroundIngress", "HttpClientPool", "HttpIngress",
           "IngressConfig", "LoadReport", "QuotaConfig", "QuotaExceeded",
           "RequestTimeout", "ServingTier", "TierClosed", "TierConfig",
           "TierError", "TierOverloaded", "TokenBucket", "http_infer",
           "make_requests", "poisson_arrivals", "run_closed_loop",
           "run_open_loop", "run_requests", "serve_once"]
