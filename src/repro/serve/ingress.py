"""HTTP ingress for the micro-batching serving tier (stdlib asyncio only).

The paper's target regimes — CERN-style triggers, pre-distortion front-ends
— are *network-facing* services, so the in-process
:class:`~repro.serve.ServingTier` (PR 6) needs a real front door.  This
module is that door, built on ``asyncio.start_server`` so the package's
runtime dependencies stay jax + numpy:

* **inference endpoint** — ``POST /v1/infer`` terminates JSON
  (``{"codes": [[...], ...]}`` -> ``{"outputs": [[...], ...]}``) or raw
  int8 bodies (``application/octet-stream``: ``rows * n_in`` int8 codes in,
  ``rows * n_out`` int8 codes out) and feeds ``ServingTier.infer`` — the
  response is bit-exact with calling the artifact directly;
* **per-tenant admission** — a token-bucket row quota keyed by the tenant
  header (default ``x-tenant``) sits *in front of* the tier's row-bound
  backpressure: the bucket refills at ``rate_rows_per_s`` up to
  ``burst_rows``, and a request whose rows exceed the tenant's balance is
  rejected with **429** before it can occupy queue space;
* **typed error mapping** — every failure is an HTTP status carrying a JSON
  body, never a wedged connection: quota rejection -> **429**,
  :class:`TierOverloaded` -> **503**, :class:`RequestTimeout` -> **408**,
  :class:`TierClosed` (draining) -> **503**, malformed request -> **400**
  (the full table lives in docs/ingress.md);
* **operations endpoints** — ``GET /metrics`` renders the process
  :class:`repro.obs.Registry` as Prometheus text exposition,
  ``GET /healthz`` reports draining state + tier counters;
* **graceful drain** — ``stop()`` (the CLI wires it to SIGTERM) stops
  accepting connections, answers new inference requests with 503
  ``draining``, lets in-flight requests finish, and drains the tier's
  queue into final batches.

Keep-alive HTTP/1.1 is supported (the open-loop load generator and curl
both reuse connections); anything fancier — TLS, HTTP/2, gRPC — is out of
scope (see the ROADMAP's streaming-ingress open item).

The per-request metrics (``ingress_requests_total`` by route/status,
``ingress_rejected_total`` by reason, decode/infer stage histograms) are
documented in docs/observability.md.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import threading
import time

import numpy as np

from repro import obs
from repro.serve.tier import (RequestTimeout, ServingTier, TierClosed,
                              TierConfig, TierError, TierOverloaded)

_REASONS = {
    200: "OK", 400: "Bad Request", 404: "Not Found",
    405: "Method Not Allowed", 408: "Request Timeout",
    413: "Payload Too Large", 429: "Too Many Requests",
    500: "Internal Server Error", 503: "Service Unavailable",
}


class QuotaExceeded(TierError):
    """The tenant's token-bucket row quota is exhausted (HTTP 429)."""


@dataclasses.dataclass(frozen=True)
class QuotaConfig:
    """Per-tenant admission quota (a token bucket over request *rows*).

    Each tenant (the value of the tenant header; absent -> the shared
    ``default`` tenant) gets its own bucket holding up to ``burst_rows``
    tokens, refilled continuously at ``rate_rows_per_s``.  A request
    costing ``rows`` tokens is admitted only if the bucket holds that
    many; otherwise it is rejected with 429 *before* touching the tier's
    queue — quota protects tenants from each other, backpressure
    (``max_queue_rows``) protects the process from everyone.
    """

    rate_rows_per_s: float
    burst_rows: float | None = None   # default: one second of rate

    @property
    def burst(self) -> float:
        return (self.rate_rows_per_s if self.burst_rows is None
                else self.burst_rows)


class TokenBucket:
    """Continuous-refill token bucket; time source injectable for tests."""

    __slots__ = ("rate", "burst", "_tokens", "_t")

    def __init__(self, rate: float, burst: float,
                 now: float | None = None) -> None:
        if rate <= 0 or burst <= 0:
            raise ValueError("token bucket rate and burst must be positive")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._t = time.monotonic() if now is None else now

    def try_take(self, n: float, now: float | None = None) -> bool:
        """Take ``n`` tokens if available; refill happens lazily here."""
        now = time.monotonic() if now is None else now
        if now > self._t:
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t) * self.rate)
        self._t = max(self._t, now)
        if n <= self._tokens:
            self._tokens -= n
            return True
        return False

    @property
    def tokens(self) -> float:
        return self._tokens


@dataclasses.dataclass(frozen=True)
class IngressConfig:
    """Knobs of the HTTP front-end (the tier has its own ``TierConfig``).

    * ``host`` / ``port`` — listen address; port ``0`` binds an ephemeral
      port (read it back from ``HttpIngress.port`` — tests and the
      ``--http 0`` CLI do).
    * ``quota`` — per-tenant :class:`QuotaConfig`; ``None`` disables
      admission control entirely (the tier's backpressure still applies).
    * ``tenant_header`` / ``default_tenant`` — where the tenant id comes
      from and what an anonymous request maps to.
    * ``max_body_bytes`` — requests larger than this get 413 without
      being buffered further.
    """

    host: str = "127.0.0.1"
    port: int = 0
    quota: QuotaConfig | None = None
    tenant_header: str = "x-tenant"
    default_tenant: str = "default"
    max_body_bytes: int = 8 << 20


class _IngressMetrics:
    """The ingress's slice of the process metrics registry."""

    def __init__(self) -> None:
        reg = obs.registry()
        self.requests = reg.counter(
            "ingress_requests_total", "HTTP requests by route and status",
            labels=("route", "status"))
        self.rejected = reg.counter(
            "ingress_rejected_total",
            "inference requests rejected, by reason "
            "(quota / overloaded / timeout / draining)",
            labels=("reason",))
        self.request_seconds = reg.histogram(
            "ingress_request_seconds",
            "whole HTTP request (read -> response flushed)")
        self.decode_seconds = reg.histogram(
            "ingress_decode_seconds",
            "request body parse + validation (JSON or raw int8)")
        self.infer_seconds = reg.histogram(
            "ingress_infer_seconds",
            "await ServingTier.infer (queue wait + batch + device)")
        self.connections = reg.gauge(
            "ingress_open_connections", "currently open HTTP connections")


class HttpIngress:
    """Asyncio HTTP server owning one :class:`ServingTier` over ``net``.

    Lifecycle: ``await ingress.start()`` (starts the tier — warmup
    included — then binds the listener), any number of concurrent HTTP
    requests, ``await ingress.stop()`` (graceful drain).  Use
    :class:`BackgroundIngress` to run it from synchronous code.
    """

    def __init__(self, net, tier_config: TierConfig | None = None,
                 config: IngressConfig | None = None):
        self._net = net
        self._cfg = config or IngressConfig()
        self.tier = ServingTier(net, tier_config)
        self._buckets: dict[str, TokenBucket] = {}
        self._server: asyncio.AbstractServer | None = None
        self._conn_tasks: set[asyncio.Task] = set()
        self._draining = False
        self._metrics = _IngressMetrics()
        self.port: int | None = None

    @property
    def url(self) -> str:
        return f"http://{self._cfg.host}:{self.port}"

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "HttpIngress":
        await self.tier.start()
        self._server = await asyncio.start_server(
            self._handle_connection, self._cfg.host, self._cfg.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        """Graceful drain: stop accepting, finish in-flight, drain tier."""
        if self._draining:
            return
        self._draining = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if self._conn_tasks:
            await asyncio.gather(*self._conn_tasks, return_exceptions=True)
        await self.tier.stop()

    async def __aenter__(self) -> "HttpIngress":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- connection handling ------------------------------------------------

    async def _handle_connection(self, reader, writer) -> None:
        task = asyncio.current_task()
        self._conn_tasks.add(task)
        self._metrics.connections.inc(1)
        try:
            while True:
                req = await self._read_request(reader)
                if req is None:
                    break
                keep_alive = await self._dispatch(req, writer)
                if not keep_alive or self._draining:
                    break
        except (ConnectionError, asyncio.IncompleteReadError):
            pass                                   # client went away
        finally:
            self._metrics.connections.inc(-1)
            self._conn_tasks.discard(task)
            writer.close()
            try:
                await writer.wait_closed()
            except ConnectionError:                # pragma: no cover
                pass

    async def _read_request(self, reader):
        """One HTTP/1.x request -> (method, path, headers, body) or None.

        ``None`` means the peer closed between requests (normal keep-alive
        teardown); malformed framing raises ``ValueError`` and the
        dispatcher answers 400.
        """
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin-1").split()
        if len(parts) != 3 or not parts[2].startswith("HTTP/"):
            raise ValueError(f"malformed request line {line!r}")
        method, target, version = parts
        headers: dict[str, str] = {}
        while True:
            raw = await reader.readline()
            if raw in (b"\r\n", b"\n"):
                break
            if not raw:
                return None
            key, _, value = raw.decode("latin-1").partition(":")
            headers[key.strip().lower()] = value.strip()
        length = int(headers.get("content-length", "0") or "0")
        if length > self._cfg.max_body_bytes:
            raise _TooLarge(length)
        body = await reader.readexactly(length) if length else b""
        return method, target.split("?", 1)[0], version, headers, body

    async def _dispatch(self, req, writer) -> bool:
        t0 = time.perf_counter()
        method, path, version, headers, body = req
        keep_alive = (headers.get("connection", "").lower() != "close"
                      and not version.endswith("/1.0"))
        route = path if path in ("/v1/infer", "/healthz", "/metrics") else "*"
        try:
            if path == "/v1/infer":
                if method != "POST":
                    status, payload, ctype = 405, _err("method_not_allowed",
                                                       "POST only"), None
                else:
                    status, payload, ctype = await self._infer(headers, body)
            elif path == "/healthz":
                status, payload, ctype = self._healthz(method)
            elif path == "/metrics":
                status, payload, ctype = self._metrics_page(method)
            else:
                status, payload, ctype = 404, _err(
                    "not_found", f"no route {path}"), None
        except Exception as exc:                   # pragma: no cover
            status, payload, ctype = 500, _err("internal", repr(exc)), None
        await self._respond(writer, status, payload, ctype, keep_alive)
        self._metrics.requests.labels(route=route, status=str(status)).inc()
        self._metrics.request_seconds.observe(time.perf_counter() - t0)
        return keep_alive

    async def _respond(self, writer, status, payload, ctype, keep_alive):
        if ctype is None:
            body = json.dumps(payload).encode()
            ctype = "application/json"
        else:
            body = payload
        head = (f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}\r\n"
                f"content-type: {ctype}\r\n"
                f"content-length: {len(body)}\r\n"
                f"connection: {'keep-alive' if keep_alive else 'close'}"
                "\r\n\r\n")
        writer.write(head.encode("latin-1") + body)
        await writer.drain()

    # -- routes -------------------------------------------------------------

    async def _infer(self, headers, body):
        """POST /v1/infer: decode -> quota -> tier -> encode."""
        m = self._metrics
        if self._draining:
            m.rejected.labels(reason="draining").inc()
            return 503, _err("draining", "ingress is shutting down"), None
        t_dec = time.perf_counter()
        raw = (headers.get("content-type", "application/json")
               .split(";")[0].strip() == "application/octet-stream")
        try:
            codes = self._decode(body, raw)
        except ValueError as exc:
            return 400, _err("bad_request", str(exc)), None
        m.decode_seconds.observe(time.perf_counter() - t_dec)

        tenant = headers.get(self._cfg.tenant_header,
                             self._cfg.default_tenant) or \
            self._cfg.default_tenant
        quota = self._cfg.quota
        if quota is not None:
            bucket = self._buckets.get(tenant)
            if bucket is None:
                bucket = self._buckets.setdefault(
                    tenant, TokenBucket(quota.rate_rows_per_s, quota.burst))
            if not bucket.try_take(codes.shape[0]):
                m.rejected.labels(reason="quota").inc()
                return 429, _err(
                    "quota_exceeded",
                    f"tenant {tenant!r} exceeded "
                    f"{quota.rate_rows_per_s:g} rows/s "
                    f"(burst {quota.burst:g})"), None

        t_inf = time.perf_counter()
        try:
            out = await self.tier.infer(codes)
        except TierOverloaded as exc:
            m.rejected.labels(reason="overloaded").inc()
            return 503, _err("overloaded", str(exc)), None
        except RequestTimeout as exc:
            m.rejected.labels(reason="timeout").inc()
            return 408, _err("timeout", str(exc)), None
        except TierClosed:
            m.rejected.labels(reason="draining").inc()
            return 503, _err("draining", "serving tier is stopping"), None
        m.infer_seconds.observe(time.perf_counter() - t_inf)

        if raw:
            return 200, np.asarray(out, np.int8).tobytes(), \
                "application/octet-stream"
        return 200, {"outputs": np.asarray(out).tolist()}, None

    def _decode(self, body: bytes, raw: bool) -> np.ndarray:
        n_in = self._net.n_in
        if raw:
            if len(body) % n_in:
                raise ValueError(
                    f"octet-stream body of {len(body)} bytes is not a "
                    f"multiple of n_in={n_in}")
            return np.frombuffer(body, np.int8).reshape(-1, n_in) \
                .astype(np.int32)
        try:
            obj = json.loads(body or b"{}")
        except json.JSONDecodeError as exc:
            raise ValueError(f"body is not valid JSON: {exc}") from exc
        if not isinstance(obj, dict) or "codes" not in obj:
            raise ValueError('JSON body must be {"codes": [[...], ...]}')
        codes = np.asarray(obj["codes"], dtype=np.int32)
        if codes.ndim == 1:
            codes = codes[None, :]
        if codes.ndim != 2 or codes.shape[1] != n_in:
            raise ValueError(
                f"expected (rows, {n_in}) codes, got shape "
                f"{tuple(codes.shape)}")
        return codes

    def _healthz(self, method):
        if method != "GET":
            return 405, _err("method_not_allowed", "GET only"), None
        st = self.tier.stats()
        return 200, {
            "status": "draining" if self._draining else "ok",
            "queued_rows": st["queued_rows"],
            "requests": st["requests"],
            "batches": st["batches"],
            "retraces_after_warmup": st["retraces_after_warmup"],
            "compiler_runs_after_warmup": st["compiler_runs_after_warmup"],
        }, None

    def _metrics_page(self, method):
        if method != "GET":
            return 405, _err("method_not_allowed", "GET only"), None
        text = obs.registry().render_prometheus()
        return 200, text.encode(), "text/plain; version=0.0.4"


class _TooLarge(ValueError):
    pass


def _err(error: str, detail: str) -> dict:
    return {"error": error, "detail": detail}


# ---------------------------------------------------------------------------
# Async HTTP client (the open-loop load generator's and tests' counterpart)
# ---------------------------------------------------------------------------

def _encode_infer_request(host: str, port: int, codes: np.ndarray, *,
                          tenant: str | None, raw: bool,
                          close: bool) -> bytes:
    """Wire bytes of one ``POST /v1/infer`` (shared by the one-shot client
    and the keep-alive pool; ``close`` controls ``connection: close``)."""
    if raw:
        body = codes.astype(np.int8).tobytes()
        ctype = "application/octet-stream"
    else:
        body = json.dumps({"codes": codes.tolist()}).encode()
        ctype = "application/json"
    headers = ["POST /v1/infer HTTP/1.1", f"host: {host}:{port}",
               f"content-type: {ctype}", f"content-length: {len(body)}"]
    if close:
        headers.append("connection: close")
    if tenant is not None:
        headers.append(f"x-tenant: {tenant}")
    return ("\r\n".join(headers) + "\r\n\r\n").encode() + body


def _decode_infer_response(status: int, headers: dict, body: bytes,
                           rows: int) -> np.ndarray:
    """The inverse of the server's status mapping: 429 ->
    :class:`QuotaExceeded`, 503 -> :class:`TierOverloaded` (or
    :class:`TierClosed` when the body says ``draining``), 408 ->
    :class:`RequestTimeout`, anything else non-200 -> :class:`TierError`.
    """
    if status == 200:
        if headers.get("content-type", "").startswith(
                "application/octet-stream"):
            return np.frombuffer(body, np.int8) \
                .reshape(rows, -1).astype(np.int32)
        return np.asarray(json.loads(body)["outputs"], np.int32)
    detail = _error_detail(body)
    if status == 429:
        raise QuotaExceeded(detail)
    if status == 408:
        raise RequestTimeout(detail)
    if status == 503:
        if "draining" in detail:
            raise TierClosed(detail)
        raise TierOverloaded(detail)
    raise TierError(f"HTTP {status}: {detail}")


async def _close_connection(conn) -> None:
    _, writer = conn
    writer.close()
    try:
        await writer.wait_closed()
    except ConnectionError:                        # pragma: no cover
        pass


async def http_infer(host: str, port: int, codes: np.ndarray, *,
                     tenant: str | None = None, raw: bool = True,
                     timeout_s: float = 60.0) -> np.ndarray:
    """One ``POST /v1/infer`` round trip; raises the tier's typed errors.

    Opens (and closes) a fresh connection per call — fine for tests and
    one-shots; a load generator should use :class:`HttpClientPool`, which
    reuses keep-alive connections and so measures server behavior rather
    than connection-setup cost.  ``raw`` uses the int8 octet-stream
    encoding (the cheap path); ``raw=False`` posts JSON.
    """
    codes = np.asarray(codes, dtype=np.int32)
    payload = _encode_infer_request(host, port, codes, tenant=tenant,
                                    raw=raw, close=True)
    conn = await asyncio.open_connection(host, port)
    reader, writer = conn
    try:
        writer.write(payload)
        await writer.drain()
        status, resp_headers, resp_body = await asyncio.wait_for(
            _read_response(reader), timeout_s)
    finally:
        await _close_connection(conn)
    return _decode_infer_response(status, resp_headers, resp_body,
                                  codes.shape[0])


class HttpClientPool:
    """Keep-alive ``POST /v1/infer`` client over a bounded connection pool.

    The load generator's counterpart to the server's persistent
    connections: up to ``size`` concurrent requests each hold one pooled
    connection (opened lazily, reused across requests), so an open-loop
    sweep exercises the *server's* admission path instead of paying — and
    measuring — a TCP handshake per request (which flattered rejection
    latency under overload; see docs/ingress.md).

    A request that finds its reused connection dead (the server dropped a
    stale keep-alive) retries once on a fresh connection; server-level
    errors map to the same typed exceptions as :func:`http_infer`.
    ``close()`` drains the pool — call it only after in-flight requests
    finished (the loadgen awaits its workers first).
    """

    def __init__(self, host: str, port: int, *, size: int = 8,
                 tenant: str | None = None, raw: bool = True,
                 timeout_s: float = 60.0):
        self._host, self._port = host, int(port)
        self._tenant, self._raw = tenant, raw
        self._timeout_s = timeout_s
        # each slot is either a live (reader, writer) pair or None (open
        # lazily on first use); the bounded queue is the concurrency gate
        self._slots: asyncio.Queue = asyncio.Queue()
        for _ in range(max(1, int(size))):
            self._slots.put_nowait(None)
        self._closed = False

    async def infer(self, codes: np.ndarray, *,
                    tenant: str | None = None) -> np.ndarray:
        """One inference round trip on a pooled keep-alive connection."""
        if self._closed:
            raise RuntimeError("HttpClientPool is closed")
        codes = np.asarray(codes, dtype=np.int32)
        tenant = self._tenant if tenant is None else tenant
        payload = _encode_infer_request(self._host, self._port, codes,
                                        tenant=tenant, raw=self._raw,
                                        close=False)
        conn = await self._slots.get()
        reused = conn is not None
        try:
            while True:
                if conn is None:
                    conn = await asyncio.open_connection(self._host,
                                                         self._port)
                reader, writer = conn
                try:
                    writer.write(payload)
                    await writer.drain()
                    status, headers, body = await asyncio.wait_for(
                        _read_response(reader), self._timeout_s)
                except asyncio.TimeoutError:
                    # connection state unknown mid-response: never reuse
                    await _close_connection(conn)
                    conn = None
                    raise
                except (ConnectionError, asyncio.IncompleteReadError):
                    await _close_connection(conn)
                    conn = None
                    if reused:
                        # stale keep-alive connection — one fresh retry
                        reused = False
                        continue
                    raise
                if headers.get("connection", "").lower() == "close":
                    await _close_connection(conn)
                    conn = None
                return _decode_infer_response(status, headers, body,
                                              codes.shape[0])
        finally:
            self._slots.put_nowait(conn)

    async def close(self) -> None:
        """Close every idle pooled connection and refuse further infers."""
        self._closed = True
        while not self._slots.empty():
            conn = self._slots.get_nowait()
            if conn is not None:
                await _close_connection(conn)


async def _read_response(reader):
    line = (await reader.readline()).decode("latin-1")
    parts = line.split()
    if len(parts) < 2:
        raise TierError(f"malformed response status line {line!r}")
    status = int(parts[1])
    headers: dict[str, str] = {}
    while True:
        raw = await reader.readline()
        if raw in (b"\r\n", b"\n", b""):
            break
        key, _, value = raw.decode("latin-1").partition(":")
        headers[key.strip().lower()] = value.strip()
    length = int(headers.get("content-length", "0") or "0")
    body = await reader.readexactly(length) if length else b""
    return status, headers, body


def _error_detail(body: bytes) -> str:
    try:
        obj = json.loads(body)
        return f"{obj.get('error', '?')}: {obj.get('detail', '')}"
    except (json.JSONDecodeError, AttributeError):
        return body.decode("latin-1", "replace")[:200]


# ---------------------------------------------------------------------------
# Background runner: the ingress on its own event-loop thread
# ---------------------------------------------------------------------------

class BackgroundIngress:
    """Run an :class:`HttpIngress` on a dedicated event-loop thread.

    The shape synchronous callers need — the bench's ``ingress`` section,
    the ``--http`` CLI, tests and the docs examples all drive a live
    localhost server while staying ordinary blocking code::

        with BackgroundIngress(net) as ing:
            rep = serve.run_open_loop(url=ing.url, offered_rps=200,
                                      n_requests=50, verify_net=net)

    ``stats()`` reads the tier's counters (thread-safe) while the server
    runs; leaving the context performs the graceful drain.
    """

    def __init__(self, net, tier_config: TierConfig | None = None,
                 config: IngressConfig | None = None):
        self._net = net
        self._tier_cfg = tier_config
        self._cfg = config
        self._ready = threading.Event()
        self._thread: threading.Thread | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._stop_evt: asyncio.Event | None = None
        self._startup_exc: BaseException | None = None
        self.ingress: HttpIngress | None = None

    def start(self) -> "BackgroundIngress":
        if self._thread is not None:
            raise TierError("ingress already started")
        self._thread = threading.Thread(
            target=lambda: asyncio.run(self._main()),
            name="http-ingress", daemon=True)
        self._thread.start()
        self._ready.wait()
        if self._startup_exc is not None:
            self._thread.join()
            raise self._startup_exc
        return self

    async def _main(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._stop_evt = asyncio.Event()
        try:
            self.ingress = HttpIngress(self._net, self._tier_cfg, self._cfg)
            await self.ingress.start()
        except BaseException as exc:
            self._startup_exc = exc
            self._ready.set()
            return
        self._ready.set()
        await self._stop_evt.wait()
        await self.ingress.stop()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._loop.call_soon_threadsafe(self._stop_evt.set)
        self._thread.join()
        self._thread = None

    @property
    def port(self) -> int:
        return self.ingress.port

    @property
    def url(self) -> str:
        return self.ingress.url

    def stats(self) -> dict:
        """The owned tier's counter snapshot (safe while serving)."""
        return self.ingress.tier.stats()

    def __enter__(self) -> "BackgroundIngress":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
