"""Closed- and open-loop load generators for the serving tier.

Two arrival models, one :class:`LoadReport`:

* **closed loop** (:func:`run_closed_loop`) — a fixed pool of
  ``n_clients`` concurrent clients each issues its next request the
  moment the previous one resolves.  The offered load self-regulates to
  whatever the tier can absorb, so this measures *steady-state
  equilibrium* (p50/p99 latency, QPS) — the classic bench setup, and
  what the gated ``serving_tier`` bench section runs.
* **open loop** (:func:`run_open_loop`) — requests fire at seeded
  Poisson arrival times regardless of whether earlier ones finished,
  the way independent network clients actually behave.  Offered load is
  an *input* (``offered_rps``), so driving it past capacity is
  meaningful: the report separates goodput from rejections
  (quota / backpressure) and timeouts instead of letting the arrival
  process silently throttle.  This is what the ``ingress`` bench
  section and overload tests run — against the in-process tier or a
  live HTTP ingress (``url=...``).

Consumers: ``benchmarks/kernel_bench.py`` (``serving_tier`` +
``ingress`` sections), ``python -m repro.launch.serve --lut`` (the
operator CLI; ``--open-loop RPS`` switches models), and the overload
walkthrough in docs/ingress.md.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time

import numpy as np

from repro.serve.tier import (RequestTimeout, ServingTier, TierClosed,
                              TierConfig, TierError, TierOverloaded)


@dataclasses.dataclass(frozen=True)
class LoadReport:
    """Serving measurements from one load-generator run.

    Latencies are wall-clock per *successful* request (submit ->
    result), in milliseconds; ``qps`` counts completed requests per
    second over the whole run; ``rows_per_sec`` is the row-throughput
    view of the same number.  ``stats`` is the tier's own counter
    snapshot (:meth:`repro.serve.ServingTier.stats`) taken at the end
    of the run — its ``retraces_after_warmup`` /
    ``compiler_runs_after_warmup`` fields are the compile-once serving
    contract (``{}`` when the run drove a remote ingress URL, whose
    tier lives in another process).

    Closed-loop runs complete every request, so the open-loop fields
    keep their defaults: ``offered_rps`` is the configured arrival
    rate (``nan`` = closed loop), ``goodput_rps`` counts only
    successful requests, ``outcomes`` histograms every request's fate
    (``ok`` / ``rejected_quota`` / ``rejected_overload`` /
    ``timeout`` / ``closed``), and ``rejection_rate`` is the non-``ok``
    fraction.
    """

    n_clients: int
    n_requests: int
    rows: int
    wall_s: float
    p50_ms: float
    p90_ms: float
    p99_ms: float
    mean_ms: float
    qps: float
    rows_per_sec: float
    stats: dict
    breakdown: dict = dataclasses.field(default_factory=dict)
    offered_rps: float = float("nan")
    goodput_rps: float = float("nan")
    rejected: int = 0
    timed_out: int = 0
    rejection_rate: float = 0.0
    outcomes: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["stats"] = dict(self.stats)
        d["breakdown"] = {k: dict(v) for k, v in self.breakdown.items()}
        d["outcomes"] = dict(self.outcomes)
        return d


def _percentile(lat_ms: np.ndarray, q: float) -> float:
    """``np.percentile`` guarded for tiny runs: nan on an empty sample
    (np.percentile raises), the plain interpolated estimate otherwise —
    callers treat p99 of a 1-2 request run as indicative only."""
    if lat_ms.size == 0:
        return float("nan")
    return float(np.percentile(lat_ms, q))


def make_requests(n_in: int, n_requests: int, *, rows_min: int = 1,
                  rows_max: int = 8, bw: int = 2, seed: int = 0
                  ) -> list[np.ndarray]:
    """Ragged synthetic request batches: ``(rows, n_in)`` int32 codes.

    Row counts are uniform in ``[rows_min, rows_max]`` and code values in
    ``[0, 2**bw)`` — the shape of a trigger-style event stream hitting the
    tier with small, uneven batches.
    """
    rng = np.random.default_rng(seed)
    sizes = rng.integers(rows_min, rows_max + 1, n_requests)
    return [rng.integers(0, 2 ** bw, (int(k), n_in), dtype=np.int32)
            for k in sizes]


def poisson_arrivals(offered_rps: float, n_requests: int, *, seed: int = 0
                     ) -> np.ndarray:
    """Seeded Poisson arrival times (seconds from t=0), sorted ascending.

    Inter-arrival gaps are i.i.d. exponential with mean
    ``1 / offered_rps`` — the memoryless arrival process of independent
    network clients.  Same seed -> identical schedule, so open-loop
    runs are reproducible.
    """
    if offered_rps <= 0:
        raise ValueError(f"offered_rps must be positive, got {offered_rps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / offered_rps, n_requests)
    return np.cumsum(gaps)


async def _closed_loop(tier: ServingTier, requests: list[np.ndarray],
                       n_clients: int):
    """Serve ``requests`` through ``tier`` from a closed client pool."""
    loop = asyncio.get_running_loop()
    latencies: list[float] = []
    outs: list = [None] * len(requests)

    async def client(idxs):
        for i in idxs:
            t0 = loop.time()
            outs[i] = await tier.infer(requests[i])
            latencies.append(loop.time() - t0)

    await asyncio.gather(*[client(range(c, len(requests), n_clients))
                           for c in range(n_clients)])
    return outs, latencies


def run_closed_loop(net, *, config: TierConfig | None = None,
                    n_clients: int = 8, n_per_client: int = 16,
                    rows_min: int = 1, rows_max: int = 8, bw: int = 2,
                    seed: int = 0, check_outputs: bool = True
                    ) -> LoadReport:
    """Drive ``net`` through a :class:`ServingTier` under closed-loop load.

    Builds ``n_clients * n_per_client`` ragged synthetic requests
    (:func:`make_requests`), serves them from ``n_clients`` concurrent
    clients, and returns the latency/throughput :class:`LoadReport`.
    With ``check_outputs`` every response is verified bit-exact against a
    direct ``net(codes)`` call *after* the timed run (correctness must not
    perturb the measurement).

    >>> import numpy as np
    >>> from repro import engine, serve
    >>> rng = np.random.default_rng(0)
    >>> idx = np.stack([np.sort(rng.choice(6, 2, replace=False))
    ...                 for _ in range(4)]).astype(np.int32)
    >>> tbl = rng.integers(0, 4, (4, 16), dtype=np.int32)
    >>> net = engine.compile_network([(idx, tbl, 2)], in_features=6,
    ...                              block_b=4)
    >>> rep = serve.run_closed_loop(net, n_clients=2, n_per_client=3,
    ...                             rows_max=3, seed=1)
    >>> rep.n_requests
    6
    >>> rep.stats["retraces_after_warmup"]          # compile-once contract
    0
    >>> rep.rejected, rep.timed_out                 # closed loop never sheds
    (0, 0)
    """
    n_requests = n_clients * n_per_client
    requests = make_requests(net.n_in, n_requests, rows_min=rows_min,
                             rows_max=rows_max, bw=bw, seed=seed)

    async def main():
        async with ServingTier(net, config) as tier:
            t0 = time.perf_counter()
            outs, lats = await _closed_loop(tier, requests, n_clients)
            wall = time.perf_counter() - t0
            return outs, lats, wall, tier.stats(), tier.latency_breakdown()

    outs, lats, wall, stats, breakdown = asyncio.run(main())
    if check_outputs:
        for req, out in zip(requests, outs):
            np.testing.assert_array_equal(out, np.asarray(net(req)))
    lat_ms = np.sort(np.asarray(lats)) * 1e3
    rows = int(sum(r.shape[0] for r in requests))
    n_done = len(lats)
    return LoadReport(
        n_clients=n_clients,
        n_requests=n_done,
        rows=rows,
        wall_s=wall,
        p50_ms=_percentile(lat_ms, 50),
        p90_ms=_percentile(lat_ms, 90),
        p99_ms=_percentile(lat_ms, 99),
        mean_ms=float(lat_ms.mean()) if n_done else float("nan"),
        qps=n_done / wall,
        rows_per_sec=rows / wall,
        stats=stats,
        breakdown=breakdown,
    )


def _classify(exc: BaseException) -> str:
    # local import: ingress imports tier, loadgen imports ingress's
    # QuotaExceeded only here to keep module import costs flat
    from repro.serve.ingress import QuotaExceeded
    if isinstance(exc, QuotaExceeded):
        return "rejected_quota"
    if isinstance(exc, TierOverloaded):
        return "rejected_overload"
    if isinstance(exc, RequestTimeout):
        return "timeout"
    if isinstance(exc, TierClosed):
        return "closed"
    raise exc


async def _open_loop(submit, requests: list[np.ndarray],
                     arrivals: np.ndarray):
    """Fire ``requests`` at their arrival times; never wait for replies."""
    loop = asyncio.get_running_loop()
    latencies = np.full(len(requests), np.nan)
    outcomes: list[str | None] = [None] * len(requests)
    outs: list = [None] * len(requests)

    async def one(i: int, at: float, t_start: float):
        delay = t_start + at - loop.time()
        if delay > 0:
            await asyncio.sleep(delay)
        t0 = loop.time()
        try:
            outs[i] = await submit(requests[i])
        except TierError as exc:
            outcomes[i] = _classify(exc)
            return
        latencies[i] = loop.time() - t0
        outcomes[i] = "ok"

    t_start = loop.time()
    await asyncio.gather(*[one(i, float(at), t_start)
                           for i, at in enumerate(arrivals)])
    return outs, latencies, outcomes


def run_open_loop(net=None, *, url: str | None = None,
                  config: TierConfig | None = None,
                  offered_rps: float = 200.0, n_requests: int = 64,
                  rows_min: int = 1, rows_max: int = 8, bw: int = 2,
                  seed: int = 0, tenant: str | None = None,
                  check_outputs: bool = True, verify_net=None,
                  n_in: int | None = None) -> LoadReport:
    """Drive open-loop Poisson-arrival load into a tier or HTTP ingress.

    Requests fire at :func:`poisson_arrivals` times whether or not
    earlier ones resolved, so ``offered_rps`` really is the offered
    load — push it past capacity and the report shows *how* the server
    sheds (``outcomes`` / ``rejection_rate``) and what it still
    completes (``goodput_rps``), instead of the arrival process
    backing off as a closed loop would.

    Exactly one target: ``net`` serves through an in-process
    :class:`ServingTier` (``config`` sets its knobs), or ``url``
    (``http://host:port``) posts raw-int8 bodies to a live HTTP
    ingress — rejections come back as the same typed exceptions either
    way, so the outcome accounting is identical.  ``check_outputs``
    verifies successful responses bit-exact after the timed run against
    ``verify_net`` (defaults to ``net``; pass it explicitly for
    ``url`` runs, or they go unverified).

    >>> import numpy as np
    >>> from repro import engine, serve
    >>> rng = np.random.default_rng(0)
    >>> idx = np.stack([np.sort(rng.choice(6, 2, replace=False))
    ...                 for _ in range(4)]).astype(np.int32)
    >>> tbl = rng.integers(0, 4, (4, 16), dtype=np.int32)
    >>> net = engine.compile_network([(idx, tbl, 2)], in_features=6,
    ...                              block_b=4)
    >>> rep = serve.run_open_loop(net, offered_rps=500.0, n_requests=8,
    ...                           rows_max=3, seed=2)
    >>> rep.outcomes                                # capacity >> offered
    {'ok': 8}
    >>> rep.rejection_rate
    0.0
    >>> serve.poisson_arrivals(100.0, 4, seed=2).shape   # seeded schedule
    (4,)
    """
    if (net is None) == (url is None):
        raise ValueError("pass exactly one of net= or url=")
    if n_in is None:
        if net is not None:
            n_in = net.n_in
        elif verify_net is not None:
            n_in = verify_net.n_in
        else:
            raise ValueError("url= mode needs verify_net= or n_in= to "
                             "size the synthetic requests")
    requests = make_requests(n_in, n_requests, rows_min=rows_min,
                             rows_max=rows_max, bw=bw, seed=seed)
    arrivals = poisson_arrivals(offered_rps, n_requests, seed=seed)

    if net is not None:
        async def main():
            async with ServingTier(net, config) as tier:
                t0 = time.perf_counter()
                res = await _open_loop(tier.infer, requests, arrivals)
                wall = time.perf_counter() - t0
                return (*res, wall, tier.stats(), tier.latency_breakdown())
    else:
        from repro.serve.ingress import HttpClientPool
        host, _, port = url.removeprefix("http://").partition(":")

        async def main():
            # keep-alive pool: requests reuse warm connections, so the
            # timed run measures the server's admission path rather than
            # a TCP handshake per request (which flattered rejection
            # latency under overload)
            pool = HttpClientPool(host, int(port), size=16, tenant=tenant)
            try:
                t0 = time.perf_counter()
                res = await _open_loop(pool.infer, requests, arrivals)
                wall = time.perf_counter() - t0
            finally:
                await pool.close()
            return (*res, wall, {}, {})

    outs, lats, outcomes, wall, stats, breakdown = asyncio.run(main())
    ref = verify_net if verify_net is not None else net
    if check_outputs and ref is not None:
        for req, out, oc in zip(requests, outs, outcomes):
            if oc == "ok":
                np.testing.assert_array_equal(out, np.asarray(ref(req)))
    counts: dict[str, int] = {}
    for oc in outcomes:
        counts[oc] = counts.get(oc, 0) + 1
    n_ok = counts.get("ok", 0)
    ok_lat_ms = np.sort(lats[~np.isnan(lats)]) * 1e3
    ok_rows = int(sum(r.shape[0] for r, oc in zip(requests, outcomes)
                      if oc == "ok"))
    return LoadReport(
        n_clients=0,
        n_requests=n_requests,
        rows=ok_rows,
        wall_s=wall,
        p50_ms=_percentile(ok_lat_ms, 50),
        p90_ms=_percentile(ok_lat_ms, 90),
        p99_ms=_percentile(ok_lat_ms, 99),
        mean_ms=float(ok_lat_ms.mean()) if n_ok else float("nan"),
        qps=n_ok / wall,
        rows_per_sec=ok_rows / wall,
        stats=stats,
        breakdown=breakdown,
        offered_rps=float(offered_rps),
        goodput_rps=n_ok / wall,
        rejected=counts.get("rejected_quota", 0)
        + counts.get("rejected_overload", 0) + counts.get("closed", 0),
        timed_out=counts.get("timeout", 0),
        rejection_rate=1.0 - n_ok / n_requests if n_requests else 0.0,
        outcomes=counts,
    )
