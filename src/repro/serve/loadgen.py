"""Closed-loop load generator for the serving tier (bench + CLI).

A fixed pool of ``n_clients`` concurrent clients each issues
``n_per_client`` requests back to back (a new request the moment the
previous one resolves), so the tier sees a steady closed-loop offered load
instead of one unbounded burst — the standard way to measure a
micro-batching server's steady-state p50/p99 latency and QPS without the
arrival process dominating the numbers.

Both consumers of this module report the same :class:`LoadReport`:

* ``benchmarks/kernel_bench.py`` — the gated ``serving_tier`` bench
  section (p50/p99/QPS against the committed baseline);
* ``python -m repro.launch.serve --lut`` — the operator-facing CLI.

Example::

    from repro import engine, serve
    net = engine.compile_network(layers, optimize_level=3, in_features=12)
    rep = serve.run_closed_loop(net, n_clients=4, n_per_client=8)
    print(rep.p99_ms, rep.qps, rep.stats["batch_occupancy"])
"""

from __future__ import annotations

import asyncio
import dataclasses
import time

import numpy as np

from repro.serve.tier import ServingTier, TierConfig


@dataclasses.dataclass(frozen=True)
class LoadReport:
    """Steady-state serving measurements from one closed-loop run.

    Latencies are wall-clock per request (submit -> result), in
    milliseconds; ``qps`` is completed requests per second over the whole
    run; ``rows_per_sec`` is the row-throughput view of the same number.
    ``stats`` is the tier's own counter snapshot
    (:meth:`repro.serve.ServingTier.stats`) taken at the end of the run —
    its ``retraces_after_warmup`` / ``compiler_runs_after_warmup`` fields
    are the compile-once serving contract.
    """

    n_clients: int
    n_requests: int
    rows: int
    wall_s: float
    p50_ms: float
    p90_ms: float
    p99_ms: float
    mean_ms: float
    qps: float
    rows_per_sec: float
    stats: dict
    breakdown: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["stats"] = dict(self.stats)
        d["breakdown"] = {k: dict(v) for k, v in self.breakdown.items()}
        return d


def _percentile(lat_ms: np.ndarray, q: float) -> float:
    """``np.percentile`` guarded for tiny runs: nan on an empty sample
    (np.percentile raises), the plain interpolated estimate otherwise —
    callers treat p99 of a 1-2 request run as indicative only."""
    if lat_ms.size == 0:
        return float("nan")
    return float(np.percentile(lat_ms, q))


def make_requests(n_in: int, n_requests: int, *, rows_min: int = 1,
                  rows_max: int = 8, bw: int = 2, seed: int = 0
                  ) -> list[np.ndarray]:
    """Ragged synthetic request batches: ``(rows, n_in)`` int32 codes.

    Row counts are uniform in ``[rows_min, rows_max]`` and code values in
    ``[0, 2**bw)`` — the shape of a trigger-style event stream hitting the
    tier with small, uneven batches.
    """
    rng = np.random.default_rng(seed)
    sizes = rng.integers(rows_min, rows_max + 1, n_requests)
    return [rng.integers(0, 2 ** bw, (int(k), n_in), dtype=np.int32)
            for k in sizes]


async def _closed_loop(tier: ServingTier, requests: list[np.ndarray],
                       n_clients: int):
    """Serve ``requests`` through ``tier`` from a closed client pool."""
    loop = asyncio.get_running_loop()
    latencies: list[float] = []
    outs: list = [None] * len(requests)

    async def client(idxs):
        for i in idxs:
            t0 = loop.time()
            outs[i] = await tier.infer(requests[i])
            latencies.append(loop.time() - t0)

    await asyncio.gather(*[client(range(c, len(requests), n_clients))
                           for c in range(n_clients)])
    return outs, latencies


def run_closed_loop(net, *, config: TierConfig | None = None,
                    n_clients: int = 8, n_per_client: int = 16,
                    rows_min: int = 1, rows_max: int = 8, bw: int = 2,
                    seed: int = 0, check_outputs: bool = True
                    ) -> LoadReport:
    """Drive ``net`` through a :class:`ServingTier` under closed-loop load.

    Builds ``n_clients * n_per_client`` ragged synthetic requests
    (:func:`make_requests`), serves them from ``n_clients`` concurrent
    clients, and returns the latency/throughput :class:`LoadReport`.
    With ``check_outputs`` every response is verified bit-exact against a
    direct ``net(codes)`` call *after* the timed run (correctness must not
    perturb the measurement).
    """
    n_requests = n_clients * n_per_client
    requests = make_requests(net.n_in, n_requests, rows_min=rows_min,
                             rows_max=rows_max, bw=bw, seed=seed)

    async def main():
        async with ServingTier(net, config) as tier:
            t0 = time.perf_counter()
            outs, lats = await _closed_loop(tier, requests, n_clients)
            wall = time.perf_counter() - t0
            return outs, lats, wall, tier.stats(), tier.latency_breakdown()

    outs, lats, wall, stats, breakdown = asyncio.run(main())
    if check_outputs:
        for req, out in zip(requests, outs):
            np.testing.assert_array_equal(out, np.asarray(net(req)))
    lat_ms = np.sort(np.asarray(lats)) * 1e3
    rows = int(sum(r.shape[0] for r in requests))
    n_done = len(lats)
    return LoadReport(
        n_clients=n_clients,
        n_requests=n_done,
        rows=rows,
        wall_s=wall,
        p50_ms=_percentile(lat_ms, 50),
        p90_ms=_percentile(lat_ms, 90),
        p99_ms=_percentile(lat_ms, 99),
        mean_ms=float(lat_ms.mean()) if n_done else float("nan"),
        qps=n_done / wall,
        rows_per_sec=rows / wall,
        stats=stats,
        breakdown=breakdown,
    )
