"""Device-sharded async micro-batching serving tier over ``CompiledLUTNet``.

The paper's deployment regime is extreme-throughput inference: per-request
work is a few thousand table lookups, so the host-side request loop — not
the kernel — is where a serving stack squanders the hardware.  This module
is the request-side half of the deployment story that
``repro.engine.compile_network`` started:

* **micro-batching** — incoming requests (each a ragged ``(rows, n_in)``
  code batch) are coalesced into ``block_b``-bucketed batches and flushed
  either when ``max_batch_rows`` rows have accumulated or when the oldest
  request has waited ``flush_deadline_s`` (size-or-deadline flush);
* **device sharding** — with more than one device the padded batch is laid
  out with ``jax.sharding`` on the batch axis (``NamedSharding`` over a
  1-D ``"data"`` mesh) and the engine's forward runs under ``shard_map``:
  the tiny table slabs are replicated, the batch is split, every device
  executes the same fused kernel on its shard (embarrassingly parallel);
  with one device the tier degrades gracefully to a plain engine call;
* **backpressure** — the queue is bounded at ``max_queue_rows`` queued
  rows; a request that would overflow it is rejected immediately with
  :class:`TierOverloaded` instead of growing an unbounded backlog;
* **per-request timeouts** — a request that has not been *launched* into a
  batch within ``request_timeout_s`` is dropped with
  :class:`RequestTimeout` (a request whose batch is already computing
  always gets its result);
* **compile-once steady state** — ``start()`` warms every batch bucket, so
  a steady-state serving loop performs **zero jit re-traces and zero
  compiler runs** (``stats()["retraces_after_warmup"]`` /
  ``["compiler_runs_after_warmup"]`` — asserted by tests/test_serve.py and
  gated by the bench's ``serving_tier`` section).

Example (single process, default device set)::

    import asyncio
    import numpy as np
    from repro import engine, serve

    net = engine.compile_network(layers, optimize_level=3, in_features=12)

    async def main():
        async with serve.ServingTier(net) as tier:
            out = await tier.infer(np.zeros((3, net.n_in), np.int32))
            print(out.shape, tier.stats()["batches"])

    asyncio.run(main())

Outputs are bit-exact with calling the ``CompiledLUTNet`` directly on the
same rows — coalescing, padding and sharding are pure layout.
"""

from __future__ import annotations

import asyncio
import collections
import dataclasses
import itertools
import math
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import engine as rengine
from repro import obs

# each tier instance gets its own label value so per-tier stats stay
# separable in the shared process registry (stats() reads them back)
_TIER_IDS = itertools.count()


class _TierMetrics:
    """This tier's labeled children in the process metrics registry.

    One instance per ServingTier: counters mirror the legacy ``stats()``
    fields, the stage histograms are fed by the per-request spans
    (queue wait / batch assembly / device time — see
    docs/observability.md), and the two ``*_after_warmup`` gauges carry
    the compile-once contract into the snapshot.
    """

    def __init__(self, tier_id: str) -> None:
        reg = obs.registry()
        t = {"tier": tier_id}

        def ctr(name, help_):
            return reg.counter(name, help_, labels=("tier",)).labels(**t)

        def hist(name, help_):
            return reg.histogram(name, help_, labels=("tier",)).labels(**t)

        def gauge(name, help_):
            return reg.gauge(name, help_, labels=("tier",)).labels(**t)

        self.requests = ctr("serve_requests_total",
                            "requests accepted by the serving tier")
        self.rows = ctr("serve_rows_total", "request rows accepted")
        self.batches = ctr("serve_batches_total", "coalesced batches run")
        self.padded_rows = ctr("serve_padded_rows_total",
                               "kernel rows launched incl. bucket padding")
        self.rejected = ctr("serve_rejected_total",
                            "requests rejected by backpressure")
        self.timed_out = ctr("serve_timed_out_total",
                             "requests expired before launch")
        self.expired_rows = ctr("serve_expired_rows_total",
                                "rows dropped by request timeouts")
        self.flush = reg.counter(
            "serve_flush_total", "batch flushes by cause",
            labels=("tier", "cause"))
        self.flush_by_cause = {
            cause: self.flush.labels(tier=tier_id, cause=cause)
            for cause in ("size", "deadline", "drain")}
        self.queue_wait = hist(
            "serve_queue_wait_seconds",
            "enqueue -> flush decision (span leg: queue wait)")
        self.assembly = hist(
            "serve_assembly_seconds",
            "flush -> device dispatch (batch concat + executor hand-off)")
        self.device = hist(
            "serve_device_seconds",
            "device dispatch -> completion (padded batch forward)")
        self.latency = hist(
            "serve_request_latency_seconds",
            "enqueue -> completion (whole request span)")
        self.queued_rows = gauge("serve_queued_rows",
                                 "rows currently queued")
        self.retraces = gauge(
            "serve_retraces_after_warmup",
            "jit traces added after warmup (compile-once: must stay 0)")
        self.compiler_runs = gauge(
            "serve_compiler_runs_after_warmup",
            "compiler runs after warmup (compile-once: must stay 0)")


class TierError(Exception):
    """Base class for serving-tier request failures."""


class TierOverloaded(TierError):
    """The bounded request queue is full — the request was rejected."""


class TierClosed(TierError):
    """The tier is stopped (or stopping) and accepts no new requests."""


class RequestTimeout(TierError):
    """The request expired before its batch was launched."""


@dataclasses.dataclass(frozen=True)
class TierConfig:
    """Knobs of the micro-batching serving tier.

    * ``max_batch_rows`` — flush a batch once this many rows are queued
      (None: the artifact's ``block_b``).  A single request larger than
      this forms its own batch.
    * ``flush_deadline_s`` — flush a non-empty partial batch once its
      oldest request has waited this long (the latency bound under light
      load).
    * ``max_queue_rows`` — bounded-queue backpressure: a request that
      would push the queued-row count past this is rejected with
      :class:`TierOverloaded`.
    * ``request_timeout_s`` — per-request launch deadline; ``None``
      disables timeouts.
    * ``devices`` — devices for data-parallel batch sharding (None: all
      of ``jax.devices()``).  One device means no sharding machinery at
      all.
    * ``warmup`` — trace every batch bucket in ``start()`` so steady
      state is re-trace free.
    """

    max_batch_rows: int | None = None
    flush_deadline_s: float = 0.005
    max_queue_rows: int = 4096
    request_timeout_s: float | None = None
    devices: tuple | None = None
    warmup: bool = True


@dataclasses.dataclass
class _Request:
    codes: np.ndarray            # (rows, n_in) int32
    future: asyncio.Future       # resolves to (rows, n_out) np.ndarray
    enqueue_t: float
    deadline_t: float | None     # absolute launch deadline (None: never)
    span: obs.Span               # enqueue -> flush -> dispatch -> done


class ServingTier:
    """Async micro-batching front-end over one :class:`CompiledLUTNet`.

    Drive it from an event loop: ``await tier.start()`` (or ``async with
    ServingTier(net) as tier``), then any number of concurrent
    ``await tier.infer(codes)`` calls, then ``await tier.stop()``.
    ``infer`` accepts ``(rows, n_in)`` or a single ``(n_in,)`` row and
    returns the matching ``(rows, n_out)`` / ``(n_out,)`` int32 output,
    bit-exact with ``net(codes)``.
    """

    def __init__(self, net, config: TierConfig | None = None):
        cfg = config or TierConfig()
        self._net = net
        self._cfg = cfg
        # the artifact's ExecutionPlan is the source of truth for the batch
        # tile (an autotuned artifact may have picked a non-default
        # block_b); net.block_b is the fallback for plan-less stand-ins
        block_b = getattr(getattr(net, "plan", None), "block_b", None) \
            or net.block_b
        self._max_batch = cfg.max_batch_rows or block_b
        if self._max_batch <= 0:
            raise ValueError("max_batch_rows must be positive")
        devices = tuple(cfg.devices) if cfg.devices else tuple(jax.devices())
        self._devices = devices
        # batches are padded to a multiple of this unit: block_b keeps the
        # engine on its one-trace-per-bucket contract, len(devices) keeps
        # the shard_map batch axis evenly divisible
        self._bucket_unit = math.lcm(block_b, len(devices))
        self._forward, self._sharded_jit = self._make_forward()
        self._pending: collections.deque[_Request] = collections.deque()
        self._queued_rows = 0
        self._wake = asyncio.Event()
        self._stopping = False
        self._task: asyncio.Task | None = None
        self._started = False
        # observability: every counter the old flat stats() dict carried
        # now lives in the process metrics registry (labeled per tier);
        # stats() reads them back so its keys are unchanged
        self._metrics = _TierMetrics(str(next(_TIER_IDS)))
        self._recent_spans: collections.deque[obs.Span] = (
            collections.deque(maxlen=32))
        self._traces0 = 0
        self._compiler_runs0 = 0

    # -- forward construction ----------------------------------------------

    def _make_forward(self):
        """(forward(padded) -> jax.Array, sharded jit fn or None)."""
        net = self._net
        if len(self._devices) == 1:
            return net, None
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        mesh = Mesh(np.asarray(self._devices), ("data",))
        # the slab arrays live in net._apply's closure: shard_map treats
        # them as replicated constants (they are tiny — the whole point of
        # the mixed layout), only the batch axis of the codes is split
        fwd = jax.jit(shard_map(net._apply, mesh=mesh, in_specs=P("data"),
                                out_specs=P("data"), check_rep=False))
        in_sharding = NamedSharding(mesh, P("data"))

        def forward(padded):
            return fwd(jax.device_put(padded, in_sharding))
        return forward, fwd

    def _bucket(self, rows: int) -> int:
        return -(-rows // self._bucket_unit) * self._bucket_unit

    def _run_batch(self, batch: np.ndarray):
        """Pad to the bucket, run the (possibly sharded) forward, slice.

        Returns ``(out, padded_rows, t_dispatch, t_done)`` — the two
        timestamps bracket the device leg of every request span in the
        batch (materializing the result included).
        """
        rows = batch.shape[0]
        padded_rows = self._bucket(rows)
        if padded_rows != rows:
            batch = np.concatenate(
                [batch, np.zeros((padded_rows - rows, batch.shape[1]),
                                 dtype=batch.dtype)], axis=0)
        t_dispatch = time.perf_counter()
        if self._sharded_jit is None:
            out = self._net(batch)           # the engine pads/slices itself
        else:
            out = self._forward(jnp.asarray(batch, dtype=jnp.int32))
        out = np.asarray(out)[:rows]
        return out, padded_rows, t_dispatch, time.perf_counter()

    def _trace_count(self) -> int:
        n = self._net.jit_cache_size()
        if self._sharded_jit is not None:
            n += self._sharded_jit._cache_size()
        return n

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "ServingTier":
        """Warm the batch buckets and start the batcher task."""
        if self._started:
            raise TierError("tier already started")
        self._started = True
        if self._cfg.warmup:
            loop = asyncio.get_running_loop()
            for rows in range(self._bucket_unit,
                              self._bucket(self._max_batch) + 1,
                              self._bucket_unit):
                zeros = np.zeros((rows, self._net.n_in), dtype=np.int32)
                await loop.run_in_executor(
                    None, lambda z=zeros: jax.block_until_ready(
                        self._run_batch(z)[0]))
        self._traces0 = self._trace_count()
        self._compiler_runs0 = rengine.compile_runs()
        self._task = asyncio.create_task(self._batcher())
        return self

    async def stop(self) -> None:
        """Drain queued requests into final batches, then shut down.

        Safe on an empty queue (returns as soon as the batcher notices);
        requests submitted after ``stop`` raise :class:`TierClosed`.
        """
        if not self._started or self._stopping:
            return
        self._stopping = True
        self._wake.set()
        if self._task is not None:
            await self._task

    async def __aenter__(self) -> "ServingTier":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- request path -------------------------------------------------------

    async def infer(self, codes) -> np.ndarray:
        """Submit one request; resolves when its batch has been served.

        ``codes`` is ``(rows, n_in)`` (or one ``(n_in,)`` row) of int
        codes.  Raises :class:`TierOverloaded` when the bounded queue is
        full, :class:`RequestTimeout` when the request expires before
        launch, :class:`TierClosed` when the tier is stopped, and
        ``ValueError`` on a shape mismatch.

        >>> import asyncio, numpy as np
        >>> from repro import engine, serve
        >>> rng = np.random.default_rng(0)
        >>> idx = np.stack([np.sort(rng.choice(6, 2, replace=False))
        ...                 for _ in range(4)]).astype(np.int32)
        >>> tbl = rng.integers(0, 4, (4, 16), dtype=np.int32)
        >>> net = engine.compile_network([(idx, tbl, 2)], in_features=6,
        ...                              block_b=4)
        >>> async def main():
        ...     async with serve.ServingTier(net) as tier:
        ...         codes = rng.integers(0, 4, (3, 6), dtype=np.int32)
        ...         out = await tier.infer(codes)
        ...         return codes, out, tier.stats()
        >>> codes, out, stats = asyncio.run(main())
        >>> bool((out == np.asarray(net(codes))).all())    # bit-exact
        True
        >>> stats["retraces_after_warmup"]                 # compile-once
        0
        """
        arr = np.asarray(codes, dtype=np.int32)
        single = arr.ndim == 1
        if single:
            arr = arr[None, :]
        if arr.ndim != 2 or arr.shape[1] != self._net.n_in:
            raise ValueError(
                f"expected (rows, {self._net.n_in}) codes, got "
                f"{np.asarray(codes).shape}")
        if self._stopping or not self._started:
            raise TierClosed("serving tier is not accepting requests")
        rows = arr.shape[0]
        if rows == 0:
            return arr.reshape(0, self._net.n_out)
        if self._queued_rows + rows > self._cfg.max_queue_rows:
            self._metrics.rejected.inc()
            raise TierOverloaded(
                f"queue holds {self._queued_rows} rows; request of {rows} "
                f"would exceed max_queue_rows={self._cfg.max_queue_rows}")
        loop = asyncio.get_running_loop()
        now = loop.time()
        deadline = (None if self._cfg.request_timeout_s is None
                    else now + self._cfg.request_timeout_s)
        req = _Request(arr, loop.create_future(), now, deadline,
                       obs.Span("request"))
        self._pending.append(req)
        self._queued_rows += rows
        self._metrics.requests.inc()
        self._metrics.rows.inc(rows)
        self._wake.set()
        out = await req.future
        return out[0] if single else out

    # -- batcher ------------------------------------------------------------

    def _expire_overdue(self, now: float) -> None:
        while self._pending:
            req = self._pending[0]
            if req.deadline_t is None or now < req.deadline_t:
                break
            self._pending.popleft()
            self._queued_rows -= req.codes.shape[0]
            self._metrics.timed_out.inc()
            self._metrics.expired_rows.inc(req.codes.shape[0])
            if not req.future.done():
                req.future.set_exception(RequestTimeout(
                    f"request waited past request_timeout_s="
                    f"{self._cfg.request_timeout_s}"))

    def _take_batch(self) -> list[_Request]:
        taken, rows = [], 0
        while self._pending:
            nxt = self._pending[0].codes.shape[0]
            if taken and rows + nxt > self._max_batch:
                break
            taken.append(self._pending.popleft())
            rows += nxt
            self._queued_rows -= nxt
            if rows >= self._max_batch:
                break
        return taken

    async def _batcher(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            while not self._pending and not self._stopping:
                self._wake.clear()
                await self._wake.wait()
            now = loop.time()
            self._expire_overdue(now)
            if not self._pending:
                if self._stopping:
                    break
                continue
            # size-or-deadline coalescing window, bounded by the oldest
            # request's timeout so an expiring request is noticed in time
            cause = "drain" if self._stopping else None
            while not self._stopping:
                if self._queued_rows >= self._max_batch:
                    cause = "size"
                    break
                oldest = self._pending[0]
                flush_at = oldest.enqueue_t + self._cfg.flush_deadline_s
                if oldest.deadline_t is not None:
                    flush_at = min(flush_at, oldest.deadline_t)
                remaining = flush_at - loop.time()
                if remaining <= 0:
                    cause = "deadline"
                    break
                self._wake.clear()
                try:
                    await asyncio.wait_for(self._wake.wait(), remaining)
                except asyncio.TimeoutError:
                    pass
            self._expire_overdue(loop.time())
            batch = self._take_batch()
            if not batch:
                continue
            cause = cause or "drain"
            t_flush = time.perf_counter()   # the flush decision: queue
            codes = (batch[0].codes if len(batch) == 1 else
                     np.concatenate([r.codes for r in batch], axis=0))
            try:
                out, padded_rows, t_dispatch, t_done = (
                    await loop.run_in_executor(None, self._run_batch, codes))
            except Exception as exc:               # pragma: no cover
                for req in batch:
                    if not req.future.done():
                        req.future.set_exception(
                            TierError(f"batch execution failed: {exc!r}"))
                continue
            self._metrics.batches.inc()
            self._metrics.padded_rows.inc(padded_rows)
            self._metrics.flush_by_cause[cause].inc()
            off = 0
            for req in batch:
                n = req.codes.shape[0]
                if not req.future.done():
                    req.future.set_result(out[off:off + n])
                off += n
                # close the request span with the batch's shared
                # timestamps and feed the stage histograms
                span = req.span
                span.mark("flush", t_flush)
                span.mark("dispatch", t_dispatch)
                span.mark("done", t_done)
                self._metrics.queue_wait.observe(
                    span.duration("enqueue", "flush"))
                self._metrics.assembly.observe(
                    span.duration("flush", "dispatch"))
                self._metrics.device.observe(
                    span.duration("dispatch", "done"))
                self._metrics.latency.observe(span.total)
                self._recent_spans.append(span)
        # post-drain: anything that slipped in after the final drain pass
        while self._pending:
            req = self._pending.popleft()
            self._queued_rows -= req.codes.shape[0]
            if not req.future.done():
                req.future.set_exception(TierClosed("tier stopped"))

    # -- observability ------------------------------------------------------

    def stats(self) -> dict:
        """Steady-state serving counters (see the bench's ``serving_tier``
        section for the latency/QPS view built on top of these).

        ``batch_occupancy`` is served rows / padded batch capacity — the
        fraction of kernel work doing real requests rather than bucket
        padding.  ``retraces_after_warmup`` / ``compiler_runs_after_warmup``
        are the compile-once serving contract and must stay exactly 0 in
        steady state.  The same counters live in the process metrics
        registry (``repro.obs``, labeled per tier); this dict is the
        backward-compatible flat view of this tier's slice of it.
        """
        m = self._metrics
        n_rows = int(m.rows.value)
        n_batches = int(m.batches.value)
        n_padded = int(m.padded_rows.value)
        served_rows = n_rows - int(m.expired_rows.value) - self._queued_rows
        retraces = self._trace_count() - self._traces0
        compiler_runs = rengine.compile_runs() - self._compiler_runs0
        # mirror the point-in-time quantities into the registry so a
        # snapshot taken after the run carries the compile-once contract
        m.queued_rows.set(self._queued_rows)
        m.retraces.set(retraces)
        m.compiler_runs.set(compiler_runs)
        return {
            "requests": int(m.requests.value),
            "rows": n_rows,
            "batches": n_batches,
            "padded_rows": n_padded,
            "batch_occupancy": served_rows / n_padded if n_padded else 0.0,
            "mean_batch_rows": (served_rows / n_batches
                                if n_batches else 0.0),
            "flush_causes": {cause: int(c.value)
                             for cause, c in m.flush_by_cause.items()},
            "rejected": int(m.rejected.value),
            "timed_out": int(m.timed_out.value),
            "queued_rows": self._queued_rows,
            "n_devices": len(self._devices),
            "sharded": self._sharded_jit is not None,
            "bucket_unit": self._bucket_unit,
            "max_batch_rows": self._max_batch,
            "retraces_after_warmup": retraces,
            "compiler_runs_after_warmup": compiler_runs,
        }

    def latency_breakdown(self) -> dict:
        """Per-stage latency summary from this tier's span histograms.

        ``{stage: {count, mean_ms, p50_ms, p99_ms}}`` for the three span
        legs (``queue_wait``, ``assembly``, ``device``) plus the whole
        request (``total``) — the "where did the latency go" view that
        ``loadgen.LoadReport.breakdown`` and the bench's ``serving_tier``
        section surface.  Percentiles are bucket-interpolated estimates;
        a stage with no observations reports zeros.
        """
        m = self._metrics
        out = {}
        for stage, h in (("queue_wait", m.queue_wait),
                         ("assembly", m.assembly),
                         ("device", m.device),
                         ("total", m.latency)):
            n = h.count
            out[stage] = {
                "count": n,
                "mean_ms": h.mean() * 1e3 if n else 0.0,
                "p50_ms": h.quantile(0.5) * 1e3 if n else 0.0,
                "p99_ms": h.quantile(0.99) * 1e3 if n else 0.0,
            }
        return out

    def recent_spans(self) -> list[obs.Span]:
        """The most recent completed request spans (bounded ring)."""
        return list(self._recent_spans)


async def serve_once(net, requests, config: TierConfig | None = None
                     ) -> list[np.ndarray]:
    """Convenience: start a tier, serve ``requests`` concurrently, stop.

    ``requests`` is an iterable of ``(rows, n_in)`` arrays; returns the
    outputs in order.  This is the one-shot shape used by the bench and
    the docs examples::

        outs = asyncio.run(serve.serve_once(net, [r0, r1, r2]))
    """
    async with ServingTier(net, config) as tier:
        return list(await asyncio.gather(
            *[tier.infer(r) for r in requests]))


def run_requests(net, requests, config: TierConfig | None = None
                 ) -> list[np.ndarray]:
    """Blocking wrapper over :func:`serve_once` for sync callers/tests."""
    return asyncio.run(serve_once(net, requests, config))
