"""Two-level logic synthesis: SOP covers over reachable on-sets.

The in-repo replacement for the synthesis step the paper delegates to
Vivado — see :mod:`repro.synth.sop` for the cover IR and
:mod:`repro.synth.minimize` for the Quine–McCluskey minimizer.
"""

from repro.synth.minimize import (
    DEFAULT_MAX_BITS,
    DEFAULT_MAX_CUBES,
    minimize_bit,
    minimize_table,
    synthesize_netlist,
)
from repro.synth.sop import Cube, SopCover

__all__ = [
    "Cube",
    "SopCover",
    "DEFAULT_MAX_BITS",
    "DEFAULT_MAX_CUBES",
    "minimize_bit",
    "minimize_table",
    "synthesize_netlist",
]
