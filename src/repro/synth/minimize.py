"""Quine–McCluskey / espresso-style two-level minimization.

The paper's tool-flow hands every neuron's full truth table to Vivado
and lets its logic synthesis find the minimized circuit; this module is
that step done in-repo, over exactly the don't-care information the
compile pipeline already harvests:

* the **on-set** of each output bit is read from the neuron's table,
  restricted to *reachable* entries (the reachability pass's mask);
* every **unreachable** entry is a don't-care, free to be absorbed into
  whichever prime implicant shrinks the cover most;
* prime implicants come from iterative cube merging (two same-mask
  cubes differing in one cared bit merge into one cube with that bit
  dropped), then an essential-prime + greedy irredundant cover of the
  on-set.

Budgets make wide fan-ins degrade gracefully: a neuron whose input
width exceeds ``max_bits``, or whose merge frontier outgrows
``max_cubes``, *falls back to the unminimized table* (``minimize_table``
returns None) — downstream consumers emit the plain case-statement
module and price the neuron at the worst-case ``lut_cost`` bound, so
synthesis can never make a build fail, only decline to improve it.

>>> import numpy as np
>>> table = np.array([0, 1, 1, 1])          # OR of two inputs
>>> cover = minimize_table(table, n_in=2, out_bits=1)
>>> cover.table().tolist()
[0, 1, 1, 1]
>>> cover.n_terms, cover.n_literals        # two 1-literal cubes: a | b
(2, 2)
"""

from __future__ import annotations

import numpy as np

from repro.synth.sop import Cube, SopCover

# fall back to the unminimized table beyond these sizes: 2^14 minterms
# is where exact QM stops being interactive, and the merge frontier cap
# bounds the pathological middle levels on dense functions
DEFAULT_MAX_BITS = 14
DEFAULT_MAX_CUBES = 8192


def _prime_implicants(minterms: set[int], n_in: int,
                      max_cubes: int) -> set[Cube] | None:
    """All prime implicants of ``minterms`` (on-set ∪ dc-set).

    Iterative merging: two cubes with the same mask whose values differ
    in exactly one cared bit combine into one cube without that bit.
    Cubes that never merge at any level are prime.  Returns None when a
    level's cube count exceeds ``max_cubes`` (budget exceeded).
    """
    full = (1 << n_in) - 1
    current: set[Cube] = {Cube(full, m) for m in minterms}
    primes: set[Cube] = set()
    while current:
        if len(current) > max_cubes:
            return None
        by_mask: dict[int, set[int]] = {}
        for c in current:
            by_mask.setdefault(c.mask, set()).add(c.value)
        merged: set[Cube] = set()
        nxt: set[Cube] = set()
        for mask, vals in by_mask.items():
            bits = [1 << i for i in range(n_in) if mask >> i & 1]
            for v in vals:
                for b in bits:
                    if v & b:
                        continue
                    if (v | b) in vals:
                        nxt.add(Cube(mask & ~b, v))
                        merged.add(Cube(mask, v))
                        merged.add(Cube(mask, v | b))
        primes |= current - merged
        current = nxt
    return primes


def _cube_minterms(cube: Cube, on_set: set[int]) -> frozenset[int]:
    """On-set minterms a cube covers (don't-cares excluded on purpose:
    the cover must contain the on-set; it never owes the dc-set)."""
    return frozenset(m for m in on_set if cube.covers(m))


def _select_cover(primes: set[Cube], on_set: set[int]) -> tuple[Cube, ...]:
    """Essential primes, then greedy set cover of the remaining on-set.

    Deterministic: ties break toward fewer literals, then the smallest
    ``(mask, value)`` pair, so identical tables always synthesize
    identical covers (CSE/golden-file friendly).
    """
    coverage = {p: _cube_minterms(p, on_set) for p in sorted(primes)}
    coverage = {p: c for p, c in coverage.items() if c}
    chosen: list[Cube] = []
    uncovered = set(on_set)

    # essential primes: an on-set minterm covered by exactly one prime
    by_minterm: dict[int, list[Cube]] = {m: [] for m in on_set}
    for p, cov in coverage.items():
        for m in cov:
            by_minterm[m].append(p)
    for m, ps in sorted(by_minterm.items()):
        if len(ps) == 1 and ps[0] not in chosen:
            chosen.append(ps[0])
            uncovered -= coverage[ps[0]]

    # greedy: the prime covering the most uncovered minterms wins
    while uncovered:
        best = max(
            coverage,
            key=lambda p: (len(coverage[p] & uncovered),
                           -p.n_literals, -p.mask, -p.value))
        if not coverage[best] & uncovered:   # pragma: no cover - safety
            raise AssertionError("prime implicants failed to cover on-set")
        chosen.append(best)
        uncovered -= coverage[best]

    # irredundant pass: drop any chosen cube whose on-set contribution
    # is contained in the union of the others (greedy order can strand
    # essential-then-superseded picks)
    kept: list[Cube] = []
    for i, p in enumerate(chosen):
        others = [q for j, q in enumerate(chosen) if j != i
                  and (q in kept or j > i)]
        rest = set().union(*(coverage[q] for q in others)) if others else set()
        if not coverage[p] <= rest:
            kept.append(p)
    return tuple(sorted(kept))


def minimize_bit(on_set: set[int], dc_set: set[int], n_in: int, *,
                 max_cubes: int = DEFAULT_MAX_CUBES
                 ) -> tuple[Cube, ...] | None:
    """Minimized cover of one output bit; None when over budget.

    ``on_set`` / ``dc_set`` are disjoint sets of input words.  Constant
    bits short-circuit: empty on-set -> ``()`` (constant 0); on-set ∪
    dc-set = everything -> the tautology cube (constant 1).
    """
    if not on_set:
        return ()
    n_words = 1 << n_in
    if len(on_set) + len(dc_set) == n_words:
        return (Cube(0, 0),)
    primes = _prime_implicants(on_set | dc_set, n_in, max_cubes)
    if primes is None:
        return None
    return _select_cover(primes, on_set)


def minimize_table(table, n_in: int, out_bits: int, reachable=None, *,
                   max_bits: int = DEFAULT_MAX_BITS,
                   max_cubes: int = DEFAULT_MAX_CUBES) -> SopCover | None:
    """Minimize one neuron's truth table into a :class:`SopCover`.

    ``table`` has ``2^n_in`` output codes; ``reachable`` (optional bool
    mask of the same length) marks which entries can occur at runtime —
    everything else is a don't-care.  Returns None when the neuron
    exceeds the budget (``n_in > max_bits``, or any output bit's merge
    frontier outgrows ``max_cubes``): the caller keeps the unminimized
    table.  The result is exact on every reachable entry (asserted) and
    unconstrained on don't-cares.
    """
    table = np.asarray(table, dtype=np.int64)
    if table.shape[0] != 1 << n_in:
        raise ValueError(
            f"table has {table.shape[0]} entries; n_in={n_in} requires "
            f"2^{n_in}")
    if n_in > max_bits:
        return None
    if reachable is None:
        reach = np.ones(table.shape[0], dtype=bool)
    else:
        reach = np.asarray(reachable, dtype=bool)
    dc_set = set(np.flatnonzero(~reach).tolist())
    reach_words = np.flatnonzero(reach)
    covers = []
    for b in range(out_bits):
        on = set(reach_words[(table[reach_words] >> b & 1) == 1].tolist())
        cover = minimize_bit(on, dc_set, n_in, max_cubes=max_cubes)
        if cover is None:
            return None
        covers.append(cover)
    result = SopCover(n_in=n_in, out_bits=out_bits, bits=tuple(covers))
    # exactness contract: reachable entries must round-trip bit-for-bit
    got = result.evaluate(reach_words)
    want = table[reach_words] & ((1 << out_bits) - 1)
    if not np.array_equal(got, want):   # pragma: no cover - invariant
        raise AssertionError("minimized cover diverged from the on-set")
    return result


def synthesize_netlist(netlist, *, max_bits: int = DEFAULT_MAX_BITS,
                       max_cubes: int = DEFAULT_MAX_CUBES) -> dict:
    """Attach minimized covers to every neuron of a ``Netlist`` in place.

    Each :class:`~repro.core.netlist.NeuronHBB` gains ``sop`` (its
    :class:`SopCover`, or None on budget fallback), using the neuron's
    ``reachable`` mask — the compile pipeline's don't-care harvest — as
    the dc-set.  Returns the synthesis statistics dict the bench/CI
    stats artifact records:

    ``neurons`` / ``covered_neurons`` / ``fallback_neurons``, plus the
    literal/term accounting before (reachable on-set minterms priced as
    full cubes — the two-level cost of the unminimized table) and after
    minimization.
    """
    neurons = covered = 0
    terms_before = literals_before = 0
    terms_after = literals_after = 0
    for layer in netlist.layers:
        for n in layer:
            neurons += 1
            n_in = len(n.input_bits)
            table = np.asarray(n.table, dtype=np.int64)
            if n.reachable is None:
                reach = np.ones(table.shape[0], dtype=bool)
            else:
                reach = np.asarray(n.reachable, dtype=bool)
            words = np.flatnonzero(reach)
            for b in range(n.out_bits):
                on = int(np.count_nonzero(table[words] >> b & 1))
                terms_before += on
                literals_before += on * n_in
            cover = minimize_table(table, n_in, n.out_bits, reach,
                                   max_bits=max_bits, max_cubes=max_cubes)
            n.sop = cover
            if cover is not None:
                covered += 1
                terms_after += cover.n_terms
                literals_after += cover.n_literals
            else:
                # fallback keeps the table: price it like the on-set
                for b in range(n.out_bits):
                    on = int(np.count_nonzero(table[words] >> b & 1))
                    terms_after += on
                    literals_after += on * n_in
    return {
        "neurons": neurons,
        "covered_neurons": covered,
        "fallback_neurons": neurons - covered,
        "terms_before": terms_before,
        "literals_before": literals_before,
        "terms_after": terms_after,
        "literals_after": literals_after,
        "max_bits": max_bits,
        "max_cubes": max_cubes,
    }


__all__ = ["DEFAULT_MAX_BITS", "DEFAULT_MAX_CUBES", "minimize_bit",
           "minimize_table", "synthesize_netlist"]
