"""Sum-of-products cover IR: the two-level synthesis result.

A :class:`SopCover` is the minimized form of one neuron's truth table —
per output bit, a list of :class:`Cube` product terms whose OR computes
that bit.  It is the contract between the minimizer
(``repro.synth.minimize``), the SOP Verilog backend
(``repro.core.verilog.generate_verilog(..., sop=True)``) and the
measured-cost model (``repro.core.lut_cost.sop_lut_estimate``).

A cube is an ``(mask, value)`` pair over the neuron's ``n_in`` input
bits: input word ``w`` is covered iff ``(w & mask) == value``.  Bits
outside ``mask`` are don't-cares within the cube, so the number of set
bits in ``mask`` is the cube's literal count — the quantity two-level
minimization drives down.  ``Cube(0, 0)`` covers every word (the
tautology); an output bit with *no* cubes is constant 0.

Covers are exact only on the *reachable* on-set they were extracted
from: on don't-care (unreachable) inputs a cover may legally disagree
with the source table — that freedom is where the minimization wins
come from, and why every consumer compares behavior on reachable
inputs only (network input words are always reachable by contract).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np


class Cube(NamedTuple):
    """One product term over ``n_in`` input bits.

    ``mask`` selects the cared-about bits, ``value`` their required
    values (``value & ~mask == 0`` always).  Examples:

    >>> c = Cube(mask=0b101, value=0b001)       # M0[0] & ~M0[2]
    >>> c.covers(0b001), c.covers(0b011), c.covers(0b100)
    (True, True, False)
    >>> c.n_literals
    2
    >>> Cube(0, 0).covers(0b111)                # tautology covers all
    True
    """

    mask: int
    value: int

    def covers(self, word: int) -> bool:
        return (word & self.mask) == self.value

    @property
    def n_literals(self) -> int:
        return int(self.mask).bit_count()

    def literals(self) -> list[tuple[int, bool]]:
        """``(input bit position, positive?)`` per literal, LSB first."""
        out = []
        mask, value = int(self.mask), int(self.value)
        pos = 0
        while mask:
            if mask & 1:
                out.append((pos, bool(value & 1)))
            mask >>= 1
            value >>= 1
            pos += 1
        return out


@dataclasses.dataclass(frozen=True)
class SopCover:
    """Minimized two-level cover of one neuron: per-output-bit cube lists.

    ``bits[b]`` is the tuple of cubes whose OR computes output bit ``b``
    (LSB first).  Empty tuple = constant 0; a tuple containing the
    tautology cube ``Cube(0, 0)`` = constant 1.

    >>> cover = SopCover(n_in=2, out_bits=1,
    ...                  bits=((Cube(0b01, 0b01), Cube(0b10, 0b00)),))
    >>> [cover.evaluate_word(w) for w in range(4)]   # M0[0] | ~M0[1]
    [1, 1, 0, 1]
    >>> cover.n_terms, cover.n_literals
    (2, 2)
    """

    n_in: int
    out_bits: int
    bits: tuple[tuple[Cube, ...], ...]

    def __post_init__(self) -> None:
        if len(self.bits) != self.out_bits:
            raise ValueError(
                f"cover has {len(self.bits)} bit covers for "
                f"{self.out_bits} output bits")

    @property
    def n_terms(self) -> int:
        """Total product terms across all output bits."""
        return sum(len(cubes) for cubes in self.bits)

    @property
    def n_literals(self) -> int:
        """Total literal count — the two-level minimization objective."""
        return sum(c.n_literals for cubes in self.bits for c in cubes)

    def bit_support(self, b: int) -> tuple[int, ...]:
        """Input bit positions output bit ``b`` actually depends on."""
        mask = 0
        for c in self.bits[b]:
            mask |= int(c.mask)
        return tuple(i for i in range(self.n_in) if mask >> i & 1)

    def evaluate(self, entries) -> np.ndarray:
        """Vectorized evaluation: entry words -> output codes (int64).

        >>> cover = SopCover(1, 1, bits=((Cube(1, 0),),))    # ~M0[0]
        >>> cover.evaluate(np.arange(2)).tolist()
        [1, 0]
        """
        entries = np.asarray(entries, dtype=np.int64)
        out = np.zeros(entries.shape, dtype=np.int64)
        for b, cubes in enumerate(self.bits):
            hit = np.zeros(entries.shape, dtype=bool)
            for c in cubes:
                hit |= (entries & int(c.mask)) == int(c.value)
            out |= hit.astype(np.int64) << b
        return out

    def evaluate_word(self, word: int) -> int:
        """Scalar evaluation of one input word."""
        return int(self.evaluate(np.asarray([word]))[0])

    def table(self) -> np.ndarray:
        """The full ``2^n_in``-entry truth table this cover computes."""
        return self.evaluate(np.arange(1 << self.n_in))


__all__ = ["Cube", "SopCover"]
