"""Import shim: real hypothesis when installed, skip-marking stubs otherwise.

CI installs the ``test`` extra (which includes hypothesis) and runs the
property tests for real.  In a bare environment the stubs below let the
modules still *collect*, marking only the property-based cases as skipped —
the plain unit tests in the same files keep running.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e .[test])",
            )(fn)
        return deco

    def settings(*args, **kwargs):
        return lambda fn: fn

    class _Strategies:
        """Strategy calls only happen at decoration time; return None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
