"""Per-architecture smoke tests: reduced same-family configs, one real
forward/train step + one decode step on CPU — shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Each per-arch case is a 5-25 s real forward/train step; CI runs -m "not slow".
pytestmark = pytest.mark.slow

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.steps import (input_specs, make_decode_step,
                                make_train_state, make_train_step)
from repro.models import model as M


def _toy_batch(cfg, batch=2, seq=32, seed=0):
    key = jax.random.PRNGKey(seed)
    b = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab),
         "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab)}
    if cfg.vision_tokens > 0:
        b["vision_embeds"] = jax.random.normal(
            key, (batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        b["frames"] = jax.random.normal(
            key, (batch, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    batch = _toy_batch(cfg, batch=2, seq=32)
    logits, aux = M.forward(state["params"], cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    step = jax.jit(make_train_step(cfg))
    new_state, loss = step(state, batch)
    assert np.isfinite(float(loss))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)),
                     state["params"], new_state["params"]))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    state = make_train_state(cfg, jax.random.PRNGKey(1))
    batch_sz, cache_len = 2, 64
    cache = M.init_cache(cfg, batch_sz, cache_len)
    tokens = jnp.zeros((batch_sz, 1), jnp.int32)
    pos = jnp.zeros((batch_sz,), jnp.int32)
    step = jax.jit(make_decode_step(cfg))
    logits, new_cache = step(state["params"], cache, tokens, pos)
    assert logits.shape == (batch_sz, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_values(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "mamba2-370m": (48, 1024, 16, 16, 0, 50280),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (128, 8)
        # ~235B total, ~22B active
        assert 2.0e11 < cfg.param_count() < 2.6e11
        assert 1.7e10 < cfg.active_param_count() < 2.7e10
    if arch == "olmoe-1b-7b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (64, 8)
        assert 5e9 < cfg.param_count() < 9e9           # ~7B total
        assert 0.7e9 < cfg.active_param_count() < 1.7e9  # ~1B active
    if arch == "zamba2-2.7b":
        assert cfg.ssm.d_state == 64 and cfg.hybrid_attn_every == 6
    if arch == "mamba2-370m":
        assert cfg.ssm.d_state == 128
        assert 2.5e8 < cfg.param_count() < 5e8
    if arch == "gemma3-27b":
        assert cfg.local_global_ratio == 5
        assert 2.2e10 < cfg.param_count() < 3.2e10


def test_shape_cells_and_skips():
    from repro.configs import SHAPES, cell_skip
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524288
    # long_500k runs only for SSM/hybrid
    assert cell_skip(get_config("mamba2-370m"), "long_500k") is None
    assert cell_skip(get_config("zamba2-2.7b"), "long_500k") is None
    assert cell_skip(get_config("gemma3-27b"), "long_500k") is not None
    assert cell_skip(get_config("qwen3-1.7b"), "long_500k") is not None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_all_cells(arch):
    from repro.configs import SHAPES, cell_skip
    cfg = get_config(arch)
    for name, cell in SHAPES.items():
        if cell_skip(cfg, name):
            continue
        specs = input_specs(cfg, cell)
        leaves = jax.tree.leaves(specs)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        if cell.kind != "decode":
            assert specs["batch"]["tokens"].shape == (cell.global_batch,
                                                      cell.seq_len)
