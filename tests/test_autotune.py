"""Compile-time variant autotuner: ``repro.kernels.plan`` + ``engine``.

Four contracts under test:

* **enumeration** — ``enumerate_variants`` produces the deterministic
  layout x block_b x pack space (fused-ineligible layouts skipped, the
  per-layer escape hatch always present), and ``default_variant``
  reproduces the heuristic ladder ``compile_network`` used before the
  autotuner existed;
* **selection** — ``compile_network(autotune=True)`` stays bit-exact
  against the reference, carries a full per-variant timing table, and
  picks the measured minimum (so it is never slower than the heuristic
  default *on the table it measured*);
* **persistence** — the :class:`ExecutionPlan` (winner, source, timing
  table, default key) round-trips through ``save``/``load`` with zero
  re-search and zero compiler runs at load;
* **compat** — a format-1 artifact (bare ``FusedPlan`` record, no
  variant) still loads bit-exact with a synthesized default plan, and a
  format newer than this build is rejected.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core.table_infer import network_table_forward
from repro.core.truth_table import LayerTruthTable
from repro.engine.autotune import ExecutionPlan, autotune_network
from repro.kernels import (DEFAULT_BLOCK_B, FusedPlan, default_variant,
                           enumerate_variants, fused_plan)


def _random_stack(widths, fan_ins, bws, seed=0):
    rng = np.random.default_rng(seed)
    layers = []
    for (n_in, n_out), fi, bw in zip(zip(widths[:-1], widths[1:]),
                                     fan_ins, bws):
        fi = min(fi, n_in)
        idx = np.stack([np.sort(rng.choice(n_in, fi, replace=False))
                        for _ in range(n_out)]).astype(np.int32)
        tab = rng.integers(0, 2 ** bw, (n_out, 2 ** (fi * bw)),
                           dtype=np.int32)
        layers.append((idx, tab, bw))
    return layers


def _tables(layers):
    return [LayerTruthTable(tab, idx, bw, bw) for idx, tab, bw in layers]


def _codes(n_in, bw, batch, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(
        0, 2 ** bw, (batch, n_in), dtype=np.int32))


STACK = ((12, 20, 16, 8), (3, 3, 3), (2, 2, 2))


# ---------------------------------------------------------------------------
# enumeration
# ---------------------------------------------------------------------------


def test_enumerate_variants_space_and_keys():
    layers = _random_stack(*STACK, seed=13)
    variants = enumerate_variants(uniform_triples=layers,
                                  block_bs=(16, 32))
    keys = [v.key for v in variants]
    assert len(keys) == len(set(keys)), "variant keys must be unique"
    layouts = {v.layout for v in variants}
    # no mixed tables were passed, so no mixed variants; the per-layer
    # escape hatch is always enumerable
    assert layouts == {"uniform", "per_layer"}
    assert {v.block_b for v in variants} == {16, 32}
    # every fused variant carries a fused costing; per_layer never does
    for v in variants:
        assert v.cost.fused == (v.layout != "per_layer")
        if v.layout == "per_layer" and fused_plan(layers).fused:
            assert v.cost.reason == "per_layer_variant"
    # a packed-eligible stack also enumerates the unpacked fallback
    auto = fused_plan(layers)
    if auto.pack:
        packs = {v.pack for v in variants if v.layout == "uniform"}
        assert packs == {True, False}


def test_enumerate_variants_skips_over_budget_layouts():
    layers = _random_stack(*STACK, seed=13)
    variants = enumerate_variants(uniform_triples=layers,
                                  block_bs=(16,), vmem_budget_bytes=64)
    # nothing fits in 64 B of VMEM: only the per-layer fallback remains
    assert {v.layout for v in variants} == {"per_layer"}
    assert variants[0].cost.reason == "slab_exceeds_vmem_budget"


def test_default_variant_matches_heuristic_ladder():
    layers = _random_stack(*STACK, seed=13)
    # fused-eligible: the ladder lands on uniform with the auto pack
    v = default_variant(uniform_triples=layers, block_b=32)
    assert v.layout == "uniform" and v.block_b == 32
    assert v.cost == fused_plan(layers)
    # over budget: the ladder falls back to per_layer, unpacked
    v64 = default_variant(uniform_triples=layers, vmem_budget_bytes=64)
    assert v64.layout == "per_layer" and v64.pack is False
    assert v64.block_b == DEFAULT_BLOCK_B
    # the heuristic compile path must agree with the ladder
    eng = engine.compile_network(layers, in_features=STACK[0][0],
                                 block_b=32)
    assert eng.plan.source == "heuristic"
    assert eng.plan.variant == v


# ---------------------------------------------------------------------------
# selection
# ---------------------------------------------------------------------------


def test_autotune_bit_exact_and_picks_measured_minimum():
    widths, fan_ins, bws = STACK
    layers = _random_stack(widths, fan_ins, bws, seed=21)
    codes = _codes(widths[0], bws[0], 17, seed=1)
    want = np.asarray(network_table_forward(_tables(layers), codes))

    runs0 = engine.compile_runs()
    eng = engine.compile_network(layers, optimize_level=3,
                                 in_features=widths[0], autotune=True,
                                 block_b=16, autotune_block_bs=(8, 16))
    np.testing.assert_array_equal(np.asarray(eng(codes)), want)
    plan = eng.plan
    assert plan.source == "autotune"
    assert plan.variant.key in plan.timings_us
    assert plan.default_key in plan.timings_us
    # winner is the measured minimum, so in particular it is no slower
    # than the heuristic default on the same timing table
    best = min(plan.timings_us, key=plan.timings_us.get)
    assert plan.variant.key == best
    assert (plan.timings_us[plan.default_key]
            >= plan.timings_us[plan.variant.key])
    # the artifact serves at the winner's batch tile
    assert eng.block_b == plan.block_b
    # the search timed the jitted forwards, never the truth-table
    # compiler (one run for optimize_level=3 itself, none for the sweep)
    assert engine.compile_runs() == runs0 + 1


def test_autotune_network_times_every_variant():
    layers = _random_stack(*STACK, seed=17)
    plan, built = autotune_network(layers, in_features=STACK[0][0],
                                   block_b=16, block_bs=(8, 16))
    want_keys = {v.key for v in enumerate_variants(
        uniform_triples=layers, block_bs=(8, 16))}
    assert set(plan.timings_us) == want_keys
    assert all(t > 0 for t in plan.timings_us.values())
    assert plan.batch == 16              # max of the sweep
    assert built is not None


def test_autotune_ignored_off_the_pallas_fused_path():
    layers = _random_stack(*STACK, seed=17)
    eng = engine.compile_network(layers, in_features=STACK[0][0],
                                 fused=False, autotune=True)
    assert eng.plan.source == "heuristic" and eng.layout == "per_layer"


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------


def test_autotuned_plan_round_trips_with_zero_search(tmp_path):
    widths, fan_ins, bws = STACK
    layers = _random_stack(widths, fan_ins, bws, seed=23)
    codes = _codes(widths[0], bws[0], 19, seed=2)
    eng = engine.compile_network(layers, in_features=widths[0],
                                 autotune=True, block_b=16,
                                 autotune_block_bs=(8, 16))
    live = np.asarray(eng(codes))

    path = os.path.join(tmp_path, "tuned.npz")
    eng.save(path)
    runs0 = engine.compile_runs()
    eng2 = engine.load(path)
    # load replays the persisted decision: no compiler run, no timing
    # sweep — the plan object (winner, source, table) is equal, not
    # re-derived
    assert engine.compile_runs() == runs0
    assert eng2.plan == eng.plan
    assert eng2.plan.source == "autotune"
    assert eng2.plan.timings_us == eng.plan.timings_us
    assert eng2.block_b == eng.plan.block_b
    np.testing.assert_array_equal(np.asarray(eng2(codes)), live)


# ---------------------------------------------------------------------------
# compat
# ---------------------------------------------------------------------------


def test_format1_artifact_loads_with_synthesized_plan(tmp_path):
    """A pre-autotune artifact (format 1: the plan record is a bare
    FusedPlan dict) must load bit-exact, with the default plan
    synthesized around the stored costing."""
    from repro.checkpoint.ckpt import load_arrays, save_arrays

    widths, fan_ins, bws = STACK
    layers = _random_stack(widths, fan_ins, bws, seed=31)
    codes = _codes(widths[0], bws[0], 15, seed=3)
    eng = engine.compile_network(layers, in_features=widths[0])
    live = np.asarray(eng(codes))

    path = os.path.join(tmp_path, "v1.npz")
    eng.save(path)
    arrays, meta = load_arrays(path)
    meta["format"] = 1
    meta["plan"] = eng.plan.variant.cost.as_dict()   # the old record
    save_arrays(path, arrays, meta)

    eng2 = engine.load(path)
    assert eng2.plan.source == "synthesized"
    assert isinstance(eng2.plan, ExecutionPlan)
    assert eng2.plan.timings_us == {}               # nothing was timed
    # the synthesized plan reconstructs the original decision exactly
    assert eng2.plan.variant.cost == FusedPlan.from_dict(meta["plan"])
    assert (eng2.plan.layout, eng2.plan.block_b) == (
        eng.layout, eng.block_b)
    np.testing.assert_array_equal(np.asarray(eng2(codes)), live)


def test_load_rejects_newer_format(tmp_path):
    from repro.checkpoint.ckpt import load_arrays, save_arrays

    layers = _random_stack((8, 6, 4), (2, 2), (2, 2), seed=9)
    eng = engine.compile_network(layers, in_features=8)
    path = os.path.join(tmp_path, "future.npz")
    eng.save(path)
    arrays, meta = load_arrays(path)
    meta["format"] = engine.engine.FORMAT_VERSION + 1
    save_arrays(path, arrays, meta)
    with pytest.raises(ValueError, match="format"):
        engine.load(path)


def test_execution_plan_compat_surface():
    """The ExecutionPlan exposes the fields callers read off the old bare
    FusedPlan (layout/block_b/pack + costing passthrough)."""
    layers = _random_stack((8, 6, 4), (2, 2), (2, 2), seed=9)
    cost = fused_plan(layers)
    plan = ExecutionPlan.from_fused(cost, "uniform", 32)
    assert (plan.layout, plan.block_b, plan.pack) == (
        "uniform", 32, cost.pack)
    assert plan.fused is cost.fused and plan.reason == cost.reason
    assert plan.slab_bytes == cost.slab_bytes
    assert ExecutionPlan.from_dict(plan.as_dict()) == plan
