"""CI perf-regression gate: the bench-vs-baseline comparison logic.

Proves the gate *demonstrably fails* on an injected regression (a
temporarily inflated baseline standing in for "the numbers got worse") and
passes on the real numbers — without running the bench itself.  The gate
lives in benchmarks/kernel_bench.py (``--baseline`` /
``--update-baseline``); CI's bench-smoke job runs it on every push/PR.
"""

import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks.kernel_bench import (BASELINE_PATH,  # noqa: E402
                                     baseline_from_payload,
                                     check_against_baseline)
from benchmarks.run_record import (build_record, record_hash,  # noqa: E402
                                   spec_hash, write_run_record)


def _payload(speedup=2.5, l2_pct=17.2, l2_bytes=53912, l3_pct=17.2,
             l3_bytes=37504, l3_bits_saved=105, l3_mixed_bytes=43228,
             l3_mixed_speedup=2.2, l3_dedup_saved=660,
             sy_covered=159, sy_fallback=0, sy_lit_pct=81.6,
             sy_bound_ratio=1.17, mode="smoke", backend="cpu",
             retraces=0, compiler_runs=0, artifact_bytes=37504,
             serving_speedup=50.0, tier_retraces=0, tier_compiler_runs=0,
             tier_qps=1000.0, tier_p99_ms=8.0, tier_occupancy=0.75,
             tier_obs=None, ing_retraces=0, ing_compiler_runs=0,
             ing_goodput_ratio=0.3, ing_rejection_rate=0.5,
             at_compiler_runs=0, at_n_variants=10, at_speedup=1.0):
    """Bench-JSON shape with only the gated quantities filled in."""
    if tier_obs is None:
        tier_obs = {"compiler_runs_delta": 0, "memo_hits_delta": 0,
                    "memo_misses_delta": 0}
    return {
        "mode": mode,
        "backend": backend,
        "fused_speedup": speedup,
        "compile": {
            "slab_reduction_pct": l2_pct,
            "stats": {"table_bytes_after": l2_bytes},
            "level3": {
                "slab_reduction_pct": l3_pct,
                "stats": {"table_bytes_after": l3_bytes,
                          "bits_saved": l3_bits_saved},
                "mixed_slab_bytes": l3_mixed_bytes,
                "mixed_fused_speedup": l3_mixed_speedup,
                "dedup_entries_saved": l3_dedup_saved,
            },
        },
        "synth": {
            "covered_neurons": sy_covered,
            "fallback_neurons": sy_fallback,
            "literal_reduction_pct": sy_lit_pct,
            "bound_over_measured": sy_bound_ratio,
        },
        "serving": {
            "retraces_after_warmup": retraces,
            "compiler_runs_after_warmup": compiler_runs,
            "artifact_table_slab_bytes": artifact_bytes,
            "serving_speedup": serving_speedup,
        },
        "serving_tier": {
            "retraces_after_warmup": tier_retraces,
            "compiler_runs_after_warmup": tier_compiler_runs,
            "qps": tier_qps,
            "p99_ms": tier_p99_ms,
            "batch_occupancy": tier_occupancy,
            "obs": tier_obs,
        },
        "ingress": {
            "retraces_after_warmup": ing_retraces,
            "compiler_runs_after_warmup": ing_compiler_runs,
            "overload_goodput_ratio": ing_goodput_ratio,
            "overload_rejection_rate": ing_rejection_rate,
        },
        "autotune": {
            "compiler_runs_after_warmup": at_compiler_runs,
            "n_variants": at_n_variants,
            "speedup_vs_default": at_speedup,
        },
    }


def test_gate_passes_on_own_numbers():
    payload = _payload()
    assert check_against_baseline(payload,
                                  baseline_from_payload(payload)) == []


def test_gate_allows_timing_noise_within_tolerance():
    # 2.3x vs a 3.0x baseline is inside the 25% interpret-mode tolerance
    baseline = baseline_from_payload(_payload(speedup=3.0))
    assert check_against_baseline(_payload(speedup=2.3), baseline) == []


def test_gate_fails_on_injected_speedup_regression():
    # inflating the baseline injects a regression: 2.5x measured vs a 4.0x
    # baseline is below the 3.0x floor -> the gate must trip
    baseline = baseline_from_payload(_payload(speedup=4.0))
    failures = check_against_baseline(_payload(speedup=2.5), baseline)
    assert any("fused_speedup" in f for f in failures), failures


def test_gate_fails_on_table_bytes_regression():
    # level-3 table bytes ballooning back to the level-2 figure must trip
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(l3_bytes=53912), baseline)
    assert any("level-3 table_bytes_after" in f for f in failures), failures


def test_gate_fails_when_reencoding_stops_firing():
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(l3_bits_saved=0), baseline)
    assert any("bits_saved" in f for f in failures), failures


def test_gate_fails_on_mixed_slab_regression():
    # the compact mixed slab creeping back toward the padded uniform
    # figure (a lowering/builder regression) must trip the gate
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(l3_mixed_bytes=83316),
                                      baseline)
    assert any("mixed_slab_bytes" in f for f in failures), failures


def test_gate_fails_on_mixed_speedup_regression():
    # the mixed timing ratio carries a wide 50% interpret-mode tolerance
    # (the byte ceiling is the sharp gate); a collapse below half the
    # baseline must still trip
    baseline = baseline_from_payload(_payload(l3_mixed_speedup=5.0))
    failures = check_against_baseline(_payload(l3_mixed_speedup=2.0),
                                      baseline)
    assert any("mixed_fused_speedup" in f for f in failures), failures
    assert check_against_baseline(_payload(l3_mixed_speedup=2.6),
                                  baseline) == []


def test_gate_tolerates_pre_mixed_baseline():
    # a baseline recorded before the mixed-width fields existed must not
    # fail the gate on the new quantities
    baseline = baseline_from_payload(_payload())
    del baseline["compile"]["level3"]["mixed_slab_bytes"]
    del baseline["compile"]["level3"]["mixed_fused_speedup"]
    assert check_against_baseline(_payload(), baseline) == []


def test_gate_fails_when_slab_dedup_stops_sharing():
    # the row-dedup entry count is deterministic on the generated stack:
    # the builder silently ceasing to share (or over-sharing) is a
    # behavior change, gated by equality
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(l3_dedup_saved=0), baseline)
    assert any("dedup_entries_saved" in f for f in failures), failures


def test_gate_tolerates_pre_dedup_baseline():
    baseline = baseline_from_payload(_payload())
    del baseline["compile"]["level3"]["dedup_entries_saved"]
    assert check_against_baseline(_payload(), baseline) == []


def test_gate_fails_on_synth_coverage_change():
    # a neuron falling out of the minimization budget (or a phantom
    # neuron appearing) is sharp — the generated stack is deterministic
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(
        _payload(sy_covered=158, sy_fallback=1), baseline)
    assert any("synth covered_neurons" in f for f in failures), failures
    assert any("synth fallback_neurons" in f for f in failures), failures


def test_gate_fails_on_synth_reduction_collapse():
    # the literal-reduction floor is additive percentage points: small
    # heuristic drift passes, losing the minimization win trips
    baseline = baseline_from_payload(_payload(sy_lit_pct=81.6))
    assert check_against_baseline(_payload(sy_lit_pct=80.1),
                                  baseline) == []
    failures = check_against_baseline(_payload(sy_lit_pct=60.0), baseline)
    assert any("literal_reduction_pct" in f for f in failures), failures


def test_gate_fails_when_measured_cost_exceeds_bound():
    # the ISSUE-10 acceptance shape: the measured k-LUT estimate must
    # beat the worst-case bound (ratio > 1), regardless of the baseline
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(sy_bound_ratio=0.95),
                                      baseline)
    assert any("bound_over_measured" in f for f in failures), failures


def test_gate_tolerates_pre_synth_baseline():
    # a baseline recorded before the synth section existed must not fail
    # the gate on the new quantities
    baseline = baseline_from_payload(_payload())
    del baseline["synth"]
    assert check_against_baseline(_payload(), baseline) == []


def test_gate_fails_on_serving_retrace_or_recompile():
    # the compile-once contract is sharp: a single steady-state re-trace
    # or compiler re-run must trip the gate, no tolerance
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(retraces=1), baseline)
    assert any("retraces_after_warmup" in f for f in failures), failures
    failures = check_against_baseline(_payload(compiler_runs=2), baseline)
    assert any("compiler_runs_after_warmup" in f
               for f in failures), failures


def test_gate_fails_on_artifact_slab_regression():
    # the artifact's table slab creeping above its byte-exact baseline
    # (e.g. the engine losing the mixed layout) must trip the ceiling
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(artifact_bytes=98304),
                                      baseline)
    assert any("artifact_table_slab_bytes" in f for f in failures), failures


def test_gate_serving_speedup_timing_tolerance():
    # the serving ratio carries the wide 50% interpret tolerance: drift
    # passes, collapse trips
    baseline = baseline_from_payload(_payload(serving_speedup=1000.0))
    assert check_against_baseline(_payload(serving_speedup=600.0),
                                  baseline) == []
    failures = check_against_baseline(_payload(serving_speedup=400.0),
                                      baseline)
    assert any("serving_speedup" in f for f in failures), failures


def test_gate_tolerates_pre_engine_baseline():
    # a baseline recorded before the serving section existed must not
    # fail the gate on the new quantities
    baseline = baseline_from_payload(_payload())
    del baseline["serving"]
    assert check_against_baseline(_payload(), baseline) == []


def test_gate_fails_on_tier_retrace_or_recompile():
    # the micro-batching tier inherits the sharp compile-once contract:
    # coalescing/padding must add zero traces and zero compiler runs
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(tier_retraces=1), baseline)
    assert any("serving_tier retraces_after_warmup" in f
               for f in failures), failures
    failures = check_against_baseline(_payload(tier_compiler_runs=3),
                                      baseline)
    assert any("serving_tier compiler_runs_after_warmup" in f
               for f in failures), failures


def test_gate_tier_timing_collapse_only():
    # QPS / p99 / occupancy are closed-loop host timings: drift within the
    # wide tolerance passes, a collapse (QPS halved, p99 doubled,
    # occupancy halved) trips
    baseline = baseline_from_payload(
        _payload(tier_qps=1000.0, tier_p99_ms=8.0, tier_occupancy=0.8))
    noisy = _payload(tier_qps=600.0, tier_p99_ms=14.0, tier_occupancy=0.5)
    assert check_against_baseline(noisy, baseline) == []
    failures = check_against_baseline(_payload(tier_qps=400.0), baseline)
    assert any("serving_tier qps" in f for f in failures), failures
    failures = check_against_baseline(_payload(tier_p99_ms=20.0), baseline)
    assert any("serving_tier p99_ms" in f for f in failures), failures
    failures = check_against_baseline(_payload(tier_occupancy=0.3),
                                      baseline)
    assert any("serving_tier batch_occupancy" in f
               for f in failures), failures


def test_gate_fails_on_tier_obs_counter_drift():
    # the registry-observed engine deltas across the closed-loop run are
    # deterministic (all 0): any drift — a compiler run, memo traffic —
    # is a real behavior change and trips the equality gate
    baseline = baseline_from_payload(_payload())
    for fld in ("compiler_runs_delta", "memo_hits_delta",
                "memo_misses_delta"):
        bad = dict(compiler_runs_delta=0, memo_hits_delta=0,
                   memo_misses_delta=0)
        bad[fld] = 1
        failures = check_against_baseline(_payload(tier_obs=bad), baseline)
        assert any(f"obs.{fld}" in f for f in failures), (fld, failures)


def test_gate_tolerates_pre_obs_baseline():
    # a baseline recorded before the obs counter deltas existed must not
    # fail the gate on the new quantities
    baseline = baseline_from_payload(_payload())
    del baseline["serving_tier"]["obs"]
    assert check_against_baseline(_payload(), baseline) == []


def test_gate_tolerates_pre_tier_baseline():
    # a baseline recorded before the serving_tier section existed must
    # not fail the gate on the new quantities
    baseline = baseline_from_payload(_payload())
    del baseline["serving_tier"]
    assert check_against_baseline(_payload(), baseline) == []


def test_gate_fails_on_ingress_retrace_or_recompile():
    # the HTTP ingress path (decode -> quota -> tier) inherits the sharp
    # compile-once contract end to end
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(ing_retraces=1), baseline)
    assert any("ingress retraces_after_warmup" in f
               for f in failures), failures
    failures = check_against_baseline(_payload(ing_compiler_runs=2),
                                      baseline)
    assert any("ingress compiler_runs_after_warmup" in f
               for f in failures), failures


def test_gate_ingress_overload_collapse_only():
    # the overload ratios are open-loop host timings with the widest
    # tolerance in the file (75%): drift passes, a collapse — goodput
    # falling away under overload, or the server ceasing to shed past
    # capacity — trips
    baseline = baseline_from_payload(
        _payload(ing_goodput_ratio=0.4, ing_rejection_rate=0.6))
    noisy = _payload(ing_goodput_ratio=0.15, ing_rejection_rate=0.2)
    assert check_against_baseline(noisy, baseline) == []
    failures = check_against_baseline(_payload(ing_goodput_ratio=0.05),
                                      baseline)
    assert any("overload_goodput_ratio" in f for f in failures), failures
    failures = check_against_baseline(_payload(ing_rejection_rate=0.0),
                                      baseline)
    assert any("overload_rejection_rate" in f for f in failures), failures


def test_gate_tolerates_pre_ingress_baseline():
    # a baseline recorded before the ingress section existed must not
    # fail the gate on the new quantities
    baseline = baseline_from_payload(_payload())
    del baseline["ingress"]
    assert check_against_baseline(_payload(), baseline) == []


def test_gate_fails_on_autotune_compiler_run_or_variant_loss():
    # the variant search must reuse the already-compiled result (sharp
    # equality), and the enumerated space is deterministic for a fixed
    # sweep — a shrunken count means eligible variants went missing
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(at_compiler_runs=1),
                                      baseline)
    assert any("autotune compiler_runs_after_warmup" in f
               for f in failures), failures
    failures = check_against_baseline(_payload(at_n_variants=6), baseline)
    assert any("autotune n_variants" in f for f in failures), failures


def test_gate_autotune_selection_collapse_only():
    # speedup_vs_default is >= 1.0 by construction (the search minimizes
    # over a set containing the default); noise above the baseline
    # passes, a collapse below the wide floor trips
    baseline = baseline_from_payload(_payload(at_speedup=1.0))
    assert check_against_baseline(_payload(at_speedup=1.4), baseline) == []
    assert check_against_baseline(_payload(at_speedup=0.8), baseline) == []
    failures = check_against_baseline(_payload(at_speedup=0.3), baseline)
    assert any("autotune speedup_vs_default" in f
               for f in failures), failures


def test_gate_tolerates_pre_autotune_baseline():
    # a baseline recorded before the autotune section existed must not
    # fail the gate on the new quantities
    baseline = baseline_from_payload(_payload())
    del baseline["autotune"]
    assert check_against_baseline(_payload(), baseline) == []


def test_gate_refuses_protocol_mismatch():
    # a full-mode or TPU run is not comparable with the smoke/cpu baseline
    baseline = baseline_from_payload(_payload())
    failures = check_against_baseline(_payload(mode="full"), baseline)
    assert any("mode mismatch" in f for f in failures), failures
    failures = check_against_baseline(_payload(backend="tpu"), baseline)
    assert any("backend mismatch" in f for f in failures), failures


def test_gate_fails_on_slab_reduction_regression():
    baseline = baseline_from_payload(_payload(l2_pct=25.0))
    failures = check_against_baseline(_payload(), baseline)
    assert any("slab_reduction_pct" in f for f in failures), failures


def test_gate_ignores_small_deterministic_drift():
    # cross-version float drift in table generation stays within tolerance
    baseline = baseline_from_payload(_payload())
    payload = _payload(l2_pct=16.9, l2_bytes=53912 + 500,
                       l3_bytes=37504 + 500)
    assert check_against_baseline(payload, baseline) == []


def test_run_record_content_addressed(tmp_path):
    """Identical (spec, payload, rev, timestamp) -> identical record file;
    any spec change moves the spec hash; records never get rewritten."""
    spec = {"benchmark": "kernel_bench", "mode": "smoke", "backend": "cpu"}
    payload = _payload()
    p1 = write_run_record(spec, payload, {"m": 1}, out_dir=str(tmp_path),
                          rev="abc123", timestamp=1000.0)
    p2 = write_run_record(spec, payload, {"m": 1}, out_dir=str(tmp_path),
                          rev="abc123", timestamp=1000.0)
    assert p1 == p2 and len(list(tmp_path.glob("*.json"))) == 1
    with open(p1) as f:
        rec = json.load(f)
    assert rec["schema_version"] == 1
    assert rec["spec"] == spec
    assert rec["spec_hash"] == spec_hash(spec)
    assert rec["git_rev"] == "abc123"
    assert rec["payload"]["mode"] == "smoke"
    assert rec["metrics"] == {"m": 1}
    # the filename is the content address
    assert os.path.basename(p1) == record_hash(rec)[:16] + ".json"
    # a different timestamp (a new run) lands a second file
    p3 = write_run_record(spec, payload, {"m": 1}, out_dir=str(tmp_path),
                          rev="abc123", timestamp=2000.0)
    assert p3 != p1 and len(list(tmp_path.glob("*.json"))) == 2
    # spec identity is stable against key order but not content
    assert spec_hash({"mode": "smoke", "backend": "cpu",
                      "benchmark": "kernel_bench"}) == rec["spec_hash"]
    assert spec_hash({**spec, "mode": "full"}) != rec["spec_hash"]
    assert (build_record(spec, payload, rev="abc123", timestamp=1000.0)
            ["metrics"] == {})


def test_committed_baseline_is_well_formed():
    """The checked-in baseline gates every quantity the CI job reads."""
    with open(BASELINE_PATH) as f:
        baseline = json.load(f)
    assert baseline["fused_speedup"] > 1.0
    assert baseline["mode"] == "smoke" and baseline["backend"] == "cpu"
    comp = baseline["compile"]
    assert comp["table_bytes_after"] > comp["level3"]["table_bytes_after"]
    assert comp["level3"]["bits_saved"] > 0
    # the ISSUE-4 acceptance shape: the mixed fused slab (tables + the
    # three small metadata slabs) sits near the exact level-3 packed
    # table bytes, far below the level-2 uniform figure, and the mixed
    # kernel beats the per-layer path
    l3 = comp["level3"]
    assert l3["mixed_slab_bytes"] < 1.25 * l3["table_bytes_after"]
    assert l3["mixed_slab_bytes"] < comp["table_bytes_after"]
    assert l3["mixed_fused_speedup"] > 1.0
    # slab row-dedup shares at least one entry on the generated stack
    assert l3["dedup_entries_saved"] > 0
    # the ISSUE-10 acceptance shape: every neuron minimized within
    # budget, a real literal reduction, and the measured k-LUT estimate
    # strictly below the worst-case bound
    sy = baseline["synth"]
    assert sy["covered_neurons"] > 0 and sy["fallback_neurons"] == 0
    assert sy["literal_reduction_pct"] > 0.0
    assert sy["bound_over_measured"] > 1.0
    # the compile-once serving contract: zero steady-state re-traces and
    # compiler re-runs, artifact table slab at (or, with row-dedup,
    # below) the level-3 byte figure
    srv = baseline["serving"]
    assert srv["retraces_after_warmup"] == 0
    assert srv["compiler_runs_after_warmup"] == 0
    assert srv["artifact_table_slab_bytes"] <= l3["table_bytes_after"]
    assert srv["serving_speedup"] > 1.0
    # the micro-batching tier: same sharp compile-once counters, sane
    # closed-loop throughput/latency/occupancy numbers
    tier = baseline["serving_tier"]
    assert tier["retraces_after_warmup"] == 0
    assert tier["compiler_runs_after_warmup"] == 0
    assert tier["qps"] > 0 and tier["p99_ms"] > 0
    assert 0.0 < tier["batch_occupancy"] <= 1.0
    # the registry-observed engine deltas are part of the compile-once
    # story: all must be pinned at exactly 0
    assert tier["obs"] == {"compiler_runs_delta": 0, "memo_hits_delta": 0,
                           "memo_misses_delta": 0}
    # the ingress section: sharp counters through the HTTP path, and
    # overload behavior that actually sheds while keeping goodput
    ing = baseline["ingress"]
    assert ing["retraces_after_warmup"] == 0
    assert ing["compiler_runs_after_warmup"] == 0
    assert 0.0 < ing["overload_goodput_ratio"] <= 1.0
    assert 0.0 < ing["overload_rejection_rate"] < 1.0
    # the autotune section: zero compiler runs during the search, a
    # deterministic variant count, and a selection no slower than the
    # heuristic default (>= 1.0 by construction)
    at = baseline["autotune"]
    assert at["compiler_runs_after_warmup"] == 0
    assert at["n_variants"] > 1
    assert at["speedup_vs_default"] >= 1.0
    # a run reproducing exactly the baseline numbers passes the gate
    payload = _payload(
        speedup=baseline["fused_speedup"],
        l2_pct=comp["slab_reduction_pct"],
        l2_bytes=comp["table_bytes_after"],
        l3_pct=comp["level3"]["slab_reduction_pct"],
        l3_bytes=comp["level3"]["table_bytes_after"],
        l3_bits_saved=comp["level3"]["bits_saved"],
        l3_mixed_bytes=l3["mixed_slab_bytes"],
        l3_mixed_speedup=l3["mixed_fused_speedup"],
        l3_dedup_saved=l3["dedup_entries_saved"],
        sy_covered=sy["covered_neurons"],
        sy_fallback=sy["fallback_neurons"],
        sy_lit_pct=sy["literal_reduction_pct"],
        sy_bound_ratio=sy["bound_over_measured"],
        retraces=srv["retraces_after_warmup"],
        compiler_runs=srv["compiler_runs_after_warmup"],
        artifact_bytes=srv["artifact_table_slab_bytes"],
        serving_speedup=srv["serving_speedup"],
        tier_retraces=tier["retraces_after_warmup"],
        tier_compiler_runs=tier["compiler_runs_after_warmup"],
        tier_qps=tier["qps"], tier_p99_ms=tier["p99_ms"],
        tier_occupancy=tier["batch_occupancy"], tier_obs=dict(tier["obs"]),
        ing_retraces=ing["retraces_after_warmup"],
        ing_compiler_runs=ing["compiler_runs_after_warmup"],
        ing_goodput_ratio=ing["overload_goodput_ratio"],
        ing_rejection_rate=ing["overload_rejection_rate"],
        at_compiler_runs=at["compiler_runs_after_warmup"],
        at_n_variants=at["n_variants"],
        at_speedup=at["speedup_vs_default"])
    assert check_against_baseline(payload, baseline) == []


# ---------------------------------------------------------------------------
# tools/promote_baseline.py: the reviewable baseline-refresh path
# ---------------------------------------------------------------------------


def test_promote_diff_classifies_sharp_vs_wide():
    from tools.promote_baseline import diff_baselines

    committed = baseline_from_payload(_payload())
    candidate = baseline_from_payload(
        _payload(speedup=3.0,            # wide: timing ratio
                 compiler_runs=1,        # sharp: compile-once counter
                 at_n_variants=12))      # sharp: variant count
    rows = {r["path"]: r for r in diff_baselines(committed, candidate)}
    assert rows["fused_speedup"]["sharp"] is False
    assert rows["serving.compiler_runs_after_warmup"]["sharp"] is True
    assert rows["autotune.n_variants"]["sharp"] is True
    # obs counters are sharp wholesale
    committed["serving_tier"]["obs"]["memo_hits_delta"] = 5
    rows = {r["path"]: r
            for r in diff_baselines(committed,
                                    baseline_from_payload(_payload()))}
    assert rows["serving_tier.obs.memo_hits_delta"]["sharp"] is True
    # added/removed keys are always sharp (the gate's shape changed)
    del committed["autotune"]
    rows = diff_baselines(committed, baseline_from_payload(_payload()))
    assert all(r["sharp"] for r in rows if r["kind"] == "added")
    # identical baselines diff empty
    same = baseline_from_payload(_payload())
    assert diff_baselines(same, json.loads(json.dumps(same))) == []


def test_promote_refuses_sharp_changes_without_allow(tmp_path):
    from tools.promote_baseline import main as promote

    committed = tmp_path / "baseline.json"
    committed.write_text(json.dumps(baseline_from_payload(_payload())))
    bad = tmp_path / "payload.json"
    bad.write_text(json.dumps(_payload(at_compiler_runs=1)))
    assert promote([str(bad), "--baseline", str(committed),
                    "--write"]) == 1
    # refused: the committed file is untouched
    assert (json.loads(committed.read_text())["autotune"]
            ["compiler_runs_after_warmup"]) == 0
    # --allow overrides after review
    assert promote([str(bad), "--baseline", str(committed), "--write",
                    "--allow"]) == 0
    assert (json.loads(committed.read_text())["autotune"]
            ["compiler_runs_after_warmup"]) == 1


def test_promote_wide_drift_passes_and_dry_run_never_writes(tmp_path):
    from tools.promote_baseline import main as promote

    committed = tmp_path / "baseline.json"
    original = baseline_from_payload(_payload(speedup=2.5))
    committed.write_text(json.dumps(original))
    drift = tmp_path / "payload.json"
    drift.write_text(json.dumps(_payload(speedup=3.1)))
    # dry run: exit 0 on wide-only drift, committed file untouched
    assert promote([str(drift), "--baseline", str(committed)]) == 0
    assert json.loads(committed.read_text()) == original
    # --write promotes wide drift freely
    assert promote([str(drift), "--baseline", str(committed),
                    "--write"]) == 0
    assert json.loads(committed.read_text())["fused_speedup"] == 3.1


def test_promote_missing_committed_baseline_is_all_sharp(tmp_path):
    from tools.promote_baseline import main as promote

    payload = tmp_path / "payload.json"
    payload.write_text(json.dumps(_payload()))
    missing = tmp_path / "nope" / "baseline.json"
    # everything is new -> sharp -> refused without --allow
    assert promote([str(payload), "--baseline", str(missing)]) == 1
    assert promote([str(payload), "--baseline", str(missing), "--write",
                    "--allow"]) == 0
    assert (json.loads(missing.read_text())["benchmark"]
            == "kernel_bench_smoke_baseline")
