"""Checkpointing + fault-tolerant runtime tests (restart, NaN guard,
elastic restore, keep-k, async, data determinism)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import TokenStream, jet_substructure_data, mnist_like_data
from repro.runtime import TrainLoop, TrainLoopCfg


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 4)),
            "nested": {"b": jnp.arange(6.0), "step": jnp.asarray(3)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 7, t)
    assert latest_step(str(tmp_path)) == 7
    got = restore_checkpoint(str(tmp_path), 7, jax.tree.map(jnp.zeros_like,
                                                            t))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, got)


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree(s))
    steps = sorted(int(f[5:13]) for f in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, _tree())
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_restore_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((3, 3))})


def test_elastic_restore_resharding_hook(tmp_path):
    """sharding_fn is called per leaf — the elastic-scale entry point."""
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, t)
    calls = []

    def sharding_fn(path, arr):
        calls.append((path, arr.shape))
        return None

    restore_checkpoint(str(tmp_path), 1, t, sharding_fn)
    assert calls and calls[0][1] == (4, 4)


# ---------------------------------------------------------------------------
# TrainLoop
# ---------------------------------------------------------------------------

def _sgd_loop(tmp_path, n_steps=10, ckpt_every=4, poison_step=None):
    def step_fn(state, batch):
        loss = jnp.sum((state["w"] - batch["target"]) ** 2)
        if poison_step is not None and batch["step"] == poison_step:
            loss = loss * jnp.nan
        new_w = state["w"] - 0.1 * 2 * (state["w"] - batch["target"])
        return {"w": new_w}, loss

    def batches(step):
        return {"target": jnp.ones((3,)), "step": step}

    loop = TrainLoop(TrainLoopCfg(ckpt_dir=str(tmp_path),
                                  ckpt_every=ckpt_every, async_save=False),
                     step_fn, {"w": jnp.zeros((3,))})
    return loop, batches


def test_loop_runs_and_checkpoints(tmp_path):
    loop, batches = _sgd_loop(tmp_path)
    loop.run(batches, 10)
    assert latest_step(str(tmp_path)) == 8
    assert len(loop.metrics) == 10


def test_loop_restart_resumes_exactly(tmp_path):
    loop, batches = _sgd_loop(tmp_path)
    loop.run(batches, 10)
    w_ref = np.asarray(loop.state["w"])

    # Simulate a node failure at step 10 -> new process restores at 8
    loop2, batches2 = _sgd_loop(tmp_path)
    assert loop2.try_restore()
    assert loop2.step == 8
    loop2.run(batches2, 10)
    np.testing.assert_allclose(np.asarray(loop2.state["w"]), w_ref,
                               rtol=1e-6)


def test_loop_nan_guard_skips_bad_step(tmp_path):
    loop, batches = _sgd_loop(tmp_path, poison_step=3)
    loop.run(batches, 6)
    assert len(loop.metrics) == 5            # step 3 skipped
    steps = [s for s, _ in loop.metrics]
    assert 3 not in steps
    assert np.isfinite(np.asarray(loop.state["w"])).all()


def test_loop_aborts_after_max_bad_steps(tmp_path):
    def step_fn(state, batch):
        return state, jnp.nan

    loop = TrainLoop(TrainLoopCfg(ckpt_dir=str(tmp_path), max_bad_steps=3,
                                  async_save=False),
                     step_fn, {"w": jnp.zeros(1)})
    with pytest.raises(FloatingPointError):
        loop.run(lambda s: {}, 100)


# ---------------------------------------------------------------------------
# Data pipeline determinism / host sharding
# ---------------------------------------------------------------------------

def test_token_stream_deterministic_and_host_sharded():
    a = TokenStream(vocab=100, seq_len=16, global_batch=8, seed=1,
                    n_hosts=2, host=0)
    b = TokenStream(vocab=100, seq_len=16, global_batch=8, seed=1,
                    n_hosts=2, host=1)
    a2 = TokenStream(vocab=100, seq_len=16, global_batch=8, seed=1,
                     n_hosts=2, host=0)
    ba, bb = a.batch(5), b.batch(5)
    np.testing.assert_array_equal(ba["tokens"], a2.batch(5)["tokens"])
    assert not np.array_equal(ba["tokens"], bb["tokens"])
    assert ba["tokens"].shape == (4, 16)
    assert ba["tokens"].max() < 100
    # labels are next-token shifted
    np.testing.assert_array_equal(ba["labels"][:, :-1], ba["tokens"][:, 1:])


def test_jsc_data_learnable_and_shaped():
    x, y = jet_substructure_data(512, seed=0)
    assert x.shape == (512, 16) and y.shape == (512,)
    assert set(np.unique(y)) <= set(range(5))
    x2, _ = jet_substructure_data(512, seed=0)
    np.testing.assert_array_equal(x, x2)


def test_mnist_like_shapes():
    x, y = mnist_like_data(64, seed=3)
    assert x.shape == (64, 28, 28, 1)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)) <= set(range(10))
