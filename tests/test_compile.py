"""Truth-table compiler: pass-by-pass units + end-to-end bit-exactness.

The pipeline's contract: ``compile.optimize`` output computes the same
function as the raw netlist on every reachable input — per-layer jnp,
fused Pallas kernel, and the Verilog interpreter all included.  Units pin
each pass's mechanism on hand-built tables with known structure; the
hypothesis sweep proves the contract on generated LogicNets end-to-end.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st  # real when installed

from repro import compile as C
from repro.core import logicnet as LN
from repro.core.lut_cost import netlist_lut_cost
from repro.core.netlist import build_netlist
from repro.core.table_infer import network_table_forward
from repro.core.truth_table import LayerTruthTable
from repro.core.verilog import evaluate_verilog, generate_verilog
from repro.kernels.ops import lut_network


def _tt(table, indices, bw_in, bw_out):
    return LayerTruthTable(np.asarray(table, np.int32),
                           np.asarray(indices, np.int32), bw_in, bw_out)


def _all_input_codes(n_features, bw):
    words = np.arange((2 ** bw) ** n_features)
    return np.stack([(words >> (bw * k)) & (2 ** bw - 1)
                     for k in range(n_features)], axis=1).astype(np.int32)


def _assert_same_function(raw_tables, res, n_features, bw):
    """Exhaustive equality over the full (reachable) input domain."""
    codes_in = jnp.asarray(_all_input_codes(n_features, bw))
    want = np.asarray(network_table_forward(raw_tables, codes_in))
    got = np.asarray(network_table_forward(res.tables, codes_in))
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        C.forward_codes(res.cnet, np.asarray(codes_in)), want)


# ---------------------------------------------------------------------------
# pass units on hand-built stacks
# ---------------------------------------------------------------------------

def test_level0_is_identity():
    t0 = _tt([[0, 1, 1, 0], [1, 1, 0, 0]], [[0], [1]], 2, 1)
    res = C.optimize([t0], level=0, in_features=2)
    np.testing.assert_array_equal(res.tables[0].table, t0.table)
    np.testing.assert_array_equal(res.tables[0].indices, t0.indices)
    assert res.stats.rounds == 0
    assert res.stats.table_bytes_after == res.stats.table_bytes_before
    # analysis still runs: reachability stats are reported, nothing rewritten
    assert [p.name for p in res.stats.passes] == ["reachability"]
    assert "reachable_code_counts" in res.stats.passes[0].detail
    assert all(n.reachable is None
               for lay in res.cnet.layers for n in lay.neurons)


def test_reachability_marks_and_canonicalizes_dont_cares():
    # layer 0 (1-bit codes): neuron emits only code 1 (constant)
    t0 = _tt([[1, 1], [0, 1]], [[0], [1]], 1, 1)
    # layer 1 reads both features; entries where feature-0's code is 0 are
    # unreachable don't-cares
    t1 = _tt([[7, 1, 2, 1]], [[0, 1]], 1, 3)
    res = C.optimize([t0, t1], level=1, in_features=2)
    assert res.stats.dont_care_entries == 2      # entries 0 and 2 of t1
    n = res.cnet.layers[1].neurons[0]
    # canonicalized: unreachable column (d0=0) copies the reachable d0=1
    np.testing.assert_array_equal(n.table, [1, 1, 1, 1])
    np.testing.assert_array_equal(n.reachable, [False, True, False, True])
    _assert_same_function([t0, t1], res, 2, 1)


def test_cse_dedups_identical_neurons():
    # neurons 0 and 2 are identical (same fan-in wires, same table)
    t0 = _tt([[0, 1, 1, 0], [1, 0, 0, 1], [0, 1, 1, 0]],
             [[0, 1], [0, 1], [0, 1]], 1, 1)
    t1 = _tt([[0, 1, 1, 1], [1, 0, 0, 1]], [[0, 2], [1, 2]], 1, 1)
    res = C.optimize([t0, t1], level=2, in_features=2)
    merged = sum(p.detail.get("merged", 0) for p in res.stats.passes)
    assert merged == 1
    assert res.cnet.layers[0].out_features == 2  # duplicate DCE'd away
    assert res.stats.neurons_after < res.stats.neurons_before
    _assert_same_function([t0, t1], res, 2, 1)


def test_dead_input_pruning_shrinks_table():
    # neuron ignores element 1 entirely: table depends only on element 0
    tab = [0, 1, 0, 1,   0, 1, 0, 1,   0, 1, 0, 1,   0, 1, 0, 1]
    t0 = _tt([tab], [[0, 1]], 2, 1)
    res = C.optimize([t0], level=2, in_features=2)
    pruned = sum(p.detail.get("pruned_elements", 0)
                 for p in res.stats.passes)
    assert pruned == 1
    n = res.cnet.layers[0].neurons[0]
    assert n.fan_in == 1 and n.n_entries == 4   # 16 -> 4: 2x per bit, 2 bits
    np.testing.assert_array_equal(n.indices, [0])
    _assert_same_function([t0], res, 2, 2)


def test_constant_producer_folds_and_dies():
    # layer-0 neuron 1 is constant; its consumer's element collapses and
    # the producer is left unconsumed -> eliminated, all in one round
    t0 = _tt([[0, 1, 1, 0], [1, 1, 1, 1]], [[0, 1], [0, 1]], 1, 1)
    t1 = _tt([[0, 0, 1, 1], [1, 0, 1, 0]], [[0, 1], [0, 1]], 1, 1)
    res = C.optimize([t0, t1], level=2, in_features=2)
    consts = max(p.detail.get("constants", 0) for p in res.stats.passes)
    assert consts >= 1
    assert res.cnet.layers[0].out_features == 1
    for n in res.cnet.layers[1].neurons:
        assert n.fan_in == 1
        np.testing.assert_array_equal(n.indices, [0])
    _assert_same_function([t0, t1], res, 2, 1)


def test_dead_neuron_chain_eliminated_backwards():
    # layer-1 neuron 1 is never consumed by layer 2; removing it leaves
    # layer-0 neuron 1 (its only supplier) dead too — one backward sweep
    t0 = _tt([[0, 1], [1, 0]], [[0], [1]], 1, 1)
    t1 = _tt([[0, 1], [1, 0]], [[0], [1]], 1, 1)
    t2 = _tt([[0, 1]], [[0]], 1, 1)
    res = C.optimize([t0, t1, t2], level=1, in_features=2)
    assert [lay.out_features for lay in res.cnet.layers] == [1, 1, 1]
    removed = sum(p.detail.get("removed_neurons", 0)
                  for p in res.stats.passes)
    assert removed == 2
    _assert_same_function([t0, t1, t2], res, 2, 1)


def test_final_layer_arity_is_preserved():
    # duplicate + constant neurons in the FINAL layer must all survive:
    # the output bus is the contract
    t0 = _tt([[0, 1, 1, 0], [0, 1, 1, 0], [3, 3, 3, 3]],
             [[0, 1], [0, 1], [0, 1]], 1, 2)
    res = C.optimize([t0], level=3, in_features=2)
    assert res.cnet.layers[-1].out_features == 3
    _assert_same_function([t0], res, 2, 1)


def test_level3_fixpoint_cascades_constants():
    # constant at layer 0 -> after round 1 its consumer becomes constant
    # too -> round 2 collapses the next layer; level 2 (single round)
    # cannot finish the chain
    t0 = _tt([[1, 1], [0, 1]], [[0], [1]], 1, 1)
    t1 = _tt([[0, 0, 0, 1], [0, 1, 1, 1]], [[0, 1], [0, 1]], 1, 1)
    t2 = _tt([[0, 1, 1, 0]], [[0, 1]], 1, 1)
    res3 = C.optimize([t0, t1, t2], level=3, in_features=2)
    assert res3.stats.rounds >= 2
    _assert_same_function([t0, t1, t2], res3, 2, 1)
    res2 = C.optimize([t0, t1, t2], level=2, in_features=2)
    assert res2.stats.table_bytes_after >= res3.stats.table_bytes_after


def test_invalid_level_rejected():
    t0 = _tt([[0, 1]], [[0]], 1, 1)
    with pytest.raises(ValueError, match="level"):
        C.optimize([t0], level=5)
    with pytest.raises(ValueError, match="level"):
        C.optimize([t0], level=-1)


def test_level4_is_synth_alias():
    """level=4 == level=3 + synth: covers attached, stats recorded."""
    t0 = _tt([[0, 1, 1, 0]], [[0, 1]], 1, 1)
    res = C.optimize([t0], level=4, in_features=2)
    assert res.stats.level == 3
    assert res.stats.synth is not None
    assert res.stats.synth["neurons"] == res.stats.synth["covered_neurons"]
    assert any(n.sop is not None
               for layer in res.netlist.layers for n in layer)
    assert any(p.name == "synth" for p in res.stats.passes)
    # and the stats round-trip through the artifact-metadata path
    assert C.CompileStats.from_dict(res.stats.as_dict()).synth == \
        res.stats.synth


# ---------------------------------------------------------------------------
# cross-layer code re-encoding (level 3)
# ---------------------------------------------------------------------------

def test_reencode_narrows_producer_and_consumer():
    # layer-0 neuron emits only codes {2, 5} of its 3-bit container: level 3
    # re-codes the feature to 1 bit (producer emits ranks), and the
    # consumer's table shrinks from 8 entries to 2
    t0 = _tt([[2, 5]], [[0]], 1, 3)
    t1 = _tt([[7, 1, 2, 1, 0, 3, 6, 5]], [[0]], 3, 3)
    res = C.optimize([t0, t1], level=3, in_features=1)
    n0 = res.cnet.layers[0].neurons[0]
    n1 = res.cnet.layers[1].neurons[0]
    assert n0.out_width == 1
    np.testing.assert_array_equal(n0.table, [0, 1])
    assert n1.n_entries == 2
    np.testing.assert_array_equal(n1.table, [2, 3])   # old entries 2 and 5
    assert res.stats.features_recoded == 1
    assert res.stats.bits_saved == 2
    assert res.stats.as_dict()["features_recoded"] == 1
    # compact widths reach the netlist / Verilog target
    nl = res.netlist
    assert nl.layers[0][0].out_bits == 1
    assert nl.layer_in_widths[1] == [1]
    # ... but never the final layer's outputs (the network contract)
    assert res.cnet.layers[-1].neurons[0].out_width is None
    assert res.tables[-1].bw_out == 3
    _assert_same_function([t0, t1], res, 1, 1)


def test_reencode_non_power_of_two_set_keeps_canonical_dont_cares():
    # k=3 reachable codes need 2 bits; compact digit 3 can never arrive and
    # must decode to compact code 0's column (canonical don't-care)
    t0 = _tt([[1, 4, 6, 1]], [[0, 1]], 1, 3)
    t1 = _tt([[7, 1, 2, 1, 0, 3, 6, 5]], [[0]], 3, 3)
    res = C.optimize([t0, t1], level=3, in_features=2)
    n0 = res.cnet.layers[0].neurons[0]
    n1 = res.cnet.layers[1].neurons[0]
    assert n0.out_width == 2
    assert n1.n_entries == 4
    # decoded entries: [old[1], old[4], old[6], old[1] (dont-care copy)]
    np.testing.assert_array_equal(n1.table, [1, 0, 6, 1])
    np.testing.assert_array_equal(n1.reachable, [True, True, True, False])
    _assert_same_function([t0, t1], res, 2, 1)


def test_reencode_single_code_feature_collapses():
    # the "width 0" information-content edge: a feature carrying ONE code,
    # read by a fan_in-1 consumer pruning cannot shrink below one element.
    # Re-encoding clamps it to the 1-bit minimum width and the consumer's
    # table collapses from 8 entries to 2, bit-exactly
    t0 = _tt([[6, 6]], [[0]], 1, 3)
    t1 = _tt([[0, 1, 2, 3, 4, 5, 7, 6]], [[0]], 3, 3)
    res = C.optimize([t0, t1], level=3, in_features=1)
    n0 = res.cnet.layers[0].neurons[0]
    n1 = res.cnet.layers[1].neurons[0]
    assert n0.out_width == 1
    assert n1.n_entries == 2
    assert set(np.asarray(n1.table).tolist()) == {7}
    _assert_same_function([t0, t1], res, 1, 1)


def test_reencode_mixed_width_bus_lowers_to_uniform_tables():
    # one feature narrows to 1 bit, its sibling keeps all 3: the IR table
    # is compact (2^(1+3) entries) while the uniform lowering pads back to
    # the bus's widest feature for the kernels' shift-pack convention
    rng = np.random.default_rng(7)
    tab_narrow = rng.choice([2, 5], size=16).astype(np.int32)
    tab_wide = np.concatenate([np.arange(8), rng.integers(0, 8, 8)]
                              ).astype(np.int32)
    t0 = _tt([tab_narrow, tab_wide], [[0, 1], [0, 1]], 2, 3)
    t1 = _tt([rng.integers(0, 4, 64).astype(np.int32)], [[0, 1]], 3, 2)
    res = C.optimize([t0, t1], level=3, in_features=2)
    widths = [res.cnet.layers[0].out_width_of(j) for j in range(2)]
    assert sorted(widths) == [1, 3], widths
    n1 = res.cnet.layers[1].neurons[0]
    assert n1.n_entries == 1 << 4
    tt1 = res.tables[1]
    assert tt1.bw_in == 3 and tt1.n_entries == 1 << 6
    _assert_same_function([t0, t1], res, 2, 2)


def test_reencoded_netlist_roundtrips_through_optimizer():
    # a re-encoded (mixed-width) netlist lifts back via layer_in_widths and
    # re-optimizes to the same function without growing
    t0 = _tt([[2, 5]], [[0]], 1, 3)
    t1 = _tt([[7, 1, 2, 1, 0, 3, 6, 5], [5, 0, 3, 0, 1, 2, 4, 7]],
             [[0], [0]], 3, 3)
    res = C.optimize([t0, t1], level=3, in_features=1)
    res2 = C.optimize(res.netlist, level=3)
    assert res2.stats.table_bytes_after <= res.stats.table_bytes_after
    _assert_same_function([t0, t1], res2, 1, 1)


# ---------------------------------------------------------------------------
# lowering targets
# ---------------------------------------------------------------------------

def test_lowered_tables_are_uniform_and_padded():
    # one neuron prunes to fan_in 1, the other keeps 2: the lowered layer
    # pads to fan_in 2 and tiles the pruned neuron's table
    tab_prunable = [0, 1] * 2    # ignores element 1
    tab_full = [0, 0, 0, 1]
    t0 = _tt([tab_prunable, tab_full], [[0, 1], [0, 1]], 1, 1)
    res = C.optimize([t0], level=2, in_features=2)
    tt = res.tables[0]
    assert tt.indices.shape == (2, 2)
    assert tt.n_entries == 4 == 1 << (tt.fan_in * tt.bw_in)
    _assert_same_function([t0], res, 2, 1)


def test_netlist_roundtrip_through_compiler():
    # optimize() accepts a Netlist (with layer_bw_in metadata) directly
    t0 = _tt([[0, 1, 1, 0], [0, 1, 1, 0]], [[0, 1], [0, 1]], 1, 1)
    t1 = _tt([[0, 1, 1, 1]], [[0, 1]], 1, 1)
    nl = build_netlist([t0, t1], in_features=2)
    res = C.optimize(nl, level=2)
    assert res.stats.neurons_after <= res.stats.neurons_before
    _assert_same_function([t0, t1], res, 2, 1)
    bad = build_netlist([t0, t1], in_features=2)
    bad.layer_bw_in = None
    with pytest.raises(ValueError, match="layer_bw_in"):
        C.optimize(bad, level=1)


def test_netlist_with_misgrouped_bits_rejected():
    """from_netlist must reject bit groups that straddle features."""
    t0 = _tt([[0, 1, 2, 3] * 4], [[0, 1]], 2, 2)
    nl = build_netlist([t0], in_features=2)
    # bits [2, 5] at bw=2 mixes feature-1 bit 0 with feature-2 bit 1
    nl.layers[0][0].input_bits = [0, 1, 2, 5]
    with pytest.raises(ValueError, match="feature groups"):
        C.optimize(nl, level=1)


def test_optimized_netlist_bytes_and_cost_reported():
    t0 = _tt([[0, 1, 1, 0], [0, 1, 1, 0], [1, 1, 1, 1]],
             [[0, 1], [0, 1], [0, 1]], 1, 1)
    t1 = _tt([[0, 1, 1, 1]], [[0, 1]], 1, 1)
    res = C.optimize([t0, t1], level=2, in_features=2)
    s = res.stats
    assert s.table_bytes_after < s.table_bytes_before
    assert s.lut_cost_after <= s.lut_cost_before
    assert s.table_bytes_after == res.cnet.table_bytes()
    assert s.table_bytes_after == res.netlist.table_bytes()
    assert s.lut_cost_after == netlist_lut_cost(res.netlist)
    d = s.as_dict()
    assert d["passes"] and all("seconds" in p for p in d["passes"])
    assert "->" in C.summarize(s)


def test_optimize_triples_wire_format():
    rng = np.random.default_rng(0)
    idx = np.stack([np.sort(rng.choice(4, 2, replace=False))
                    for _ in range(4)]).astype(np.int32)
    tab = rng.integers(0, 2, (4, 16), dtype=np.int32)
    layers = [(idx, tab, 2)]
    opt = C.optimize_triples(layers, level=2, in_features=4)
    codes_in = jnp.asarray(rng.integers(0, 4, (16, 4), dtype=np.int32))
    want = np.asarray(lut_network(codes_in, layers, fused=False))
    got = np.asarray(lut_network(codes_in, opt, fused=False))
    np.testing.assert_array_equal(got, want)
    got = np.asarray(lut_network(codes_in, layers, optimize_level=2))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# end-to-end on generated LogicNets (all three execution paths)
# ---------------------------------------------------------------------------

def _trained_toy(seed=0, hidden=(6, 5), fan_in=2, bw=2, in_features=6,
                 n_classes=3):
    cfg = LN.LogicNetCfg(in_features=in_features, n_classes=n_classes,
                         hidden=hidden, fan_in=fan_in, bw=bw,
                         final_dense=False, fan_in_fc=fan_in, bw_fc=bw)
    key = jax.random.PRNGKey(seed)
    model = LN.init(cfg, key, mask_seed=seed)
    x = jax.random.uniform(key, (64, in_features), minval=-1.0, maxval=3.0)
    _, model = LN.forward(cfg, model, x, train=True)
    return cfg, model, x


def _check_all_paths_tables(tables, res, in_features, bw,
                            n_words=40, seed=0):
    """Raw vs optimized: per-layer jnp, fused Pallas, IR reference forward
    and the Verilog interpreter — the full three-execution-path contract."""
    rng = np.random.default_rng(seed)
    codes_in = jnp.asarray(rng.integers(0, 2 ** bw,
                                        (17, in_features),
                                        dtype=np.int32))
    want = np.asarray(network_table_forward(tables, codes_in))
    got_pl = np.asarray(network_table_forward(res.tables, codes_in))
    np.testing.assert_array_equal(got_pl, want)
    got_fused = np.asarray(network_table_forward(res.tables, codes_in,
                                                 fused=True))
    np.testing.assert_array_equal(got_fused, want)
    np.testing.assert_array_equal(
        C.forward_codes(res.cnet, np.asarray(codes_in)), want)

    files = generate_verilog(res.netlist)
    n_layers = 1 + max(int(m.group(1)) for m in
                       (re.match(r"LUTLayer(\d+)\.v$", f) for f in files)
                       if m)
    bw_out = tables[-1].bw_out
    o_last = tables[-1].out_features
    for _ in range(n_words):
        word = int(rng.integers(0, 2 ** (bw * in_features)))
        digits = [(word >> (bw * f)) & (2 ** bw - 1)
                  for f in range(in_features)]
        expect = np.asarray(network_table_forward(
            tables, jnp.asarray([digits], jnp.int32)))[0]
        out_word = evaluate_verilog(files, word, n_layers=n_layers)
        got = [(out_word >> (bw_out * j)) & (2 ** bw_out - 1)
               for j in range(o_last)]
        assert got == [int(v) for v in expect], f"word={word}"


def _check_all_paths(cfg, tables, res, n_words=40, seed=0):
    _check_all_paths_tables(tables, res, cfg.in_features, cfg.bw,
                            n_words=n_words, seed=seed)


@pytest.mark.parametrize("level", [1, 2, 3])
def test_generated_logicnet_all_paths_bit_exact(level):
    cfg, model, _ = _trained_toy(seed=11)
    tables = LN.generate_tables(cfg, model)
    res = C.optimize(tables, level, in_features=cfg.in_features)
    _check_all_paths(cfg, tables, res)


def test_verify_tables_with_optimize_level():
    cfg, model, x = _trained_toy(seed=5)
    tables = LN.generate_tables(cfg, model)
    for fused in (False, True):
        f_codes, t_codes = LN.verify_tables(cfg, model, tables, x,
                                            fused=fused, optimize_level=2)
        np.testing.assert_array_equal(np.asarray(f_codes),
                                      np.asarray(t_codes))


def test_model_a_stack_shrinks_measurably():
    """The acceptance-criteria case: fpga4hep model A's packed tables and
    fused slab both shrink at level 2, level-3 re-encoding beats level 2's
    table bytes, and both results stay bit-exact (sampled)."""
    from repro.configs import fpga4hep
    from repro.kernels.lut_network import estimate_slab_bytes

    cfg = fpga4hep.model_a()
    model = LN.init(cfg, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (256, cfg.in_features),
                           minval=-1, maxval=3)
    _, model = LN.forward(cfg, model, x, train=True)
    tables = LN.generate_tables(cfg, model)
    res = C.optimize(tables, level=2, in_features=cfg.in_features)
    assert res.stats.table_bytes_after < res.stats.table_bytes_before
    raw_slab, _, _ = estimate_slab_bytes(
        [(tt.indices, tt.table, tt.bw_in) for tt in tables])
    opt_slab, _, _ = estimate_slab_bytes(
        [(tt.indices, tt.table, tt.bw_in) for tt in res.tables])
    assert opt_slab < raw_slab
    codes_in = jnp.asarray(np.random.default_rng(0).integers(
        0, 2 ** cfg.bw, (64, cfg.in_features), dtype=np.int32))
    want = np.asarray(network_table_forward(tables, codes_in))
    got = np.asarray(network_table_forward(res.tables, codes_in,
                                           fused=True))
    np.testing.assert_array_equal(got, want)

    # level 3: cross-layer re-encoding narrows real generated buses and
    # must land strictly below the level-2 packed-table figure
    res3 = C.optimize(tables, level=3, in_features=cfg.in_features)
    assert res3.stats.features_recoded > 0
    assert res3.stats.bits_saved > 0
    assert (res3.stats.table_bytes_after
            < res.stats.table_bytes_after)
    got3 = np.asarray(network_table_forward(res3.tables, codes_in,
                                            fused=True))
    np.testing.assert_array_equal(got3, want)


# ---------------------------------------------------------------------------
# hypothesis sweep: the full round-trip contract (skipped w/o hypothesis)
# ---------------------------------------------------------------------------

@given(data=st.data())
@settings(max_examples=15, deadline=None)
def test_reencode_random_sparse_stacks_bit_exact_hypothesis(data):
    """Level-3 re-encoding contract on random sparse stacks whose layer
    value pools are deliberately small (k as low as 1, the width-collapse
    edge): output is bit-exact with the unoptimized reference across all
    three execution paths — per-layer jnp, fused Pallas, Verilog."""
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")
    rng = np.random.default_rng(seed)
    bw = data.draw(st.integers(2, 3), label="bw")
    n_layers = data.draw(st.integers(2, 3), label="n_layers")
    in_features = data.draw(st.integers(2, 4), label="in_features")
    width = in_features
    tables = []
    for li in range(n_layers):
        n_out = data.draw(st.integers(2, 5), label=f"o{li}")
        fi = min(2, width)
        idx = np.stack([np.sort(rng.choice(width, fi, replace=False))
                        for _ in range(n_out)]).astype(np.int32)
        if li + 1 < n_layers:
            # intermediate bus: draw each layer's emitted codes from a
            # small pool so features carry k < 2^bw distinct codes and the
            # re-encoding pass actually fires (k == 1 collapses a feature)
            k = data.draw(st.integers(1, 2 ** bw), label=f"k{li}")
            pool = rng.choice(2 ** bw, size=k, replace=False)
        else:
            pool = np.arange(2 ** bw)
        tab = rng.choice(pool, size=(n_out, 2 ** (fi * bw))
                         ).astype(np.int32)
        tables.append(_tt(tab, idx, bw, bw))
        width = n_out
    res = C.optimize(tables, level=3, in_features=in_features)
    assert res.stats.table_bytes_after <= res.stats.table_bytes_before
    _check_all_paths_tables(tables, res, in_features, bw,
                            n_words=10, seed=seed)


@given(data=st.data())
@settings(max_examples=12, deadline=None)
def test_optimize_round_trip_bit_exact_hypothesis(data):
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")
    bw = data.draw(st.integers(1, 2), label="bw")
    n_hidden = data.draw(st.integers(1, 2), label="n_hidden")
    hidden = tuple(data.draw(st.integers(3, 7), label=f"h{i}")
                   for i in range(n_hidden))
    level = data.draw(st.integers(1, 3), label="level")
    cfg, model, _ = _trained_toy(seed=seed, hidden=hidden, fan_in=2,
                                 bw=bw, in_features=5, n_classes=3)
    tables = LN.generate_tables(cfg, model)
    res = C.optimize(tables, level, in_features=cfg.in_features)
    _check_all_paths(cfg, tables, res, n_words=12, seed=seed)
