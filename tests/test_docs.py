"""Docs stay true: link check, executable examples, CLI drift checks.

The docs tree (docs/*.md), the ROADMAP Quickstart block and
examples/quickstart.py all reference concrete CLIs and APIs.  These
tests are the rot-proofing the docs satellite promised:

* every relative markdown link (and ``#anchor``) resolves;
* every fenced ```python example in docs/*.md executes;
* every ``--flag`` a doc's command line mentions exists as an
  ``add_argument`` in the module it invokes (so renaming a CLI flag
  without updating the docs fails CI, and vice versa);
* every ``module.attr`` reference in examples/quickstart.py resolves
  against the live modules (so API renames can't strand the example);
* the ``--lut`` serving CLI itself runs end to end (slow lane).

CI's ``docs`` job runs the same checks via ``tools/check_docs.py``;
having them in the suite keeps local `pytest` honest too.
"""

import ast
import importlib
import os
import re
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_docs  # noqa: E402  (tools/ is not a package)

DOC_PATHS = [os.path.join(REPO, "docs"), os.path.join(REPO, "ROADMAP.md"),
             os.path.join(REPO, "CHANGES.md")]

# command prefix -> source file whose argparse must accept the flags
CLI_SOURCES = {
    "python -m benchmarks.kernel_bench":
        os.path.join(REPO, "benchmarks", "kernel_bench.py"),
    "python -m repro.launch.serve":
        os.path.join(REPO, "src", "repro", "launch", "serve.py"),
    "python tools/check_docs.py":
        os.path.join(REPO, "tools", "check_docs.py"),
    "python tools/promote_baseline.py":
        os.path.join(REPO, "tools", "promote_baseline.py"),
}


def test_markdown_links_resolve():
    assert check_docs.check_links(DOC_PATHS) == []


def test_docs_python_examples_execute():
    """The fenced examples in docs/*.md are the documented API surface;
    they must run (CI's docs job executes them too)."""
    assert check_docs.run_doctests([os.path.join(REPO, "docs")]) == []


def _declared_flags(source_path):
    """Every --flag the module's argparse declares (source-level scan —
    the parsers are built inside main() so importing won't expose them)."""
    src = open(source_path).read()
    return set(re.findall(r"add_argument\(\s*[\"'](--[\w-]+)[\"']", src))


def _doc_command_lines():
    """(doc file, command, flags) for every documented CLI invocation."""
    out = []
    md_files = [os.path.join(REPO, "ROADMAP.md"),
                *(os.path.join(REPO, "docs", f)
                  for f in sorted(os.listdir(os.path.join(REPO, "docs"))))]
    md_files.append(os.path.join(REPO, "src", "repro", "launch", "serve.py"))
    md_files.append(os.path.join(REPO, "examples", "quickstart.py"))
    for path in md_files:
        for line in open(path).read().splitlines():
            line = line.strip()
            for prefix in CLI_SOURCES:
                if prefix in line:
                    cmd = line[line.index(prefix):]
                    out.append((os.path.basename(path), prefix,
                                set(re.findall(r"(--[\w-]+)", cmd))))
    return out


def test_documented_cli_flags_exist():
    """Each --flag in a documented command line must be declared by the
    module the command invokes — the quickstart/ROADMAP drift check."""
    cmds = _doc_command_lines()
    # the load-bearing invocations must actually be documented somewhere
    assert any(p == "python -m benchmarks.kernel_bench" for _, p, _ in cmds)
    assert any(p == "python -m repro.launch.serve" and "--lut" in flags
               for _, p, flags in cmds)
    # the HTTP ingress front door (docs/ingress.md) stays documented
    assert any(p == "python -m repro.launch.serve" and "--http" in flags
               for _, p, flags in cmds)
    assert any(p == "python -m repro.launch.serve"
               and "--tenant-quota" in flags for _, p, flags in cmds)
    assert any(p == "python tools/check_docs.py" and "--pydoctest" in flags
               for _, p, flags in cmds)
    declared = {p: _declared_flags(src) for p, src in CLI_SOURCES.items()}
    for doc, prefix, flags in cmds:
        missing = flags - declared[prefix]
        assert not missing, (
            f"{doc} documents `{prefix}` with {sorted(missing)} "
            f"but {CLI_SOURCES[prefix]} does not declare them")


def test_quickstart_api_references_resolve():
    """Every module.attr used in examples/quickstart.py exists in the
    imported module — the example can't silently rot on an API rename."""
    path = os.path.join(REPO, "examples", "quickstart.py")
    tree = ast.parse(open(path).read(), path)
    imported = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                name = alias.asname or alias.name
                try:
                    mod = importlib.import_module(node.module)
                except ImportError:
                    pytest.fail(f"quickstart imports missing {node.module}")
                try:  # `from pkg import sub` may name a submodule ...
                    imported[name] = importlib.import_module(
                        f"{node.module}.{alias.name}")
                    continue
                except ImportError:  # ... or an attribute of the module
                    pass
                assert hasattr(mod, alias.name), (
                    f"quickstart imports {alias.name} from {node.module}, "
                    "which no longer provides it")
                imported[name] = getattr(mod, alias.name)
    checked = 0
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id in imported):
            target = imported[node.value.id]
            # only module-level references are static enough to assert
            if hasattr(target, "__spec__"):
                assert hasattr(target, node.attr), (
                    f"quickstart uses {node.value.id}.{node.attr}, which "
                    "does not exist")
                checked += 1
    assert checked >= 5, "drift check matched suspiciously few references"


def test_serve_lut_cli_smoke(tmp_path):
    """`python -m repro.launch.serve --lut --smoke` end to end: compiles
    model A, drives the tier, and enforces the compile-once contract
    (the CLI exits non-zero when the counters are non-zero).  The
    ``--metrics-json`` snapshot must carry the docs/observability.md
    walkthrough's shape: populated stage histograms and compile-pass
    timings, compile-once counters exactly 0 after warmup."""
    import json

    metrics = str(tmp_path / "m.json")
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--lut", "--smoke",
         "--report-every-s", "0", "--metrics-json", metrics],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "compile-once contract" in proc.stdout
    assert "retraces=0" in proc.stdout
    assert "compiler_runs=0" in proc.stdout
    assert f"metrics snapshot -> {metrics}" in proc.stdout
    with open(metrics) as f:
        snap = json.load(f)
    for name in ("serve_queue_wait_seconds", "serve_assembly_seconds",
                 "serve_device_seconds", "serve_request_latency_seconds",
                 "compile_pass_seconds_total", "compile_pass_runs_total",
                 "engine_compiler_runs_total", "engine_builds_total"):
        assert snap[name]["series"], f"{name} empty in --metrics-json"
    for name in ("serve_queue_wait_seconds", "serve_device_seconds"):
        assert all(s["count"] > 0 for s in snap[name]["series"]), name
    for name in ("serve_retraces_after_warmup",
                 "serve_compiler_runs_after_warmup"):
        assert all(s["value"] == 0 for s in snap[name]["series"]), (
            f"{name} non-zero: the compile-once serving contract broke")
