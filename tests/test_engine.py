"""Serving-artifact contract: ``repro.engine.CompiledLUTNet``.

Three contracts under test:

* **bit-exactness** — the artifact matches ``network_table_forward`` (the
  reference semantics) across the mixed, uniform and per-layer-fallback
  layouts, including packed-int8 boundary codes {0, 255};
* **round-trip** — ``save``/``load`` reproduces the live artifact's
  outputs exactly (slabs, plan and stats all survive the ``.npz``);
* **compile-once** — a steady-state serving loop performs zero jit
  re-traces and zero compiler re-runs after warmup, and the legacy flag
  API (``ops.lut_network``) memoizes instead of silently recompiling.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core.table_infer import network_table_forward
from repro.core.truth_table import LayerTruthTable
from repro.kernels.ops import lut_network


def _random_stack(widths, fan_ins, bws, seed=0):
    rng = np.random.default_rng(seed)
    layers = []
    for (n_in, n_out), fi, bw in zip(zip(widths[:-1], widths[1:]),
                                     fan_ins, bws):
        fi = min(fi, n_in)
        idx = np.stack([np.sort(rng.choice(n_in, fi, replace=False))
                        for _ in range(n_out)]).astype(np.int32)
        tab = rng.integers(0, 2 ** bw, (n_out, 2 ** (fi * bw)),
                           dtype=np.int32)
        layers.append((idx, tab, bw))
    return layers


def _tables(layers):
    return [LayerTruthTable(tab, idx, bw, bw) for idx, tab, bw in layers]


def _codes(n_in, bw, batch, seed=0):
    return jnp.asarray(np.random.default_rng(seed).integers(
        0, 2 ** bw, (batch, n_in), dtype=np.int32))


STACK = ((12, 20, 16, 8), (3, 3, 3), (2, 2, 2))


@pytest.mark.parametrize("kwargs,layout", [
    ({}, "uniform"),
    ({"optimize_level": 2}, "mixed"),
    ({"optimize_level": 3}, "mixed"),
    ({"vmem_budget_bytes": 64}, "per_layer"),
    ({"fused": False}, "per_layer"),
    ({"use_pallas": False}, "reference"),
])
def test_artifact_bit_exact_across_layouts(kwargs, layout):
    widths, fan_ins, bws = STACK
    layers = _random_stack(widths, fan_ins, bws, seed=13)
    codes = _codes(widths[0], bws[0], 27, seed=1)
    want = np.asarray(network_table_forward(_tables(layers), codes))

    eng = engine.compile_network(layers, in_features=widths[0], **kwargs)
    assert eng.layout == layout
    assert eng.n_in == widths[0] and eng.n_out == widths[-1]
    np.testing.assert_array_equal(np.asarray(eng(codes)), want)
    # plan records the actual decision, stats only when the compiler ran
    assert (eng.plan.reason == "fused") == (layout in ("uniform", "mixed"))
    assert (eng.stats is not None) == ("optimize_level" in kwargs)
    assert eng.vmem_breakdown()["layout"] == layout


def test_batch_edges_and_input_validation():
    widths, fan_ins, bws = STACK
    layers = _random_stack(widths, fan_ins, bws, seed=13)
    eng = engine.compile_network(layers, in_features=widths[0], block_b=8)
    empty = eng(jnp.zeros((0, widths[0]), jnp.int32))
    assert empty.shape == (0, widths[-1]) and empty.dtype == jnp.int32
    with pytest.raises(ValueError, match="expected"):
        eng(jnp.zeros((4, widths[0] + 1), jnp.int32))
    # ragged batches (pad-and-slice) match the unpadded reference
    codes = _codes(widths[0], bws[0], 11, seed=2)
    want = np.asarray(network_table_forward(_tables(layers), codes))
    np.testing.assert_array_equal(np.asarray(eng(codes)), want)
    # numpy input is accepted
    np.testing.assert_array_equal(np.asarray(eng(np.asarray(codes))), want)


@pytest.mark.parametrize("kwargs,layout", [
    ({"optimize_level": 3}, "mixed"),
    ({}, "uniform"),
    ({"vmem_budget_bytes": 64}, "per_layer"),
])
def test_save_load_round_trip_across_layouts(tmp_path, kwargs, layout):
    """Acceptance: save -> load preserves outputs exactly vs both the live
    artifact and the ``network_table_forward`` reference."""
    widths, fan_ins, bws = STACK
    layers = _random_stack(widths, fan_ins, bws, seed=21)
    codes = _codes(widths[0], bws[0], 33, seed=3)
    want = np.asarray(network_table_forward(_tables(layers), codes))

    eng = engine.compile_network(layers, in_features=widths[0], **kwargs)
    assert eng.layout == layout
    live = np.asarray(eng(codes))
    np.testing.assert_array_equal(live, want)

    path = os.path.join(tmp_path, "net.npz")
    assert eng.save(path) == path
    eng2 = engine.load(path)
    assert eng2.layout == eng.layout
    assert (eng2.n_in, eng2.n_out, eng2.block_b) == (
        eng.n_in, eng.n_out, eng.block_b)
    assert eng2.plan == eng.plan
    np.testing.assert_array_equal(np.asarray(eng2(codes)), live)
    np.testing.assert_array_equal(np.asarray(eng2(codes)), want)
    if eng.stats is not None:
        assert eng2.stats.as_dict() == eng.stats.as_dict()
    assert eng2.vmem_breakdown() == eng.vmem_breakdown()


def test_round_trip_packed_int8_boundary_codes(tmp_path):
    """Packed-int8 tables with boundary codes 0/255 must survive the uint8
    view through the npz and back (mixed and uniform layouts)."""
    layers = _random_stack((8, 10, 6), (2, 2), (2, 2), seed=9)
    idx, tab, bw = layers[-1]
    layers[-1] = (idx, (tab % 2) * 255, bw)      # outputs exactly {0, 255}
    codes = _codes(8, 2, 19, seed=4)
    want = np.asarray(network_table_forward(_tables(layers), codes))
    assert set(np.unique(want)) <= {0, 255}

    for kwargs, layout in (({"optimize_level": 3}, "mixed"), ({}, "uniform")):
        eng = engine.compile_network(layers, in_features=8, **kwargs)
        assert eng.layout == layout and eng.slabs.packed
        assert eng.slabs.table_slab.dtype == jnp.int8
        path = os.path.join(tmp_path, f"{layout}.npz")
        eng.save(path)
        eng2 = engine.load(path)
        assert eng2.slabs.packed
        np.testing.assert_array_equal(np.asarray(eng2(codes)), want)


def test_round_trip_per_layer_fallback_over_budget(tmp_path):
    """The over-VMEM-budget artifact serializes its per-layer triples and
    still serves bit-exactly after a reload."""
    widths, fan_ins, bws = STACK
    layers = _random_stack(widths, fan_ins, bws, seed=31)
    eng = engine.compile_network(layers, in_features=widths[0],
                                 vmem_budget_bytes=64)
    assert eng.layout == "per_layer"
    assert eng.plan.reason == "slab_exceeds_vmem_budget"
    codes = _codes(widths[0], bws[0], 14, seed=5)
    want = np.asarray(network_table_forward(_tables(layers), codes))
    path = os.path.join(tmp_path, "fallback.npz")
    eng.save(path)
    eng2 = engine.load(path)
    assert eng2.layout == "per_layer" and eng2.plan == eng.plan
    np.testing.assert_array_equal(np.asarray(eng2(codes)), want)


def test_load_rejects_foreign_npz(tmp_path):
    from repro.checkpoint import save_arrays

    path = os.path.join(tmp_path, "other.npz")
    save_arrays(path, {"x": np.zeros(3)}, {"kind": "something_else"})
    with pytest.raises(ValueError, match="not a repro.engine"):
        engine.load(path)
    # a plain np.savez file (no manifest) must fail with the friendly
    # ValueError too, not an opaque KeyError from deep inside the loader
    plain = os.path.join(tmp_path, "plain.npz")
    np.savez(plain, x=np.zeros(3))
    with pytest.raises(ValueError, match="manifest"):
        engine.load(plain)


def test_default_in_features_ignores_hidden_layer_indices():
    """Regression: the inferred input-bus width must come from the FIRST
    layer's indices only — a hidden layer wider than the input bus used
    to inflate n_in and reject valid codes."""
    widths, fan_ins, bws = (4, 10, 3), (2, 2), (2, 2)
    layers = _random_stack(widths, fan_ins, bws, seed=41)
    codes = _codes(4, 2, 5, seed=9)
    want = np.asarray(network_table_forward(_tables(layers), codes))
    for kwargs in ({}, {"optimize_level": 3}):
        eng = engine.compile_network(layers, **kwargs)   # no in_features
        assert eng.n_in == 4
        np.testing.assert_array_equal(np.asarray(eng(codes)), want)


def test_compile_network_accepts_optimize_result():
    """An already-computed OptimizeResult is reused, not recompiled."""
    from repro import compile as rcompile

    widths, fan_ins, bws = STACK
    layers = _random_stack(widths, fan_ins, bws, seed=17)
    res = rcompile.optimize(rcompile.tables_from_triples(layers), 3,
                            in_features=widths[0])
    runs0 = engine.compile_runs()
    eng = engine.compile_network(res)
    assert engine.compile_runs() == runs0      # no compiler run
    assert eng.layout == "mixed" and eng.stats is res.stats
    assert eng.n_in == widths[0]
    codes = _codes(widths[0], bws[0], 9, seed=6)
    want = np.asarray(network_table_forward(_tables(layers), codes))
    np.testing.assert_array_equal(np.asarray(eng(codes)), want)
    with pytest.raises(ValueError, match="OptimizeResult"):
        engine.compile_network(res, optimize_level=3)


def test_serving_loop_zero_retrace_zero_recompile():
    """Acceptance: after warmup, a steady-state serving loop with ragged
    batch sizes adds no jit traces and never re-runs the compiler."""
    widths, fan_ins, bws = STACK
    layers = _random_stack(widths, fan_ins, bws, seed=23)
    eng = engine.compile_network(layers, optimize_level=3,
                                 in_features=widths[0], block_b=32)
    assert eng.layout == "mixed"
    want_full = np.asarray(network_table_forward(
        _tables(layers), _codes(widths[0], bws[0], 32, seed=7)))
    np.testing.assert_array_equal(
        np.asarray(eng(_codes(widths[0], bws[0], 32, seed=7))), want_full)

    traces0 = eng.jit_cache_size()
    runs0 = engine.compile_runs()
    for batch in (32, 1, 17, 32, 9, 25, 32):   # one block_b bucket
        codes = _codes(widths[0], bws[0], batch, seed=7)
        out = np.asarray(eng(codes))
        np.testing.assert_array_equal(out, want_full[:batch])
    assert eng.jit_cache_size() == traces0, "serving loop re-traced"
    assert engine.compile_runs() == runs0, "serving loop re-ran the compiler"


def test_legacy_flag_api_memoizes():
    """Regression (the `_cache_size` pattern): ops.lut_network with
    optimize_level= used to re-run the compiler and rebuild slabs on every
    call; the engine memo must absorb repeated calls entirely."""
    widths, fan_ins, bws = STACK
    layers = _random_stack(widths, fan_ins, bws, seed=29)
    codes = _codes(widths[0], bws[0], 21, seed=8)
    want = np.asarray(network_table_forward(_tables(layers), codes))

    got = np.asarray(lut_network(codes, layers, optimize_level=3))
    np.testing.assert_array_equal(got, want)
    size0 = engine.cache_size()
    runs0 = engine.compile_runs()
    for _ in range(4):
        got = np.asarray(lut_network(codes, layers, optimize_level=3))
    np.testing.assert_array_equal(got, want)
    assert engine.cache_size() == size0, "legacy calls grew the memo"
    assert engine.compile_runs() == runs0, "legacy calls re-ran the compiler"
    # distinct flag combinations are distinct artifacts ...
    lut_network(codes, layers, optimize_level=2)
    assert engine.cache_size() == size0 + 1
    # ... and cache_clear forces a fresh compile
    engine.cache_clear()
    assert engine.cache_size() == 0
    got = np.asarray(lut_network(codes, layers, optimize_level=3))
    np.testing.assert_array_equal(got, want)
    assert engine.compile_runs() == runs0 + 2


def test_cache_bounded_fifo_eviction_order(monkeypatch):
    """The legacy-API memo is FIFO-bounded: filling past _CACHE_MAX
    evicts the *oldest insertion* (not least-recently-used — a re-hit
    does not refresh an entry's position)."""
    from repro.engine import engine as engmod

    engine.cache_clear()
    monkeypatch.setattr(engmod, "_CACHE_MAX", 3)
    stacks = [_random_stack((8, 6, 4), (2, 2), (2, 2), seed=100 + i)
              for i in range(4)]

    def compiled(stack):
        return engine.cached_compile(
            stack, optimize_level=None, in_features=8, fused=True,
            use_pallas=True, block_b=8,
            vmem_budget_bytes=8 * 2 ** 20)

    a, b, c = (compiled(s) for s in stacks[:3])
    assert engine.cache_size() == 3
    assert compiled(stacks[0]) is a, "expected a memo hit"
    # inserting a 4th evicts the oldest insertion: stack 0, even though
    # it was just re-hit (FIFO, not LRU)
    d = compiled(stacks[3])
    assert engine.cache_size() == 3
    assert compiled(stacks[1]) is b and compiled(stacks[2]) is c
    a2 = compiled(stacks[0])
    assert a2 is not a, "evicted entry must recompile"
    # that reinsertion evicted the new oldest entry (stack 1); the
    # younger entries survived
    assert engine.cache_size() == 3
    assert compiled(stacks[2]) is c and compiled(stacks[3]) is d
    engine.cache_clear()
    assert engine.cache_size() == 0


def test_cache_clear_after_in_place_edit():
    """The documented immutability contract: an in-place table edit is
    served stale until ``engine.cache_clear()`` forces a fresh compile."""
    engine.cache_clear()
    layers = _random_stack((8, 6, 4), (2, 2), (2, 2), seed=77)
    codes = _codes(8, 2, 9, seed=9)
    stale = np.asarray(lut_network(codes, layers))
    np.testing.assert_array_equal(
        stale, np.asarray(network_table_forward(_tables(layers), codes)))

    idx0, tab0, bw0 = layers[0]
    tab0 += 1
    tab0 %= 2 ** bw0                     # in-place edit, same array id
    np.testing.assert_array_equal(
        np.asarray(lut_network(codes, layers)), stale)   # stale hit

    engine.cache_clear()
    fresh = np.asarray(lut_network(codes, layers))
    np.testing.assert_array_equal(
        fresh, np.asarray(network_table_forward(_tables(layers), codes)))
    assert not np.array_equal(fresh, stale), (
        "the edit was chosen to change outputs; stale and fresh must "
        "differ for this regression test to mean anything")


def test_generated_model_round_trip(tmp_path):
    """End-to-end on real generated tables (fpga4hep model C shape): the
    engine artifact equals the float-path verification codes, survives a
    round-trip, and reports the compiler's stats."""
    import jax

    from repro.configs import fpga4hep
    from repro.core import logicnet as LN
    from repro.core.quantize import codes as qcodes

    cfg = fpga4hep.model_c()
    model = LN.init(cfg, jax.random.PRNGKey(0))
    tables = LN.generate_tables(cfg, model)
    x = jax.random.uniform(jax.random.PRNGKey(1), (40, cfg.in_features),
                           minval=-1, maxval=3)
    eng = engine.compile_network(tables, optimize_level=3,
                                 in_features=cfg.in_features)
    in_codes = qcodes(cfg.layer_cfgs()[0].in_quant, x)
    want = np.asarray(network_table_forward(tables, in_codes))
    np.testing.assert_array_equal(np.asarray(eng(in_codes)), want)
    assert eng.stats.table_bytes_after < eng.stats.table_bytes_before

    path = os.path.join(tmp_path, "model_c.npz")
    eng.save(path)
    eng2 = engine.load(path)
    np.testing.assert_array_equal(np.asarray(eng2(in_codes)), want)
    assert eng2.stats.table_bytes_after == eng.stats.table_bytes_after
