"""Unit tests for the collective-traffic HLO parser (roofline input)."""

import textwrap

from repro.launch.hlo_stats import collective_bytes, op_histogram


HLO = textwrap.dedent("""
  %all-reduce.5 = f32[1024,128]{1,0} all-reduce(f32[1024,128]{1,0} %add.3)
  %ag = bf16[256,512]{1,0} all-gather(bf16[128,512]{1,0} %p0)
  %rs.1 = f32[64]{0} reduce-scatter(f32[512]{0} %x)
  %a2a = (f32[8,16]{1,0}, f32[8,16]{1,0}) all-to-all(f32[8,16] %a, f32[8,16] %b)
  %cp = u32[4]{0} collective-permute(u32[4]{0} %c)
  %dot.1 = f32[10,10]{1,0} dot(f32[10,10] %l, f32[10,10] %r)
  %ar-start = f32[32]{0} all-reduce-start(f32[32]{0} %y)
  %ar-done = f32[32]{0} all-reduce-done(f32[32]{0} %ar-start)
""")


def test_collective_bytes_by_type():
    out = collective_bytes(HLO)
    # all-reduce: (1024*128*4 + 32*4[start]) * 2x ring
    assert out["all-reduce"] == (1024 * 128 * 4 + 32 * 4) * 2.0
    assert out["all-gather"] == 256 * 512 * 2
    assert out["reduce-scatter"] == 64 * 4
    assert out["all-to-all"] == 2 * 8 * 16 * 4     # tuple result
    assert out["collective-permute"] == 4 * 4
    assert out["total"] == sum(v for k, v in out.items()
                               if k in ("all-reduce", "all-gather",
                                        "reduce-scatter", "all-to-all",
                                        "collective-permute"))


def test_done_ops_not_double_counted():
    out = collective_bytes(HLO)
    assert out["n_all-reduce"] == 2     # .5 and -start; -done skipped


def test_empty_module():
    out = collective_bytes("%add = f32[2] add(f32[2] %a, f32[2] %b)")
    assert out["total"] == 0.0


def test_op_histogram():
    h = op_histogram(HLO, ("dot", "all-gather"))
    assert h["dot"] == 1
    assert h["all-gather"] == 1
