"""HTTP ingress contract: ``repro.serve.HttpIngress`` + open-loop load.

The ingress is pure plumbing between a socket and ``ServingTier.infer``,
so the contracts mirror the tier's plus the wire-level ones:

* **transport correctness** — JSON and raw-int8 responses through a real
  localhost socket are bit-exact with calling the artifact directly, and
  steady state adds zero traces and zero compiler runs;
* **typed error mapping** — 400/404/405/429/503 each carry the JSON
  ``{"error", "detail"}`` body docs/ingress.md tables, and the client
  (``serve.http_infer``) raises the matching typed exception;
* **per-tenant quota** — deterministic token-bucket math with an
  injected clock, and over-quota 429s accounted identically by the
  ``LoadReport`` outcomes and the ``ingress_rejected_total`` metric;
* **open-loop generator** — seeded Poisson schedule is reproducible;
  under capacity every request completes, past capacity the bounded
  queue sheds with 503s instead of queueing unboundedly;
* **CLI end to end** (subprocess) — ``serve --lut --http 0 --smoke``
  verifies bit-exact over HTTP and exits zero; the serve-forever mode
  drains on SIGTERM and still dumps its ``--metrics-json`` snapshot.
"""

import asyncio
import http.client
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro import engine, obs, serve

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="module")
def net():
    """Tiny compiled artifact (no compiler pass: cheap, still jitted)."""
    rng = np.random.default_rng(7)
    idx = np.stack([np.sort(rng.choice(12, 3, replace=False))
                    for _ in range(8)]).astype(np.int32)
    tbl = rng.integers(0, 4, (8, 2 ** 6), dtype=np.int32)
    return engine.compile_network([(idx, tbl, 2)], in_features=12,
                                  block_b=8)


def _codes(net, rows, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 4, (rows, net.n_in), dtype=np.int32)


def _counter(snap, name, **labels):
    for s in snap.get(name, {}).get("series", []):
        if s["labels"] == labels:
            return s["value"]
    return 0.0


def _request(port, method, path, body=None, headers=None):
    """One blocking HTTP request against the background ingress."""
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=30)
    try:
        conn.request(method, path, body=body, headers=headers or {})
        resp = conn.getresponse()
        return resp.status, dict(resp.getheaders()), resp.read()
    finally:
        conn.close()


# ---------------------------------------------------------------------------
# deterministic building blocks
# ---------------------------------------------------------------------------

def test_token_bucket_injected_clock():
    """Quota math is exact under an injected monotonic clock."""
    b = serve.TokenBucket(rate=10.0, burst=5.0, now=0.0)
    assert b.try_take(5, now=0.0)            # full burst available
    assert not b.try_take(1, now=0.0)        # empty
    assert not b.try_take(2, now=0.1)        # refilled only 1 token
    assert b.try_take(1, now=0.1)
    assert b.try_take(5, now=100.0)          # refill caps at burst ...
    assert b.tokens == 0.0                   # ... not 1000 tokens
    assert not b.try_take(1, now=99.0)       # clock never runs backwards
    with pytest.raises(ValueError, match="positive"):
        serve.TokenBucket(rate=0.0, burst=5.0)


def test_quota_config_burst_defaults_to_rate():
    assert serve.QuotaConfig(rate_rows_per_s=250.0).burst == 250.0
    assert serve.QuotaConfig(rate_rows_per_s=250.0, burst_rows=7.0).burst \
        == 7.0


def test_poisson_arrivals_seeded_schedule():
    a = serve.poisson_arrivals(200.0, 500, seed=3)
    b = serve.poisson_arrivals(200.0, 500, seed=3)
    np.testing.assert_array_equal(a, b)      # reproducible
    assert a.shape == (500,)
    assert np.all(np.diff(a) >= 0)           # cumulative times
    # mean inter-arrival ~ 1/rate (loose: 500 samples)
    assert 0.5 / 200.0 < float(a[-1] / 500) < 2.0 / 200.0
    assert not np.array_equal(a, serve.poisson_arrivals(200.0, 500, seed=4))
    with pytest.raises(ValueError, match="positive"):
        serve.poisson_arrivals(0.0, 4)


# ---------------------------------------------------------------------------
# HTTP transport: bit-exact + typed errors over a real socket
# ---------------------------------------------------------------------------

def test_http_json_and_raw_bit_exact(net):
    with serve.BackgroundIngress(net) as ing:
        codes = _codes(net, 5, seed=1)
        want = np.asarray(net(codes))
        raw = asyncio.run(serve.http_infer("127.0.0.1", ing.port, codes))
        as_json = asyncio.run(serve.http_infer("127.0.0.1", ing.port,
                                               codes, raw=False))
        np.testing.assert_array_equal(raw, want)
        np.testing.assert_array_equal(as_json, want)
        # one flat row is promoted to (1, n_in)
        status, _, body = _request(
            ing.port, "POST", "/v1/infer",
            body=json.dumps({"codes": codes[0].tolist()}),
            headers={"content-type": "application/json"})
        assert status == 200
        np.testing.assert_array_equal(
            np.asarray(json.loads(body)["outputs"]), want[:1])
        stats = ing.stats()
    assert stats["retraces_after_warmup"] == 0
    assert stats["compiler_runs_after_warmup"] == 0


def test_http_error_mappings(net):
    with serve.BackgroundIngress(net) as ing:
        port = ing.port
        for method, path, body, hdrs, status, err in [
            ("GET", "/nope", None, {}, 404, "not_found"),
            ("GET", "/v1/infer", None, {}, 405, "method_not_allowed"),
            ("POST", "/healthz", None, {}, 405, "method_not_allowed"),
            ("POST", "/v1/infer", b"{not json",
             {"content-type": "application/json"}, 400, "bad_request"),
            ("POST", "/v1/infer", json.dumps({"codes": [[1, 2, 3]]}),
             {"content-type": "application/json"}, 400, "bad_request"),
            ("POST", "/v1/infer", b"\x01" * (net.n_in + 1),
             {"content-type": "application/octet-stream"}, 400,
             "bad_request"),
        ]:
            got, _, body_out = _request(port, method, path, body, hdrs)
            assert got == status, (method, path, body_out)
            assert json.loads(body_out)["error"] == err

        status, _, body = _request(port, "GET", "/healthz")
        health = json.loads(body)
        assert status == 200 and health["status"] == "ok"
        assert health["retraces_after_warmup"] == 0
        assert health["compiler_runs_after_warmup"] == 0

        status, headers, body = _request(port, "GET", "/metrics")
        assert status == 200
        assert headers["content-type"].startswith("text/plain")
        text = body.decode()
        assert "# TYPE ingress_requests_total counter" in text
        assert 'ingress_requests_total{route="/healthz",status="200"}' \
            in text


# ---------------------------------------------------------------------------
# per-tenant quota: 429 accounting matches the LoadReport exactly
# ---------------------------------------------------------------------------

def test_quota_rejections_match_load_report(net):
    """6 burst tokens at 2 rows/request admit exactly 3 requests; every
    other request 429s, and the ``ingress_rejected_total{reason=quota}``
    delta equals the LoadReport's ``rejected_quota`` outcome."""
    cfg = serve.IngressConfig(
        quota=serve.QuotaConfig(rate_rows_per_s=0.5, burst_rows=6.0))
    before = obs.registry().snapshot()
    with serve.BackgroundIngress(net, config=cfg) as ing:
        rep = serve.run_open_loop(
            url=ing.url, offered_rps=500.0, n_requests=10,
            rows_min=2, rows_max=2, seed=11, tenant="alice",
            verify_net=net)
    after = obs.registry().snapshot()

    assert rep.outcomes["ok"] == 3                     # 6 tokens / 2 rows
    assert rep.outcomes["rejected_quota"] == 7
    assert rep.rejected == 7 and rep.timed_out == 0
    assert rep.rejection_rate == pytest.approx(0.7)
    assert sum(rep.outcomes.values()) == rep.n_requests == 10
    delta = (_counter(after, "ingress_rejected_total", reason="quota")
             - _counter(before, "ingress_rejected_total", reason="quota"))
    assert delta == rep.outcomes["rejected_quota"]


def test_quota_isolates_tenants(net):
    """One tenant exhausting its bucket must not affect another's."""
    cfg = serve.IngressConfig(
        quota=serve.QuotaConfig(rate_rows_per_s=0.5, burst_rows=4.0))

    async def main(port):
        codes = _codes(net, 4, seed=2)
        await serve.http_infer("127.0.0.1", port, codes, tenant="noisy")
        with pytest.raises(serve.QuotaExceeded):
            await serve.http_infer("127.0.0.1", port, codes, tenant="noisy")
        return await serve.http_infer("127.0.0.1", port, codes,
                                      tenant="quiet")

    with serve.BackgroundIngress(net, config=cfg) as ing:
        out = asyncio.run(main(ing.port))
    np.testing.assert_array_equal(out, np.asarray(net(_codes(net, 4,
                                                             seed=2))))


# ---------------------------------------------------------------------------
# open-loop generator: determinism under capacity, shedding past it
# ---------------------------------------------------------------------------

def test_open_loop_in_process_all_ok_and_deterministic(net):
    kw = dict(offered_rps=300.0, n_requests=12, rows_max=4, seed=5)
    a = serve.run_open_loop(net, **kw)       # check_outputs verifies
    b = serve.run_open_loop(net, **kw)       # bit-exact vs net(codes)
    assert a.outcomes == b.outcomes == {"ok": 12}
    assert a.rejection_rate == 0.0
    assert a.n_clients == 0                  # the open-loop marker
    assert a.rows == b.rows                  # same seeded request sizes
    assert a.stats["retraces_after_warmup"] == 0
    assert a.stats["compiler_runs_after_warmup"] == 0


class _SlowNet:
    """Fixed per-batch cost so overload is deterministic in tests."""

    def __init__(self, inner, delay_s=0.02):
        self._inner, self._delay = inner, delay_s
        self.n_in, self.n_out = inner.n_in, inner.n_out
        self.block_b = inner.block_b

    def jit_cache_size(self):
        return self._inner.jit_cache_size()

    def __call__(self, codes):
        time.sleep(self._delay)
        return self._inner(codes)


def test_open_loop_overload_sheds_not_queues(net):
    """Past capacity the bounded queue must reject (503), keep some
    goodput, and keep the outcome accounting consistent."""
    cfg = serve.TierConfig(max_batch_rows=8, flush_deadline_s=0.002,
                           max_queue_rows=8)
    rep = serve.run_open_loop(_SlowNet(net), config=cfg,
                              offered_rps=1000.0, n_requests=30,
                              rows_min=2, rows_max=4, seed=0,
                              check_outputs=False)
    assert rep.outcomes["ok"] >= 1
    assert rep.outcomes.get("rejected_overload", 0) > 0
    assert rep.rejected == (rep.outcomes.get("rejected_overload", 0)
                            + rep.outcomes.get("rejected_quota", 0)
                            + rep.outcomes.get("closed", 0))
    assert rep.goodput_rps < rep.offered_rps
    assert rep.rejection_rate == pytest.approx(
        1.0 - rep.outcomes["ok"] / rep.n_requests)


def test_open_loop_url_mode_needs_sizing():
    with pytest.raises(ValueError, match="exactly one"):
        serve.run_open_loop()
    with pytest.raises(ValueError, match="verify_net= or n_in="):
        serve.run_open_loop(url="http://127.0.0.1:1")


# ---------------------------------------------------------------------------
# CLI end to end (subprocess): --http --smoke, and SIGTERM drain
# ---------------------------------------------------------------------------

def _subprocess_env():
    return dict(os.environ, JAX_PLATFORMS="cpu",
                PYTHONPATH=SRC + os.pathsep
                + os.environ.get("PYTHONPATH", ""))


@pytest.fixture(scope="module")
def artifact(net, tmp_path_factory):
    """The tiny artifact saved to disk so subprocesses skip model A."""
    path = str(tmp_path_factory.mktemp("ingress") / "tiny.npz")
    net.save(path)
    return path


def test_cli_http_smoke_end_to_end(net, artifact, tmp_path):
    """``serve --lut --http 0 --smoke``: open-loop load through a live
    localhost ingress, every response verified bit-exact, compile-once
    counters zero, LoadReport and metrics snapshot dumped."""
    report = str(tmp_path / "r.json")
    metrics = str(tmp_path / "m.json")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--lut",
         "--artifact", artifact, "--http", "0", "--smoke",
         "--report-every-s", "0", "--report-json", report,
         "--metrics-json", metrics],
        env=_subprocess_env(), capture_output=True, text=True,
        timeout=600, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "http ingress listening on http://127.0.0.1:" in proc.stdout
    assert "responses verified bit-exact over HTTP" in proc.stdout
    assert "retraces=0" in proc.stdout
    assert "compiler_runs=0" in proc.stdout
    with open(report) as fh:
        rep = json.load(fh)
    assert rep["n_clients"] == 0                       # open loop
    assert sum(rep["outcomes"].values()) == rep["n_requests"] == 16
    with open(metrics) as fh:
        snap = json.load(fh)
    assert any(s["labels"].get("route") == "/v1/infer"
               for s in snap["ingress_requests_total"]["series"])
    assert all(s["count"] > 0
               for s in snap["ingress_infer_seconds"]["series"])


def test_cli_http_sigterm_drains_and_dumps_metrics(net, artifact, tmp_path):
    """Serve-forever mode: answer requests, then SIGTERM -> graceful
    drain, exit 0, and the ``--metrics-json`` snapshot still lands."""
    metrics = str(tmp_path / "m.json")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve", "--lut",
         "--artifact", artifact, "--http", "0",
         "--report-every-s", "0", "--metrics-json", metrics],
        env=_subprocess_env(), stdout=subprocess.PIPE,
        stderr=subprocess.PIPE, text=True, cwd=REPO)
    try:
        port, head = None, []
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline:
            line = proc.stdout.readline()
            if not line:
                break
            head.append(line)
            if "listening on http://127.0.0.1:" in line:
                port = int(line.split("http://127.0.0.1:")[1].split()[0])
                break
        assert port is not None, "".join(head) + proc.stderr.read()

        codes = _codes(net, 3, seed=9)
        out = asyncio.run(serve.http_infer("127.0.0.1", port, codes))
        np.testing.assert_array_equal(out, np.asarray(net(codes)))

        proc.send_signal(signal.SIGTERM)
        stdout, stderr = proc.communicate(timeout=120)
    finally:
        if proc.poll() is None:                        # pragma: no cover
            proc.kill()
            proc.communicate()
    full = "".join(head) + stdout
    assert proc.returncode == 0, full + stderr[-2000:]
    assert "draining" in full
    assert f"metrics snapshot -> {metrics}" in full
    with open(metrics) as fh:
        snap = json.load(fh)
    assert any(s["labels"].get("route") == "/v1/infer"
               and s["labels"].get("status") == "200"
               for s in snap["ingress_requests_total"]["series"])
    for name in ("serve_retraces_after_warmup",
                 "serve_compiler_runs_after_warmup"):
        assert all(s["value"] == 0 for s in snap[name]["series"]), name
