"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.lut_lookup import lut_lookup_pallas
from repro.kernels.masked_matmul import masked_matmul_pallas


def _indices(n_out, n_in, fan_in, seed=0):
    rng = np.random.default_rng(seed)
    idx = np.stack([np.sort(rng.choice(n_in, fan_in, replace=False))
                    for _ in range(n_out)])
    return jnp.asarray(idx.astype(np.int32))


# ---------------------------------------------------------------------------
# lut_lookup
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch,n_in,n_out,fan_in,bw", [
    (4, 8, 8, 2, 1),
    (17, 12, 9, 3, 2),      # non-divisible batch/neurons
    (64, 32, 16, 2, 3),
    (256, 64, 64, 4, 2),    # multi-block batch
    (33, 16, 200, 3, 1),    # multi-block neurons
    (8, 24, 5, 6, 2),       # 12-bit tables, multiple e-chunks
])
def test_lut_lookup_matches_ref(batch, n_in, n_out, fan_in, bw):
    key = jax.random.PRNGKey(batch + n_out)
    codes = jax.random.randint(key, (batch, n_in), 0, 2 ** bw,
                               dtype=jnp.int32)
    idx = _indices(n_out, n_in, fan_in, seed=n_out)
    table = jax.random.randint(jax.random.PRNGKey(1), (n_out,
                               2 ** (fan_in * bw)), 0, 2 ** bw,
                               dtype=jnp.int32)
    got = lut_lookup_pallas(codes, idx, table, bw, block_b=16, block_o=32,
                            e_chunk=64, interpret=True)
    want = ref.lut_lookup_ref(codes, idx, table, bw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lut_lookup_matches_truth_table_layer():
    """The kernel result == core.table_infer layer forward (the network-level
    semantics the paper verifies functionally)."""
    from repro.core import layers as L
    from repro.core.quantize import QuantizerCfg, codes as qcodes
    from repro.core.table_infer import layer_table_forward
    from repro.core.truth_table import generate_sparse_linear_table

    cfg = L.SparseLinearCfg(in_features=16, out_features=12, fan_in=3,
                            bw_in=2)
    layer = L.sparse_linear_init(cfg, jax.random.PRNGKey(0))
    tt = generate_sparse_linear_table(cfg, layer, QuantizerCfg(2))
    x = jax.random.uniform(jax.random.PRNGKey(1), (40, 16), minval=-1,
                           maxval=3)
    c = qcodes(cfg.in_quant, x)
    want = layer_table_forward(tt, c)
    got = lut_lookup_pallas(c, jnp.asarray(tt.indices),
                            jnp.asarray(tt.table), tt.bw_in, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# masked_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 16, 8), (33, 70, 19), (128, 256, 64), (130, 100, 50),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_matmul_matches_ref(m, k, n, dtype):
    key = jax.random.PRNGKey(m * n)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (m, k), dtype)
    w = jax.random.normal(ks[1], (k, n), dtype)
    mask = (jax.random.uniform(ks[2], (k, n)) > 0.6).astype(dtype)
    b = jax.random.normal(ks[3], (n,), dtype)
    got = masked_matmul_pallas(x, w, mask, b, block_m=32, block_n=32,
                               block_k=32, interpret=True)
    want = ref.masked_matmul_ref(x, w, mask, b)
    atol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol,
                               rtol=1e-3)


def test_masked_matmul_respects_mask_exactly():
    """Zeroed weights contribute nothing even with huge magnitudes."""
    x = jnp.ones((4, 8))
    w = jnp.full((8, 4), 1e9)
    mask = jnp.zeros((8, 4)).at[0, :].set(1.0)
    got = masked_matmul_pallas(x, w, mask, interpret=True)
    np.testing.assert_allclose(np.asarray(got), 1e9)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (1, 2, 2, 64, 16),       # MHA
    (2, 4, 2, 96, 32),       # GQA, non-divisible seq vs block
    (1, 8, 1, 128, 16),      # MQA
    (2, 4, 4, 250, 8),       # ragged seq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal):
    key = jax.random.PRNGKey(s + hq)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=32,
                                 block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("window", [16, 64, 1024])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 16), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=1e-4)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 64, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 64, 32), jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=32,
                                 block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)
