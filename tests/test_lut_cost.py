"""Analytical LUT cost model vs the paper's own numbers (Tables 2.1, 6.1)."""

import pytest
from hypothesis_compat import given, settings, st  # real when installed

from repro.core import lut_cost as lc
from repro.core.logicnet import LogicNetCfg


# Table 2.1, byte-exact.
TABLE_2_1 = [
    # fan-in, n 6-LUTs, truth-table bits, LUT config bits, % utilized
    (6, 1, 64, 64, 100.0),
    (7, 3, 128, 192, 66.67),
    (8, 5, 256, 320, 80.0),
    (9, 11, 512, 704, 72.73),
    (10, 21, 1024, 1344, 76.19),
    (11, 43, 2048, 2752, 74.42),
]


@pytest.mark.parametrize("fan_in,n,tt,cfg,pct", TABLE_2_1)
def test_table_2_1_exact(fan_in, n, tt, cfg, pct):
    row = lc.static_mapping_row(fan_in)
    assert row.n_6luts == n
    assert row.truth_table_bits == tt
    assert row.lut_config_bits == cfg
    assert abs(row.pct_utilized - pct) < 0.01


@given(n=st.integers(min_value=6, max_value=40),
       m=st.integers(min_value=1, max_value=16))
@settings(max_examples=200, deadline=None)
def test_closed_form_matches_recursion(n, m):
    """Eq. (2.3) closed form == eq. (2.1) recursion."""
    assert lc.lut_cost(n, m) == lc.lut_cost_recursive(n, m)


@given(n=st.integers(min_value=6, max_value=40))
@settings(max_examples=100, deadline=None)
def test_cost_is_integer_and_monotone(n):
    assert lc.lut_cost_per_bit(n + 1) > lc.lut_cost_per_bit(n) >= 1
    # (2^(N-4) - (-1)^N) must be divisible by 3 for the formula to be exact
    assert (2 ** (n - 4) - (-1) ** n) % 3 == 0


def test_naive_truth_table_bits():
    # §1.2: 16-bit fixed point, fan-in 3 neuron => f: B^48 -> B^16,
    # "around 4.50e15 bits of storage" (output-only accounting).
    assert lc.truth_table_output_bits(48, 16) == pytest.approx(4.50e15,
                                                               rel=0.01)
    # §3 accounting stores inputs too: 2^ip * (op + ip).
    assert lc.truth_table_bits(48, 16) == (2 ** 48) * 64


def test_model_a_layer_luts_exact():
    """Table 6.1 Model A: HL (64,64,64), BW 3, X 3 -> 2112 per sparse layer."""
    cfg = LogicNetCfg(in_features=16, n_classes=5, hidden=(64, 64, 64),
                      fan_in=3, bw=3, final_dense=True, bw_fc=3)
    assert cfg.luts()[:3] == [2112, 2112, 2112]


def test_model_b_layer_luts_exact():
    """Table 6.1 Model B: HL (128,64,32), BW 3, X 3 -> 4224/2112/1056."""
    cfg = LogicNetCfg(in_features=16, n_classes=5, hidden=(128, 64, 32),
                      fan_in=3, bw=3, final_dense=True, bw_fc=3)
    assert cfg.luts()[:3] == [4224, 2112, 1056]


def test_model_c_layer_luts_exact():
    """Table 6.1 Model C: HL (64,32,32), BW 2, X 3 -> 128/64/64."""
    cfg = LogicNetCfg(in_features=16, n_classes=5, hidden=(64, 32, 32),
                      fan_in=3, bw=2, final_dense=True, bw_fc=2)
    assert cfg.luts()[:3] == [128, 64, 64]


def test_model_d_layer_luts_exact():
    """Table 6.1 Model D: HL (64,32,32), BW 2, X 5, X_fc 6, BW_fc 4
    -> 2688/1344/1344/3400 (all four sparse)."""
    cfg = LogicNetCfg(in_features=16, n_classes=5, hidden=(64, 32, 32),
                      fan_in=5, bw=2, final_dense=False, fan_in_fc=6,
                      bw_fc=4)
    assert cfg.luts() == [2688, 1344, 1344, 3400]


def test_model_e_layer_luts_exact():
    """Table 6.1 Model E: HL (64,64,64), BW 2, X 4, X_fc 4, BW_fc 4
    -> 640/640/640/200."""
    cfg = LogicNetCfg(in_features=16, n_classes=5, hidden=(64, 64, 64),
                      fan_in=4, bw=2, final_dense=False, fan_in_fc=4,
                      bw_fc=4)
    assert cfg.luts() == [640, 640, 640, 200]


def test_dense_cost_formula():
    # eq. 4.1 sanity: n(O)*(n(I)*BWin*BWwt*1.0699 + 10.779)
    assert lc.dense_quant_linear_cost(5, 32, 2, 4) == pytest.approx(
        5 * (32 * 2 * 4 * 1.0699 + 10.779))


def test_skip_connections_do_not_change_sparse_cost():
    """§7: 'As long as the per neuron fan-in remains the same, the LUT cost
    remains the same' — skips are LUT-free."""
    base = LogicNetCfg(in_features=16, n_classes=5, hidden=(64, 64, 64),
                       fan_in=3, bw=3, final_dense=True, bw_fc=3)
    skip = LogicNetCfg(in_features=16, n_classes=5, hidden=(64, 64, 64),
                       fan_in=3, bw=3, final_dense=True, bw_fc=3,
                       skips=((0, 2),))
    assert base.luts()[:3] == skip.luts()[:3]
