"""Fused whole-network LUT kernel vs the per-layer reference semantics.

``table_infer.network_table_forward`` names itself the kernel's reference
semantics; the contract here is bit-exactness against it across topology
shapes, bit-widths, the int8-packed vs unpacked table paths, and the
VMEM-overflow fallback to per-layer execution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st  # real when installed

from repro.core.table_infer import network_table_forward
from repro.core.truth_table import LayerTruthTable
from repro.kernels import ref
from repro.kernels.ops import lut_network
from repro.kernels.lut_network import (build_network_slabs,
                                       lut_network_pallas)


def _random_stack(widths, fan_ins, bws, seed=0):
    """(indices, table, bw_in) triples for a stack of random LUT layers."""
    rng = np.random.default_rng(seed)
    layers = []
    for (n_in, n_out), fi, bw in zip(zip(widths[:-1], widths[1:]),
                                     fan_ins, bws):
        fi = min(fi, n_in)
        idx = np.stack([np.sort(rng.choice(n_in, fi, replace=False))
                        for _ in range(n_out)]).astype(np.int32)
        tab = rng.integers(0, 2 ** bw, (n_out, 2 ** (fi * bw)),
                           dtype=np.int32)
        layers.append((idx, tab, bw))
    return layers


def _ref_forward(codes, layers):
    c = codes
    for idx, tab, bw in layers:
        c = ref.lut_lookup_ref(c, jnp.asarray(idx), jnp.asarray(tab), bw)
    return c


def _tables(layers):
    return [LayerTruthTable(tab, idx, bw, bw) for idx, tab, bw in layers]


@pytest.mark.parametrize("widths,fan_ins,bws,batch", [
    ((8, 8, 8), (2, 2), (1, 1), 4),             # minimal 2-layer binary
    ((16, 64, 64, 64), (3, 3, 3), (2, 2, 2), 37),   # model-A-like, ragged B
    ((16, 64, 32, 32, 5), (3, 4, 4, 5), (2, 2, 2, 2), 64),  # 4-layer, het FI
    ((12, 24, 10), (6, 3), (2, 2), 17),         # 12-bit tables, e-chunks
    ((16, 32, 16), (2, 2), (3, 3), 150),        # multi-block batch, bw 3
])
def test_fused_matches_network_table_forward(widths, fan_ins, bws, batch):
    layers = _random_stack(widths, fan_ins, bws, seed=sum(widths))
    codes = jnp.asarray(np.random.default_rng(batch).integers(
        0, 2 ** bws[0], (batch, widths[0]), dtype=np.int32))
    want = network_table_forward(_tables(layers), codes)
    got = lut_network_pallas(codes, build_network_slabs(layers),
                             block_b=32, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_packed_and_unpacked_paths_agree():
    layers = _random_stack((16, 48, 48, 24), (3, 3, 3), (2, 2, 2), seed=5)
    codes = jnp.asarray(np.random.default_rng(1).integers(
        0, 4, (40, 16), dtype=np.int32))
    want = _ref_forward(codes, layers)

    packed = build_network_slabs(layers, pack=True)
    unpacked = build_network_slabs(layers, pack=False)
    assert packed.packed and packed.table_slab.dtype == jnp.int8
    assert not unpacked.packed and unpacked.table_slab.dtype == jnp.int32
    # int8 packing quarters the table slab footprint
    assert packed.vmem_bytes() < unpacked.vmem_bytes()

    for slabs in (packed, unpacked):
        got = lut_network_pallas(codes, slabs, block_b=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_explicit_pack_wide_codes_raise():
    """Regression: pack=True used to uint8-wrap codes >= 256 into corrupt
    tables silently; it must refuse the out-of-range request instead."""
    layers = _random_stack((8, 8, 8), (2, 2), (2, 2), seed=2)
    idx, tab, bw = layers[-1]
    layers[-1] = (idx, tab + 300, bw)
    with pytest.raises(ValueError, match="pack=True"):
        build_network_slabs(layers, pack=True)
    # in-range tables still pack explicitly, bit-exactly
    layers = _random_stack((8, 8, 8), (2, 2), (2, 2), seed=2)
    slabs = build_network_slabs(layers, pack=True)
    assert slabs.packed
    codes = jnp.asarray(np.random.default_rng(0).integers(
        0, 4, (9, 8), dtype=np.int32))
    got = lut_network_pallas(codes, slabs, block_b=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(_ref_forward(codes, layers)))


def test_empty_and_ragged_batch_edges():
    """Regression: batch == 0 used to build a zero-size grid via
    min(block_b, 0); both kernels must return an empty result instead, and
    a batch that is not a multiple of block_b must mask correctly."""
    from repro.kernels.lut_lookup import lut_lookup_pallas

    layers = _random_stack((8, 12, 6), (2, 2), (2, 2), seed=6)
    slabs = build_network_slabs(layers)
    empty = lut_network_pallas(jnp.zeros((0, 8), jnp.int32), slabs,
                               interpret=True)
    assert empty.shape == (0, 6) and empty.dtype == jnp.int32
    idx, tab, bw = layers[0]
    empty = lut_lookup_pallas(jnp.zeros((0, 8), jnp.int32),
                              jnp.asarray(idx), jnp.asarray(tab), bw,
                              interpret=True)
    assert empty.shape == (0, 12) and empty.dtype == jnp.int32
    # ops-level: both the fused route and the per-layer fallback
    empty = lut_network(jnp.zeros((0, 8), jnp.int32), layers)
    assert empty.shape == (0, 6)
    empty = lut_network(jnp.zeros((0, 8), jnp.int32), layers, fused=False)
    assert empty.shape == (0, 6)
    # ragged batch: 11 rows through block_b=8 needs a masked final block
    codes = jnp.asarray(np.random.default_rng(3).integers(
        0, 4, (11, 8), dtype=np.int32))
    got = lut_network_pallas(codes, slabs, block_b=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(_ref_forward(codes, layers)))


def test_per_layer_fallback_reuses_jit_cache():
    """Regression: the per-layer fallback used to re-trace every layer on
    every call; routed through the engine's shared jitted chain (and the
    identity-keyed memo), repeated calls must add no traces, no memo
    entries and no compiler runs."""
    from repro import engine

    layers = _random_stack((8, 10, 6), (2, 2), (2, 2), seed=12)
    codes = jnp.asarray(np.random.default_rng(5).integers(
        0, 4, (7, 8), dtype=np.int32))
    want = np.asarray(_ref_forward(codes, layers))
    got = lut_network(codes, layers, fused=False)   # traces the chain once
    np.testing.assert_array_equal(np.asarray(got), want)
    traces = engine.engine._per_layer_forward._cache_size()
    memo, runs = engine.cache_size(), engine.compile_runs()
    for _ in range(3):
        got = lut_network(codes, layers, fused=False)
    np.testing.assert_array_equal(np.asarray(got), want)
    assert engine.engine._per_layer_forward._cache_size() == traces
    assert engine.cache_size() == memo
    assert engine.compile_runs() == runs


def test_auto_pack_declines_wide_codes():
    """Tables holding codes >= 256 must not be byte-packed."""
    layers = _random_stack((8, 8, 8), (2, 2), (2, 2), seed=2)
    idx, tab, bw = layers[-1]
    layers[-1] = (idx, tab + 300, bw)           # out codes exceed a byte
    slabs = build_network_slabs(layers)
    assert not slabs.packed
    codes = jnp.asarray(np.random.default_rng(0).integers(
        0, 4, (9, 8), dtype=np.int32))
    got = lut_network_pallas(codes, slabs, block_b=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(_ref_forward(codes, layers)))


def test_wide_codes_rejected_by_builder_and_fall_back_in_ops():
    """Output codes >= 2^24 would round in the kernel's f32 one-hot gather:
    build_network_slabs must refuse them, and ops.lut_network must route
    to the (integer, exact) per-layer path instead."""
    layers = _random_stack((8, 8), (2,), (2,), seed=11)
    idx, tab, bw = layers[0]
    layers[0] = (idx, tab + (1 << 24), bw)
    with pytest.raises(ValueError, match="f32"):
        build_network_slabs(layers)
    codes = jnp.asarray(np.random.default_rng(4).integers(
        0, 4, (6, 8), dtype=np.int32))
    got = lut_network(codes, layers)            # silent per-layer fallback
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(_ref_forward(codes, layers)))


def test_vmem_overflow_falls_back_to_per_layer():
    """A tiny budget must route through per-layer lut_lookup, bit-exactly."""
    layers = _random_stack((16, 32, 32, 16), (3, 3, 3), (2, 2, 2), seed=3)
    codes = jnp.asarray(np.random.default_rng(2).integers(
        0, 4, (21, 16), dtype=np.int32))
    want = _ref_forward(codes, layers)
    slabs = build_network_slabs(layers)
    assert slabs.vmem_bytes() > 64          # budget below any real slab
    got = lut_network(codes, layers, vmem_budget_bytes=64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got = lut_network(codes, layers, fused=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_path_through_core_api():
    """network_table_forward(fused=True) == its own jnp semantics."""
    layers = _random_stack((16, 24, 24, 12), (3, 3, 3), (2, 2, 2), seed=7)
    tables = _tables(layers)
    codes = jnp.asarray(np.random.default_rng(3).integers(
        0, 4, (33, 16), dtype=np.int32))
    want = network_table_forward(tables, codes)
    got = network_table_forward(tables, codes, fused=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_matches_generated_tables():
    """End-to-end on real generated truth tables (fpga4hep model C shape)."""
    from repro.configs import fpga4hep
    from repro.core import logicnet as LN

    cfg = fpga4hep.model_c()
    model = LN.init(cfg, jax.random.PRNGKey(0))
    tables = LN.generate_tables(cfg, model)
    x = jax.random.uniform(jax.random.PRNGKey(1), (48, cfg.in_features),
                           minval=-1, maxval=3)
    float_codes, fused_codes = LN.verify_tables(cfg, model, tables, x,
                                                fused=True)
    np.testing.assert_array_equal(np.asarray(float_codes),
                                  np.asarray(fused_codes))


# ---------------------------------------------------------------------------
# hypothesis-driven sweep (skipped when hypothesis isn't installed)
# ---------------------------------------------------------------------------


@given(data=st.data())
@settings(max_examples=25, deadline=None)
def test_fused_bit_exact_hypothesis(data):
    n_layers = data.draw(st.integers(2, 4), label="n_layers")
    widths = [data.draw(st.integers(4, 24), label=f"w{i}")
              for i in range(n_layers + 1)]
    bws = [data.draw(st.integers(1, 3), label=f"bw{i}")
           for i in range(n_layers)]
    fan_ins = []
    for i in range(n_layers):
        max_fi = max(1, min(widths[i], 10 // bws[i]))
        fan_ins.append(data.draw(st.integers(1, max_fi), label=f"fi{i}"))
    batch = data.draw(st.integers(1, 40), label="batch")
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")

    layers = _random_stack(widths, fan_ins, bws, seed=seed)
    # chain input codes must respect each layer's input bit-width: layer
    # i+1 reads layer i's output codes, so feed bw-consistent tables only.
    for i in range(n_layers - 1):
        idx, tab, bw = layers[i]
        layers[i] = (idx, tab % (2 ** bws[i + 1]), bw)

    codes = jnp.asarray(np.random.default_rng(seed).integers(
        0, 2 ** bws[0], (batch, widths[0]), dtype=np.int32))
    want = _ref_forward(codes, layers)
    got = lut_network_pallas(codes, build_network_slabs(layers),
                             block_b=16, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
