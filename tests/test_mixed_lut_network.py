"""Mixed-width fused LUT kernel: compiler-exact slabs vs every other path.

The contract under test: ``lut_network_mixed_pallas`` over
``CNet.to_mixed_tables()`` slabs is bit-exact with the per-layer jnp
reference, the uniform fused kernel, and the emitted Verilog — while its
table slab costs exactly the bytes the compiler's per-neuron accounting
proves (no padding to the widest feature or largest entry count).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st  # real when installed

from repro import compile as C
from repro.core import logicnet as LN
from repro.core.table_infer import network_table_forward
from repro.kernels import ref
from repro.kernels.lut_network import (build_mixed_network_slabs,
                                       build_network_slabs,
                                       estimate_mixed_slab_bytes,
                                       lut_network_mixed_pallas,
                                       lut_network_pallas)
from repro.kernels.ops import fused_plan, lut_network


def _random_stack(widths, fan_ins, bws, seed=0):
    rng = np.random.default_rng(seed)
    layers = []
    for (n_in, n_out), fi, bw in zip(zip(widths[:-1], widths[1:]),
                                     fan_ins, bws):
        fi = min(fi, n_in)
        idx = np.stack([np.sort(rng.choice(n_in, fi, replace=False))
                        for _ in range(n_out)]).astype(np.int32)
        tab = rng.integers(0, 2 ** bw, (n_out, 2 ** (fi * bw)),
                           dtype=np.int32)
        layers.append((idx, tab, bw))
    return layers


def _het_fan_in_stack(widths, bws, fan_in_choices, seed=0):
    """A stack whose *per-neuron* fan-ins differ — ragged entry counts.

    Uniform ``LayerTruthTable`` cannot express this, so it is built as a
    ``CNet`` directly (the IR the compiler's passes produce); the uniform
    lowering pads it back, the mixed lowering keeps it exact.
    """
    rng = np.random.default_rng(seed)
    layers = []
    for li, ((n_in, n_out), bw) in enumerate(zip(zip(widths[:-1],
                                                     widths[1:]), bws)):
        neurons = []
        for _ in range(n_out):
            fi = min(int(rng.choice(fan_in_choices)), n_in)
            idx = np.sort(rng.choice(n_in, fi, replace=False)).astype(
                np.int32)
            bw_out = bws[li + 1] if li + 1 < len(bws) else bw
            tab = rng.integers(0, 2 ** bw_out, 2 ** (fi * bw),
                               dtype=np.int32)
            neurons.append(C.CNeuron(idx, tab))
        bw_out = bws[li + 1] if li + 1 < len(bws) else bw
        layers.append(C.CLayer(neurons, bw, bw_out))
    net = C.CNet(widths[0], layers)
    net.validate()
    return net


def _ref_forward(codes, layers):
    c = codes
    for idx, tab, bw in layers:
        c = ref.lut_lookup_ref(c, jnp.asarray(idx), jnp.asarray(tab), bw)
    return c


def test_mixed_matches_reference_on_heterogeneous_fan_ins():
    """Ragged per-neuron fan-ins: mixed slabs are exact, smaller, bit-equal."""
    net = _het_fan_in_stack((10, 16, 12, 8), (2, 2, 2), (1, 2, 3), seed=3)
    mixed = net.to_mixed_tables()
    slabs = build_mixed_network_slabs(mixed)
    uni = build_network_slabs(
        [(tt.indices, tt.table, tt.bw_in) for tt in net.to_tables()])
    # the uniform layout pads every neuron to the layer's max fan-in; the
    # mixed table slab stores exactly sum_j 2^(sum of widths_j) entries
    assert (slabs.vmem_breakdown()["table_slab_bytes"]
            < uni.vmem_breakdown()["table_slab_bytes"])
    codes = jnp.asarray(np.random.default_rng(0).integers(
        0, 4, (23, 10), dtype=np.int32))
    want = C.forward_codes(net, np.asarray(codes))
    got = lut_network_mixed_pallas(codes, slabs, block_b=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)
    got_uni = lut_network_pallas(codes, uni, block_b=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_uni), want)


def test_mixed_group_sort_restores_output_order():
    """A final layer with interleaved entry counts forces a non-trivial
    out_perm; the kernel's static column shuffle must undo it exactly."""
    net = _het_fan_in_stack((8, 8, 9), (2, 2), (1, 3), seed=17)
    # interleave fan-ins by hand so the stable sort is not the identity
    lay = net.layers[-1]
    fis = [n.fan_in for n in lay.neurons]
    assert len(set(fis)) > 1, "seed must give mixed fan-ins"
    slabs = build_mixed_network_slabs(net.to_mixed_tables())
    assert slabs.out_perm is not None
    codes = jnp.asarray(np.random.default_rng(5).integers(
        0, 4, (11, 8), dtype=np.int32))
    want = C.forward_codes(net, np.asarray(codes))
    got = lut_network_mixed_pallas(codes, slabs, block_b=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_mixed_packed_boundary_codes():
    """Packed-int8 boundary codes 0/255 must survive the uint8 view and the
    in-kernel widening mask on both the packed and unpacked paths."""
    layers = _random_stack((8, 10, 6), (2, 2), (2, 2), seed=9)
    idx, tab, bw = layers[-1]
    layers[-1] = (idx, (tab % 2) * 255, bw)     # outputs are exactly {0, 255}
    tables = C.tables_from_triples(layers)
    net = C.CNet.from_tables(tables, in_features=8)
    mixed = net.to_mixed_tables()
    codes = jnp.asarray(np.random.default_rng(2).integers(
        0, 4, (19, 8), dtype=np.int32))
    want = np.asarray(_ref_forward(codes, layers))
    assert set(np.unique(want)) <= {0, 255}

    packed = build_mixed_network_slabs(mixed, pack=True)
    unpacked = build_mixed_network_slabs(mixed, pack=False)
    assert packed.packed and packed.table_slab.dtype == jnp.int8
    assert not unpacked.packed and unpacked.table_slab.dtype == jnp.int32
    assert packed.vmem_bytes() < unpacked.vmem_bytes()
    for slabs in (packed, unpacked):
        got = lut_network_mixed_pallas(codes, slabs, block_b=8,
                                       interpret=True)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_mixed_pack_true_wide_codes_raise():
    """Explicit pack=True with codes >= 256 must raise, not wrap (the same
    contract as build_network_slabs after the uint8-wraparound fix)."""
    layers = _random_stack((6, 6), (2,), (2,), seed=4)
    idx, tab, bw = layers[0]
    layers[0] = (idx, tab + 300, bw)
    net = C.CNet.from_tables(C.tables_from_triples(layers), in_features=6)
    mixed = net.to_mixed_tables()
    with pytest.raises(ValueError, match="pack=True"):
        build_mixed_network_slabs(mixed, pack=True)
    slabs = build_mixed_network_slabs(mixed)      # auto declines packing
    assert not slabs.packed


def test_mixed_empty_and_ragged_batch():
    net = _het_fan_in_stack((6, 8, 5), (2, 2), (1, 2), seed=1)
    slabs = build_mixed_network_slabs(net.to_mixed_tables())
    empty = lut_network_mixed_pallas(jnp.zeros((0, 6), jnp.int32), slabs,
                                     interpret=True)
    assert empty.shape == (0, 5) and empty.dtype == jnp.int32
    codes = jnp.asarray(np.random.default_rng(8).integers(
        0, 4, (13, 6), dtype=np.int32))          # 13 % block_b != 0
    want = C.forward_codes(net, np.asarray(codes))
    got = lut_network_mixed_pallas(codes, slabs, block_b=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_fused_plan_mixed_unlocks_overflowing_stack():
    """A stack whose uniform slabs overflow the VMEM budget but whose
    compact mixed slabs fit must take the fused path via optimize_level."""
    rng = np.random.default_rng(7)
    n_in, n_out, bw = 12, 24, 2
    # one wide neuron (fan-in 6 -> 4096 entries) among single-input ones:
    # the uniform layout pads all 24 neurons to 4096 entries each
    neurons = []
    for j in range(n_out):
        fi = 6 if j == 0 else 1
        idx = np.sort(rng.choice(n_in, fi, replace=False)).astype(np.int32)
        tab = rng.integers(0, 2 ** bw, 2 ** (fi * bw), dtype=np.int32)
        neurons.append(C.CNeuron(idx, tab))
    net = C.CNet(n_in, [C.CLayer(neurons, bw, bw)])
    net.validate()
    uniform = [(tt.indices, tt.table, tt.bw_in) for tt in net.to_tables()]
    mixed = net.to_mixed_tables()
    budget = 40_000     # between the two footprints
    u_plan = fused_plan(uniform, budget)
    m_plan = fused_plan(mixed, budget)
    assert not u_plan.fused and u_plan.reason == "slab_exceeds_vmem_budget"
    assert m_plan.fused and m_plan.layout == "mixed"
    assert m_plan.slab_bytes < u_plan.slab_bytes

    # the estimate is the pre-dedup upper bound; the built slab may come
    # in under it by exactly the shared entries (1 byte each when packed)
    est_bytes, pack, f32 = estimate_mixed_slab_bytes(mixed)
    slabs = build_mixed_network_slabs(mixed, pack=pack)
    assert pack and f32
    assert est_bytes - slabs.dedup_entries_saved == slabs.vmem_bytes()

    codes = jnp.asarray(rng.integers(0, 2 ** bw, (9, n_in), dtype=np.int32))
    want = C.forward_codes(net, np.asarray(codes))
    got = lut_network_mixed_pallas(codes, slabs, block_b=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_lut_network_routes_optimize_level_through_mixed():
    """ops.lut_network(optimize_level=...) must execute the compact slabs
    and stay bit-exact with the raw per-layer reference."""
    layers = _random_stack((12, 20, 16, 8), (3, 3, 3), (2, 2, 2), seed=13)
    codes = jnp.asarray(np.random.default_rng(1).integers(
        0, 4, (27, 12), dtype=np.int32))
    want = np.asarray(_ref_forward(codes, layers))
    for level in (1, 2, 3):
        got = np.asarray(lut_network(codes, layers, optimize_level=level))
        np.testing.assert_array_equal(got, want)
    # and through the core API (the deployment entry points)
    tables = C.tables_from_triples(layers)
    got = np.asarray(network_table_forward(tables, codes, fused=True,
                                           optimize_level=3))
    np.testing.assert_array_equal(got, want)


def test_mixed_slab_banks_compiler_bytes_on_model_a():
    """Acceptance: on the generated fpga4hep model A stack at level 3 the
    fused table slab costs within 10% of the netlist's exact packed bytes
    (37504 B on the reference build, ~98304 B uniform), bit-exactly."""
    from repro.configs import fpga4hep

    cfg = fpga4hep.model_a()
    model = LN.init(cfg, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (256, cfg.in_features),
                           minval=-1, maxval=3)
    _, model = LN.forward(cfg, model, x, train=True)
    tables = LN.generate_tables(cfg, model)
    res = C.optimize(tables, level=3, in_features=cfg.in_features)

    exact_bytes = res.cnet.table_bytes()
    slabs = build_mixed_network_slabs(res.mixed_tables)
    breakdown = slabs.vmem_breakdown()
    assert slabs.packed  # bw <= 8: packed table slab is byte-per-entry
    assert breakdown["table_slab_bytes"] <= exact_bytes * 1.10
    # and the savings are real against the raw uniform slab
    raw = build_network_slabs(
        [(tt.indices, tt.table, tt.bw_in) for tt in tables])
    assert (breakdown["table_slab_bytes"]
            < 0.5 * raw.vmem_breakdown()["table_slab_bytes"])

    codes_in = jnp.asarray(np.random.default_rng(0).integers(
        0, 2 ** cfg.bw, (64, cfg.in_features), dtype=np.int32))
    want = np.asarray(network_table_forward(tables, codes_in))
    got = np.asarray(lut_network_mixed_pallas(codes_in, slabs, block_b=32,
                                              interpret=True))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# slab row-dedup: identical table rows stored once, indirected by offsets
# ---------------------------------------------------------------------------


def _duplicate_row_stack(seed=0):
    """A stack where several neurons share identical table content."""
    layers = _random_stack((8, 12, 6), (2, 2), (2, 2), seed=seed)
    for li in range(len(layers)):
        idx, tab, bw = layers[li]
        tab = tab.copy()
        tab[1::2] = tab[0]          # every odd neuron mirrors neuron 0
        layers[li] = (idx, tab, bw)
    return layers


def test_slab_row_dedup_shares_identical_rows():
    layers = _duplicate_row_stack(seed=6)
    net = C.CNet.from_tables(C.tables_from_triples(layers), in_features=8)
    mixed = net.to_mixed_tables()
    deduped = build_mixed_network_slabs(mixed)
    plain = build_mixed_network_slabs(mixed, dedup=False)
    assert plain.dedup_entries_saved == 0
    assert all(g.offs is None for m in plain.meta for g in m.groups)
    assert deduped.dedup_entries_saved > 0
    assert any(g.offs is not None for m in deduped.meta
               for g in m.groups)
    assert (deduped.vmem_breakdown()["table_slab_bytes"]
            < plain.vmem_breakdown()["table_slab_bytes"])
    # estimate_mixed_slab_bytes stays the pre-dedup upper bound
    est_bytes, pack, _ = estimate_mixed_slab_bytes(mixed)
    assert deduped.vmem_bytes() < est_bytes == plain.vmem_bytes()
    codes = jnp.asarray(np.random.default_rng(1).integers(
        0, 4, (17, 8), dtype=np.int32))
    want = C.forward_codes(net, np.asarray(codes))
    for slabs in (deduped, plain):
        got = lut_network_mixed_pallas(codes, slabs, block_b=8,
                                       interpret=True)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_slab_dedup_noop_without_duplicates():
    """All-or-nothing contract: a build with zero duplicate rows is
    byte-identical to the legacy contiguous layout (offs stay None)."""
    net = _het_fan_in_stack((10, 16, 12, 8), (2, 2, 2), (1, 2, 3), seed=3)
    mixed = net.to_mixed_tables()
    deduped = build_mixed_network_slabs(mixed)
    plain = build_mixed_network_slabs(mixed, dedup=False)
    if deduped.dedup_entries_saved == 0:
        assert all(g.offs is None for m in deduped.meta
                   for g in m.groups)
        np.testing.assert_array_equal(np.asarray(deduped.table_slab),
                                      np.asarray(plain.table_slab))
    codes = jnp.asarray(np.random.default_rng(4).integers(
        0, 4, (9, 10), dtype=np.int32))
    want = C.forward_codes(net, np.asarray(codes))
    got = lut_network_mixed_pallas(codes, deduped, block_b=4,
                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_dedup_slabs_roundtrip_engine_artifact(tmp_path):
    """Format-3 engine artifacts persist the dedup offsets: a reloaded
    CompiledLUTNet keeps the shared slab and stays bit-exact."""
    from repro import engine

    layers = _duplicate_row_stack(seed=11)
    tables = C.tables_from_triples(layers)
    net = engine.compile_network(tables, optimize_level=3, in_features=8)
    assert net.layout == "mixed"
    saved = net.slabs.dedup_entries_saved
    assert saved > 0
    path = tmp_path / "dedup_model.npz"
    net.save(str(path))
    fresh = engine.load(str(path))
    assert fresh.slabs.dedup_entries_saved == saved
    assert ([g for m in fresh.slabs.meta for g in m.groups]
            == [g for m in net.slabs.meta for g in m.groups])
    codes = np.random.default_rng(2).integers(0, 4, (21, 8),
                                              dtype=np.int32)
    np.testing.assert_array_equal(np.asarray(fresh(codes)),
                                  np.asarray(net(codes)))


# ---------------------------------------------------------------------------
# three-path sweep: fused-mixed == per-layer == Verilog on optimized stacks
# (deterministic cases always run; the hypothesis sweep widens them in CI)
# ---------------------------------------------------------------------------


def _check_three_paths(widths, fan_ins, bws, seed, *,
                       constant_feature=False, boundary_codes=False):
    """Raw stack -> level-3 compile -> mixed-fused / per-layer / Verilog."""
    import re

    from repro.core.verilog import evaluate_verilog, generate_verilog

    n_layers = len(bws)
    layers = _random_stack(widths, fan_ins, bws, seed=seed)
    for i in range(n_layers - 1):
        idx, tab, bw = layers[i]
        layers[i] = (idx, tab % (2 ** bws[i + 1]), bw)
    if constant_feature:
        # k=1 collapse: a constant producer narrows to the 1-bit minimum
        # and its consumers' elements prune away in the same fixpoint
        idx, tab, bw = layers[0]
        tab = tab.copy()
        tab[0, :] = tab[0, 0]
        layers[0] = (idx, tab, bw)
    if boundary_codes:
        # exercise the packed-int8 byte boundaries on the output bus
        idx, tab, bw = layers[-1]
        layers[-1] = (idx, (tab % 2) * 255, bw)

    in_features, bw0 = widths[0], bws[0]
    tables = C.tables_from_triples(layers)
    res = C.optimize(tables, level=3, in_features=in_features)

    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 2 ** bw0, (9, in_features),
                                     dtype=np.int32))
    want = np.asarray(_ref_forward(codes, layers))

    # fused-mixed (direct slabs) == per-layer (uniform lowering) == raw
    slabs = build_mixed_network_slabs(res.mixed_tables)
    got_mixed = np.asarray(lut_network_mixed_pallas(codes, slabs,
                                                    block_b=4,
                                                    interpret=True))
    np.testing.assert_array_equal(got_mixed, want)
    got_pl = np.asarray(network_table_forward(res.tables, codes))
    np.testing.assert_array_equal(got_pl, want)

    # Verilog on a few sampled words (the netlist keeps compact wires)
    files = generate_verilog(res.netlist)
    vl_layers = 1 + max(int(m.group(1)) for m in
                        (re.match(r"LUTLayer(\d+)\.v$", f) for f in files)
                        if m)
    bw_out = tables[-1].bw_out
    o_last = tables[-1].out_features
    for _ in range(3):
        word = int(rng.integers(0, 2 ** (bw0 * in_features)))
        digits = [(word >> (bw0 * f)) & (2 ** bw0 - 1)
                  for f in range(in_features)]
        expect = np.asarray(_ref_forward(
            jnp.asarray([digits], jnp.int32), layers))[0]
        out_word = evaluate_verilog(files, word, n_layers=vl_layers)
        got = [(out_word >> (bw_out * j)) & (2 ** bw_out - 1)
               for j in range(o_last)]
        assert got == [int(v) for v in expect], f"word={word}"


@pytest.mark.parametrize("widths,fan_ins,bws,seed,kw", [
    ((6, 8, 5), (2, 3), (2, 2), 21, {}),
    ((5, 7, 7, 4), (2, 2, 3), (1, 2, 1), 33, {"constant_feature": True}),
    ((8, 6, 6), (3, 2), (2, 1), 54, {"boundary_codes": True}),
    ((4, 9, 4), (2, 2), (1, 1), 77, {"constant_feature": True,
                                     "boundary_codes": True}),
])
def test_mixed_fused_per_layer_verilog_bit_exact(widths, fan_ins, bws,
                                                 seed, kw):
    _check_three_paths(widths, fan_ins, bws, seed, **kw)


@given(data=st.data())
@settings(max_examples=12, deadline=None)
def test_mixed_fused_per_layer_verilog_bit_exact_hypothesis(data):
    """Ragged fan-ins and widths through the level-3 compiler: the mixed
    fused kernel, the per-layer path and the emitted Verilog agree on
    every sampled input.  Includes k=1 collapsed features (constant
    producers) and packed-int8 boundary codes {0, 255}."""
    n_layers = data.draw(st.integers(2, 3), label="n_layers")
    widths = [data.draw(st.integers(3, 8), label=f"w{i}")
              for i in range(n_layers + 1)]
    bws = [data.draw(st.integers(1, 2), label=f"bw{i}")
           for i in range(n_layers)]
    fan_ins = [data.draw(st.integers(1, max(1, min(widths[i], 6 // bws[i]))),
                         label=f"fi{i}")
               for i in range(n_layers)]
    seed = data.draw(st.integers(0, 2 ** 16), label="seed")
    _check_three_paths(
        widths, fan_ins, bws, seed,
        constant_feature=data.draw(st.booleans(), label="constant_feature"),
        boundary_codes=data.draw(st.booleans(), label="boundary_codes"))
