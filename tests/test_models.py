"""Model correctness beyond smoke: SSD vs naive recurrence, chunked
attention vs dense reference, decode-vs-forward consistency, MoE dispatch
equivalence, sharding rule resolution."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Dense-reference equivalence sweeps run 5-15 s per case; excluded from fast CI.
pytestmark = pytest.mark.slow

from repro.configs import get_smoke_config
from repro.kernels.ref import flash_attention_ref
from repro.models import attention as ATT
from repro.models import model as M
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelCfg, MoECfg


# ---------------------------------------------------------------------------
# SSD: chunked scan == naive O(S^2) recurrence
# ---------------------------------------------------------------------------

def _naive_ssd(x, a, b, c):
    """h_t = exp(a_t) h_{t-1} + B_t x_t^T ; y_t = C_t h_t (per head)."""
    bs, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bf = np.repeat(np.asarray(b, np.float64), rep, axis=2)
    cf = np.repeat(np.asarray(c, np.float64), rep, axis=2)
    xf = np.asarray(x, np.float64)
    af = np.asarray(a, np.float64)
    y = np.zeros((bs, s, h, p))
    hstate = np.zeros((bs, h, p, n))
    for t in range(s):
        decay = np.exp(af[:, t])[:, :, None, None]
        hstate = hstate * decay + np.einsum("bhp,bhn->bhpn", xf[:, t],
                                            bf[:, t])
        y[:, t] = np.einsum("bhpn,bhn->bhp", hstate, cf[:, t])
    return y


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_naive(chunk):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    bs, s, h, p, g, n = 2, 32, 4, 8, 2, 8
    x = jax.random.normal(ks[0], (bs, s, h, p), jnp.float32)
    a = -jnp.abs(jax.random.normal(ks[1], (bs, s, h))) * 0.5
    b = jax.random.normal(ks[2], (bs, s, g, n), jnp.float32) * 0.3
    c = jax.random.normal(ks[3], (bs, s, g, n), jnp.float32) * 0.3
    y, final = SSM.ssd_chunked(x, a, b, c, chunk)
    want = _naive_ssd(x, a, b, c)
    np.testing.assert_allclose(np.asarray(y, np.float64), want, atol=2e-4)


def test_ssd_decode_matches_prefill():
    """Token-by-token ssm_decode == full-sequence ssm_apply."""
    cfg = get_smoke_config("mamba2-370m")
    key = jax.random.PRNGKey(1)
    p = SSM.ssm_init(key, cfg, jnp.float32)
    s = 16
    u = jax.random.normal(key, (2, s, cfg.d_model), jnp.float32) * 0.5
    cfg16 = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                             chunk=8))
    full = SSM.ssm_apply(p, cfg16, u)
    state = SSM.ssm_decode_state(cfg, 2)
    outs = []
    for t in range(s):
        y, state = SSM.ssm_decode(p, cfg, u[:, t:t + 1], state)
        outs.append(y)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               atol=2e-3, rtol=1e-2)


# ---------------------------------------------------------------------------
# Chunked attention == dense reference; decode == prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk,window", [(8, 0), (16, 0), (8, 12)])
def test_chunked_attention_matches_dense(chunk, window):
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    b, s, hq, hkv, d = 2, 64, 4, 2, 16
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    got = ATT._chunked_attention(q, k, v, q_offset=0, window=window,
                                 causal=True, chunk=chunk)
    want = flash_attention_ref(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True,
                               window=window or None)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.transpose(0, 2, 1, 3)),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-27b",
                                  "qwen2-vl-2b"])
def test_decode_matches_forward(arch):
    """Feeding tokens one-by-one through decode_step reproduces forward
    logits (the KV-cache correctness contract)."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, vision_tokens=0, mrope=False)
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    s = 12
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, s), 0,
                                cfg.vocab)
    ref_logits, _ = M.forward(params, cfg, {"tokens": tokens})
    cache = M.init_cache(cfg, 2, s)
    got = []
    for t in range(s):
        logits, cache = M.decode_step(params, cfg, cache,
                                      tokens[:, t:t + 1],
                                      jnp.full((2,), t, jnp.int32))
        got.append(logits[:, 0, :])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref_logits, np.float32),
        atol=0.05, rtol=0.05)


def test_decode_cache_update_dus_matches_onehot():
    """The O(1)-traffic dynamic_update_slice cache write (§Perf) is
    numerically identical to the baseline one-hot blend when all rows
    share the step position (the lowered serve_step shape)."""
    base = get_smoke_config("qwen3-1.7b")
    params = M.init_params(base, jax.random.PRNGKey(9))
    tokens = jax.random.randint(jax.random.PRNGKey(10), (2, 6), 0,
                                base.vocab)
    outs = {}
    for mode in ("onehot", "dus"):
        cfg = dataclasses.replace(base, cache_update=mode)
        cache = M.init_cache(cfg, 2, 8)
        got = []
        for t in range(6):
            logits, cache = M.decode_step(params, cfg, cache,
                                          tokens[:, t:t + 1],
                                          jnp.full((2,), t, jnp.int32))
            got.append(logits)
        outs[mode] = jnp.stack(got)
    np.testing.assert_allclose(np.asarray(outs["onehot"], np.float32),
                               np.asarray(outs["dus"], np.float32),
                               atol=1e-2, rtol=1e-2)


def test_hybrid_decode_matches_forward():
    cfg = get_smoke_config("zamba2-2.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    s = 8
    tokens = jax.random.randint(jax.random.PRNGKey(6), (1, s), 0, cfg.vocab)
    ref_logits, _ = M.forward(params, cfg, {"tokens": tokens})
    cache = M.init_cache(cfg, 1, s)
    got = []
    for t in range(s):
        logits, cache = M.decode_step(params, cfg, cache,
                                      tokens[:, t:t + 1],
                                      jnp.full((1,), t, jnp.int32))
        got.append(logits[:, 0, :])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref_logits, np.float32),
        atol=0.08, rtol=0.08)


# ---------------------------------------------------------------------------
# MoE: dense dispatch == sorted dispatch (ample capacity)
# ---------------------------------------------------------------------------

def test_moe_dispatch_paths_agree():
    cfg = ModelCfg(arch_id="t", n_layers=1, d_model=32, n_heads=4,
                   n_kv_heads=4, d_ff=16, vocab=64,
                   moe=MoECfg(n_experts=4, top_k=2, capacity_factor=4.0))
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32), jnp.float32)
    dense, aux_d = MOE.moe_apply_dense(p, cfg, x)
    srt, aux_s = MOE.moe_apply_sorted(p, cfg, x)
    loc, aux_l = MOE.moe_apply_sorted_local(p, cfg, x)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(srt),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(loc),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(float(aux_d), float(aux_s), rtol=1e-5)
    np.testing.assert_allclose(float(aux_d), float(aux_l), rtol=1e-5)


def test_moe_capacity_drops_when_tight():
    cfg = ModelCfg(arch_id="t", n_layers=1, d_model=16, n_heads=4,
                   n_kv_heads=4, d_ff=8, vocab=64,
                   moe=MoECfg(n_experts=2, top_k=2, capacity_factor=0.25))
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16), jnp.float32)
    out, _ = MOE.moe_apply_dense(p, cfg, x)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# Sharding rules resolve sanely
# ---------------------------------------------------------------------------

def test_sharding_rules_resolution():
    import os
    from repro.parallel import sharding as SH
    if len(jax.devices()) != 1:
        pytest.skip("expects the default single-device test env")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    policy = SH.ShardingPolicy()
    # kv heads = 4 cannot shard a 16-way axis -> falls back to None
    spec = SH.resolve_spec((28, 2048, 4, 128), (None, "fsdp", "tp", None),
                           policy, mesh)
    assert spec == jax.sharding.PartitionSpec(None, "data", "model", None) \
        or spec is not None  # on 1x1 mesh everything divides
    # path matching
    s = SH.spec_for_path("['layers']['attn']['wq']", (2, 64, 4, 16),
                         policy, mesh)
    assert s[1] == "data" and s[2] == "model"
    s = SH.spec_for_path("['embed']['tok']", (512, 64), policy, mesh)
    assert s[0] == "model"
    s = SH.spec_for_path("['final_norm']", (64,), policy, mesh)
    assert s == jax.sharding.PartitionSpec()


def test_sharding_divisibility_fallback():
    from repro.parallel import sharding as SH
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    policy = SH.ShardingPolicy()
    spec = SH.resolve_spec((3, 7), ("fsdp", "tp"), policy, mesh)
    # 1x1 mesh: everything divides, axes kept
    assert spec == jax.sharding.PartitionSpec("data", "model")
