"""Observability substrate contract: ``repro.obs`` + its stack wiring.

* **primitives** — counter monotonicity, histogram bucketing at the
  edges (``le`` semantics), strict edge validation, interpolated
  quantiles, labeled-family child reuse, idempotent-but-strict
  registration;
* **atomic snapshot** — a snapshot taken while other threads increment
  never shows a histogram whose ``count`` disagrees with its bucket
  counts;
* **exposition** — a golden Prometheus text rendering and a JSON dump;
* **spans** — mark ordering, derived leg durations;
* **stack wiring** — the serving tier feeds the stage histograms and its
  ``latency_breakdown()``/``LoadReport.breakdown`` stay JSON-safe on
  empty and tiny runs (the loadgen 0/1/2-request edges);
* **hot-path overhead** — serving a request costs a bounded handful of
  metric operations (regression-tested so an exporter can never creep
  into the request path).
"""

import asyncio
import io
import json
import math
import threading
import time

import numpy as np
import pytest

from repro import engine, obs, serve

# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def test_counter_monotonic():
    c = obs.Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)


def test_gauge_set_and_inc():
    g = obs.Gauge()
    g.set(4)
    g.inc(-1.5)
    assert g.value == 2.5


def test_histogram_bucketing_at_the_edges():
    """``le`` semantics: a value equal to an edge lands in that edge's
    bucket (inclusive upper bound), one past it in the next."""
    h = obs.Histogram(edges=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.0000001, 2.0, 5.0, 5.0000001, 100.0):
        h.observe(v)
    snap = h._snapshot()
    assert snap["counts"] == [2, 2, 1, 2]       # le=1, le=2, le=5, +Inf
    assert snap["count"] == 7
    assert snap["sum"] == pytest.approx(sum(
        (0.5, 1.0, 1.0000001, 2.0, 5.0, 5.0000001, 100.0)))


def test_histogram_edge_validation():
    with pytest.raises(ValueError, match="strictly increase"):
        obs.Histogram(edges=(1.0, 1.0, 2.0))
    with pytest.raises(ValueError, match="at least one"):
        obs.Histogram(edges=())


def test_histogram_quantile_interpolation():
    h = obs.Histogram(edges=(1.0, 2.0, 4.0))
    for _ in range(10):
        h.observe(1.5)                           # all in the (1, 2] bucket
    # rank q*10 inside a uniform bucket: linear interpolation over (1, 2]
    assert h.quantile(0.5) == pytest.approx(1.5)
    assert h.quantile(1.0) == pytest.approx(2.0)
    assert h.quantile(0.0) == pytest.approx(1.0)
    h.observe(100.0)                             # +Inf bucket
    assert h.quantile(1.0) == 4.0                # clamps to largest edge
    assert math.isnan(obs.Histogram(edges=(1.0,)).quantile(0.5))
    assert math.isnan(obs.Histogram(edges=(1.0,)).mean())
    with pytest.raises(ValueError, match="quantile"):
        h.quantile(1.5)


def test_labeled_family_reuses_children():
    reg = obs.Registry()
    fam = reg.counter("hits_total", "hits", labels=("kind",))
    a1 = fam.labels(kind="a")
    a2 = fam.labels(kind="a")
    b = fam.labels(kind="b")
    assert a1 is a2 and a1 is not b
    a1.inc(3)
    b.inc()
    # same name + same shape -> the same Family object back
    assert reg.counter("hits_total", labels=("kind",)) is fam
    with pytest.raises(ValueError, match="expected labels"):
        fam.labels(nope="x")
    snap = reg.snapshot()["hits_total"]
    assert snap["series"] == [{"labels": {"kind": "a"}, "value": 3.0},
                              {"labels": {"kind": "b"}, "value": 1.0}]


def test_registry_rejects_conflicting_reregistration():
    reg = obs.Registry()
    reg.counter("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("x_total", labels=("tier",))
    reg.histogram("h_seconds", buckets=(1.0, 2.0))
    with pytest.raises(ValueError, match="bucket edges"):
        reg.histogram("h_seconds", buckets=(1.0, 3.0))
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad name")
    with pytest.raises(ValueError, match="invalid label name"):
        reg.counter("ok_total", labels=("bad-label",))
    assert reg.get("x_total") is not None
    assert reg.get("never_registered") is None


# ---------------------------------------------------------------------------
# snapshot atomicity + exposition
# ---------------------------------------------------------------------------


def test_snapshot_consistent_under_concurrent_increment():
    """Histogram ``count`` must always equal the sum of its bucket counts
    in a snapshot, no matter how hard other threads are observing."""
    reg = obs.Registry()
    h = reg.histogram("h_seconds", buckets=(0.5, 1.5))
    c = reg.counter("c_total")
    stop = threading.Event()

    def mutate():
        while not stop.is_set():
            h.observe(1.0)
            c.inc()

    threads = [threading.Thread(target=mutate) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        deadline = time.perf_counter() + 0.5
        snaps = 0
        while time.perf_counter() < deadline:
            s = reg.snapshot()["h_seconds"]["series"][0]
            assert sum(s["counts"]) == s["count"]
            snaps += 1
        assert snaps > 10
    finally:
        stop.set()
        for t in threads:
            t.join()
    s = reg.snapshot()["h_seconds"]["series"][0]
    assert s["count"] > 0 and sum(s["counts"]) == s["count"]


def test_prometheus_text_golden():
    reg = obs.Registry()
    reg.gauge("depth", "queue depth").set(2.5)
    reg.counter("hits_total", "hits by kind",
                labels=("kind",)).labels(kind="a").inc()
    reg.counter("jobs_total", "jobs processed").inc(3)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert reg.render_prometheus() == (
        "# HELP depth queue depth\n"
        "# TYPE depth gauge\n"
        "depth 2.5\n"
        "# HELP hits_total hits by kind\n"
        "# TYPE hits_total counter\n"
        'hits_total{kind="a"} 1\n'
        "# HELP jobs_total jobs processed\n"
        "# TYPE jobs_total counter\n"
        "jobs_total 3\n"
        "# HELP lat_seconds latency\n"
        "# TYPE lat_seconds histogram\n"
        'lat_seconds_bucket{le="0.1"} 1\n'
        'lat_seconds_bucket{le="1"} 2\n'
        'lat_seconds_bucket{le="+Inf"} 3\n'
        "lat_seconds_sum 5.55\n"
        "lat_seconds_count 3\n")


def test_dump_json_roundtrip(tmp_path):
    reg = obs.Registry()
    reg.counter("n_total", "n").inc(7)
    path = str(tmp_path / "m.json")
    assert reg.dump_json(path) == path
    with open(path) as f:
        snap = json.load(f)
    assert snap["n_total"]["series"][0]["value"] == 7.0


def test_span_marks_and_durations():
    span = obs.Span("request", t=10.0)
    span.mark("flush", 10.5)
    span.mark("dispatch", 10.6)
    span.mark("done", 11.0)
    assert [s for s, _ in span.marks] == list(obs.REQUEST_STAGES)
    assert span.duration("enqueue", "flush") == pytest.approx(0.5)
    assert span.durations() == pytest.approx(
        {"enqueue->flush": 0.5, "flush->dispatch": 0.1,
         "dispatch->done": 0.4})
    assert span.total == pytest.approx(1.0)
    assert span.as_dict()["stages"] == list(obs.REQUEST_STAGES)


def test_summary_line_and_periodic_reporter():
    reg = obs.Registry()
    line = obs.summary_line(reg)
    assert line.startswith("[obs] requests=0")
    stream = io.StringIO()
    rep = obs.PeriodicReporter(interval_s=0.02, reg=reg, stream=stream)
    with rep:
        time.sleep(0.1)
    assert "[obs] requests=0" in stream.getvalue()
    after = stream.getvalue()
    time.sleep(0.05)
    assert stream.getvalue() == after, "reporter printed after stop()"
    # a non-positive interval never starts the thread
    off = obs.PeriodicReporter(interval_s=0, reg=reg, stream=stream)
    with off:
        assert off._thread is None


# ---------------------------------------------------------------------------
# stack wiring
# ---------------------------------------------------------------------------


def _tiny_net(seed=7):
    rng = np.random.default_rng(seed)
    layers = []
    for a, b in zip((10, 16), (16, 6)):
        idx = np.stack([np.sort(rng.choice(a, 2, replace=False))
                        for _ in range(b)]).astype(np.int32)
        tab = rng.integers(0, 4, (b, 2 ** 4), dtype=np.int32)
        layers.append((idx, tab, 2))
    return engine.compile_network(layers, optimize_level=2, in_features=10,
                                  block_b=4)


@pytest.fixture(scope="module")
def net():
    return _tiny_net()


def test_tier_feeds_stage_histograms_and_breakdown(net):
    async def main():
        async with serve.ServingTier(net) as tier:
            await asyncio.gather(*[
                tier.infer(np.zeros((2, net.n_in), np.int32))
                for _ in range(5)])
            return tier.latency_breakdown(), tier.recent_spans()

    breakdown, spans = asyncio.run(main())
    assert set(breakdown) == {"queue_wait", "assembly", "device", "total"}
    for stage, leg in breakdown.items():
        assert leg["count"] == 5, stage
        assert leg["mean_ms"] >= 0.0
        assert leg["p50_ms"] <= leg["p99_ms"]
    # a request's legs sum to its total, and the ring kept the spans
    assert len(spans) == 5
    for span in spans:
        legs = span.durations()
        assert sum(legs.values()) == pytest.approx(span.total)
        assert [s for s, _ in span.marks] == list(obs.REQUEST_STAGES)
    # breakdown is JSON-strict (no NaN): the --metrics-json contract
    json.dumps(breakdown, allow_nan=False)


def test_tier_metrics_in_process_registry(net):
    before = _tier_series_count()
    serve.run_requests(net, [np.zeros((3, net.n_in), np.int32)])
    assert _tier_series_count() == before + 1
    snap = obs.registry().snapshot()
    for name in ("serve_requests_total", "serve_queue_wait_seconds",
                 "serve_assembly_seconds", "serve_device_seconds",
                 "serve_request_latency_seconds", "serve_flush_total",
                 "serve_retraces_after_warmup"):
        assert name in snap, name


def _tier_series_count() -> int:
    fam = obs.registry().get("serve_requests_total")
    return len(fam._series()) if fam is not None else 0


def test_loadgen_edge_counts(net):
    """0-, 1- and 2-request runs must produce a well-formed LoadReport
    (np.percentile raises on an empty sample without the guard)."""
    rep0 = serve.run_closed_loop(net, n_clients=1, n_per_client=0, bw=2)
    assert rep0.n_requests == 0 and rep0.rows == 0
    assert math.isnan(rep0.p50_ms) and math.isnan(rep0.mean_ms)
    assert rep0.qps == 0.0
    d = rep0.as_dict()
    assert d["n_requests"] == 0
    json.dumps(d["breakdown"], allow_nan=False)

    rep1 = serve.run_closed_loop(net, n_clients=1, n_per_client=1, bw=2)
    assert rep1.n_requests == 1
    assert rep1.p50_ms == pytest.approx(rep1.p99_ms)
    assert rep1.p50_ms > 0.0 and rep1.qps > 0.0
    assert rep1.breakdown["total"]["count"] == 1

    rep2 = serve.run_closed_loop(net, n_clients=2, n_per_client=1, bw=2)
    assert rep2.n_requests == 2
    assert rep2.p50_ms <= rep2.p90_ms <= rep2.p99_ms
    assert rep2.as_dict()["n_requests"] == 2


def test_engine_counters_record_compiles_and_memo():
    reg = obs.registry()

    def total(name):
        m = reg.get(name)
        if m is None:
            return 0.0
        if isinstance(m, obs.Family):
            return sum(c.value for _, c in m._series())
        return m.value

    runs0 = total("engine_compiler_runs_total")
    builds0 = total("engine_builds_total")
    slab0 = reg.get("engine_slab_build_seconds").count
    _tiny_net(seed=8)
    assert total("engine_compiler_runs_total") == runs0 + 1
    assert total("engine_builds_total") == builds0 + 1
    assert reg.get("engine_slab_build_seconds").count == slab0 + 1

    hits0, misses0 = total("engine_memo_hits_total"), total(
        "engine_memo_misses_total")
    rng = np.random.default_rng(3)
    idx = np.stack([np.sort(rng.choice(6, 2, replace=False))
                    for _ in range(4)]).astype(np.int32)
    tab = rng.integers(0, 4, (4, 2 ** 4), dtype=np.int32)
    triples = [(idx, tab, 2)]
    engine.cache_clear()
    from repro.kernels.ops import FUSED_VMEM_BUDGET_BYTES
    kwargs = dict(optimize_level=1, in_features=6, fused=True,
                  use_pallas=True, block_b=8,
                  vmem_budget_bytes=FUSED_VMEM_BUDGET_BYTES)
    engine.cached_compile(triples, **kwargs)
    engine.cached_compile(triples, **kwargs)
    assert total("engine_memo_misses_total") == misses0 + 1
    assert total("engine_memo_hits_total") == hits0 + 1


def test_compile_pass_timings_in_registry(net):
    # the module fixture compiled at level 2, so the pipeline has run at
    # least once in this process and its passes are in the registry
    snap = obs.registry().snapshot()
    runs = {tuple(s["labels"].values()): s["value"]
            for s in snap["compile_pass_runs_total"]["series"]}
    secs = {tuple(s["labels"].values()): s["value"]
            for s in snap["compile_pass_seconds_total"]["series"]}
    assert ("reachability",) in runs
    for key, n in runs.items():
        assert n >= 1
        assert secs[key] >= 0.0
    assert snap["compile_optimize_seconds"]["series"][0]["count"] >= 1
    assert any(s["value"] >= 1
               for s in snap["compile_optimize_runs_total"]["series"])


# ---------------------------------------------------------------------------
# hot-path overhead regression
# ---------------------------------------------------------------------------


def test_request_path_metric_overhead_is_bounded(net, monkeypatch):
    """Serving a request costs a bounded handful of metric ops: 2 counter
    incs at submit, 4 histogram observes at completion, ~3 counter incs
    amortized per batch.  A metrics/tracing change that adds per-request
    rendering, snapshotting or extra metric traffic trips this budget."""
    ops = {"n": 0}

    def counted(orig):
        def wrapper(self, *a, **kw):
            ops["n"] += 1
            return orig(self, *a, **kw)
        return wrapper

    monkeypatch.setattr(obs.Counter, "inc", counted(obs.Counter.inc))
    monkeypatch.setattr(obs.Gauge, "inc", counted(obs.Gauge.inc))
    monkeypatch.setattr(obs.Gauge, "set", counted(obs.Gauge.set))
    monkeypatch.setattr(obs.Histogram, "observe",
                        counted(obs.Histogram.observe))

    n_requests = 24
    reqs = [np.full((2, net.n_in), i % 4, np.int32)
            for i in range(n_requests)]
    serve.run_requests(net, reqs)
    # 2 (submit) + 4 (observe) per request, <= 3 per batch (batches <=
    # requests), plus a constant few for lifecycle — 10/request is the
    # regression ceiling, ~2-3x the typical coalesced cost
    assert ops["n"] <= 10 * n_requests, (
        f"{ops['n']} metric ops for {n_requests} requests — the request "
        "path grew metric work beyond the counter-increment budget")
