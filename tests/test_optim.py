"""Optimizer + gradient compression unit/property tests."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st  # real when installed

from repro.optim.adamw import (AdamWCfg, adamw_update, cosine_schedule,
                               global_norm, init_opt_state,
                               logicnet_mask_fn)
from repro.optim.compress import (compress_grads_with_feedback,
                                  compress_int8, decompress_int8,
                                  init_error_state)


def _quad_params():
    return {"w": jnp.array([3.0, -2.0, 1.0]), "b": jnp.array([0.5])}


def test_adamw_decreases_quadratic():
    cfg = AdamWCfg(lr=0.1, weight_decay=0.0, clip_norm=0.0)
    params = _quad_params()
    state = init_opt_state(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(cfg, params, g, state)
    assert float(loss(params)) < 1e-2
    assert int(state["step"]) == 200


def test_masked_update_keeps_pruned_weights_zero():
    """The LogicNets invariant: masked weights stay exactly zero."""
    mask = jnp.array([[1.0, 0.0], [0.0, 1.0]])
    params = {"layer": {"wi_gate": jnp.ones((2, 2)) * mask,
                        "wi_up": jnp.ones((2, 2)) * mask,
                        "wo": jnp.ones((2, 2)) * mask,
                        "mask_in": mask, "mask_out": mask}}
    grads = jax.tree.map(jnp.ones_like, params)
    state = init_opt_state(params)
    cfg = AdamWCfg(lr=0.5)
    new, _ = adamw_update(cfg, params, grads, state,
                          mask_fn=logicnet_mask_fn)
    w = np.asarray(new["layer"]["wi_gate"])
    assert w[0, 1] == 0.0 and w[1, 0] == 0.0
    assert w[0, 0] != 1.0          # unmasked weights moved
    # masks themselves frozen
    np.testing.assert_array_equal(np.asarray(new["layer"]["mask_in"]),
                                  np.asarray(mask))


def test_freeze_rule_default():
    params = {"mask": jnp.ones((2,)), "w": jnp.ones((2,))}
    grads = jax.tree.map(jnp.ones_like, params)
    state = init_opt_state(params)
    new, _ = adamw_update(AdamWCfg(lr=0.5), params, grads, state)
    np.testing.assert_array_equal(np.asarray(new["mask"]), 1.0)
    assert not np.allclose(np.asarray(new["w"]), 1.0)


def test_grad_clipping():
    params = {"w": jnp.zeros((4,))}
    grads = {"w": jnp.full((4,), 1e6)}
    state = init_opt_state(params)
    cfg = AdamWCfg(lr=1.0, clip_norm=1.0)
    new, _ = adamw_update(cfg, params, grads, state)
    assert np.isfinite(np.asarray(new["w"])).all()


def test_cosine_schedule_shape():
    s = cosine_schedule(warmup=10, total=100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) <= 0.11
    assert float(s(jnp.asarray(55))) < float(s(jnp.asarray(20)))


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.ones((1,)) * 2}
    np.testing.assert_allclose(float(global_norm(t)), np.sqrt(3 + 4))


@given(seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_bounded_error(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 10
    q, s = compress_int8(g)
    deq = decompress_int8(q, s)
    assert float(jnp.abs(deq - g).max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_reduces_bias():
    """With feedback, the accumulated compressed sum tracks the true sum."""
    grads = {"w": jnp.full((64,), 0.003)}   # small: heavy quantization loss
    err = init_error_state(grads)
    total_c, total_t = jnp.zeros((64,)), jnp.zeros((64,))
    for _ in range(50):
        deq, err = compress_grads_with_feedback(grads, err)
        total_c = total_c + deq["w"]
        total_t = total_t + grads["w"]
    # residual is bounded by one quantization step, not growing with steps
    resid = float(jnp.abs(total_c - total_t).max())
    assert resid <= float(jnp.abs(err["w"]).max()) + 1e-5


def test_compression_convergence_parity():
    """AdamW + int8-compressed grads converges on a least-squares problem
    nearly as well as exact grads (the paper's §1.2.1 concern, mitigated
    by error feedback)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (128, 8))
    w_true = jnp.arange(1.0, 9.0)
    y = x @ w_true

    def loss(p):
        return jnp.mean((x @ p["w"] - y) ** 2)

    def train(compressed: bool):
        params = {"w": jnp.zeros((8,))}
        state = init_opt_state(params)
        err = init_error_state(params)
        cfg = AdamWCfg(lr=0.05, clip_norm=0.0)
        for _ in range(300):
            g = jax.grad(loss)(params)
            if compressed:
                g, err = compress_grads_with_feedback(g, err)
            params, state = adamw_update(cfg, params, g, state)
        return float(loss(params))

    exact, comp = train(False), train(True)
    assert comp < exact * 3 + 1e-3
