"""Quantizer unit + property tests (paper §3.1.2, §4.1, Listing 4.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # real when installed

from repro.core.quantize import (QuantizerCfg, all_codes, codes,
                                 dequantize_code, quantize)


def test_hardtanh_is_binary():
    """Listing 4.1: bit-width 1, max_val 1.61 -> values in {-1.61, +1.61}."""
    cfg = QuantizerCfg(1, 1.61)
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    qt = quantize(cfg, x)
    vals = np.unique(np.asarray(qt.value, dtype=np.float64))
    assert len(vals) == 2
    np.testing.assert_allclose(vals, [-1.61, 1.61], rtol=1e-6)
    assert qt.bit_width == 1


def test_quantrelu_levels():
    """QuantReLU(b bits) emits integer levels 0..2^b-1 times the step."""
    cfg = QuantizerCfg(3, 1.0)
    x = jnp.linspace(-1.0, 2.0, 1001)
    qt = quantize(cfg, x)
    lv = np.asarray(qt.value) / cfg.step
    assert np.allclose(lv, np.round(lv), atol=1e-5)
    assert lv.min() >= 0 and lv.max() <= 7


@given(bits=st.integers(1, 8), max_val=st.floats(0.25, 8.0),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=60, deadline=None)
def test_code_roundtrip_exact(bits, max_val, seed):
    """codes() -> dequantize_code() -> codes() is the identity."""
    cfg = QuantizerCfg(bits, max_val)
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * max_val
    c = codes(cfg, x)
    v = dequantize_code(cfg, c)
    c2 = codes(cfg, v)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c2))
    assert int(c.min()) >= 0 and int(c.max()) < cfg.n_levels


@given(bits=st.integers(1, 6), max_val=st.floats(0.5, 4.0),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=40, deadline=None)
def test_quantize_matches_codes(bits, max_val, seed):
    """The fake-quant forward value equals the dequantized code — the
    bridge that makes truth tables exact."""
    cfg = QuantizerCfg(bits, max_val)
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * max_val
    qt = quantize(cfg, x)
    v = dequantize_code(cfg, codes(cfg, x))
    np.testing.assert_allclose(np.asarray(qt.value), np.asarray(v),
                               rtol=0, atol=1e-6)


def test_ste_gradient_passthrough():
    """Gradient is 1 inside the clip range, 0 outside (STE)."""
    cfg = QuantizerCfg(3, 1.0)
    g = jax.grad(lambda x: quantize(cfg, x).value.sum())(
        jnp.array([-0.5, 0.2, 0.7, 1.5]))
    np.testing.assert_allclose(np.asarray(g), [0.0, 1.0, 1.0, 0.0])


def test_all_codes():
    assert list(np.asarray(all_codes(QuantizerCfg(2)))) == [0, 1, 2, 3]


@pytest.mark.parametrize("bits", [1, 2, 3, 4])
def test_quant_output_count(bits):
    cfg = QuantizerCfg(bits, 1.0)
    x = jnp.linspace(-2, 2, 4001)
    distinct = np.unique(np.asarray(quantize(cfg, x).value))
    assert len(distinct) <= 2 ** bits
