"""Micro-batching serving-tier contract: ``repro.serve.ServingTier``.

The tier is pure request plumbing over a ``CompiledLUTNet``, so the
contracts are:

* **coalescing correctness** — concurrent ragged requests, coalesced into
  shared batches, return outputs bit-exact with calling the artifact
  directly on each request's rows;
* **flush policy** — size flush under load, deadline flush under light
  load, drain flush at shutdown (empty-queue shutdown returns promptly);
* **backpressure / timeouts** — a full bounded queue rejects instead of
  queueing unboundedly; a request not launched within its timeout fails
  with ``RequestTimeout``;
* **compile-once steady state** — after ``start()``'s warmup a serving
  loop adds zero jit traces and zero compiler runs;
* **device sharding** — with a forced multi-device CPU the batch axis is
  sharded over all devices and stays bit-exact (subprocess: the device
  count is fixed at jax import time).
"""

import asyncio
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro import engine, serve

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


def _random_stack(widths, fan_ins, bws, seed=0):
    rng = np.random.default_rng(seed)
    layers = []
    for (n_in, n_out), fi, bw in zip(zip(widths[:-1], widths[1:]),
                                     fan_ins, bws):
        fi = min(fi, n_in)
        idx = np.stack([np.sort(rng.choice(n_in, fi, replace=False))
                        for _ in range(n_out)]).astype(np.int32)
        tab = rng.integers(0, 2 ** bw, (n_out, 2 ** (fi * bw)),
                           dtype=np.int32)
        layers.append((idx, tab, bw))
    return layers


@pytest.fixture(scope="module")
def net():
    layers = _random_stack((12, 20, 16, 8), (3, 3, 3), (2, 2, 2), seed=13)
    return engine.compile_network(layers, optimize_level=3, in_features=12,
                                  block_b=8)


def _requests(net, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 4, (int(k), net.n_in), dtype=np.int32)
            for k in sizes]


def test_coalescing_bit_exact_and_zero_retrace(net):
    """Concurrent ragged requests coalesce into fewer batches, outputs are
    bit-exact vs direct ``net(codes)``, and steady state adds no traces."""
    sizes = np.random.default_rng(1).integers(1, 7, 60)
    reqs = _requests(net, sizes, seed=2)

    async def main():
        cfg = serve.TierConfig(max_batch_rows=16, flush_deadline_s=0.002)
        async with serve.ServingTier(net, cfg) as tier:
            outs = await asyncio.gather(*[tier.infer(r) for r in reqs])
            return outs, tier.stats()

    outs, stats = asyncio.run(main())
    for r, o in zip(reqs, outs):
        assert o.dtype == np.int32
        np.testing.assert_array_equal(o, np.asarray(net(r)))
    assert stats["batches"] < stats["requests"], "no coalescing happened"
    assert stats["retraces_after_warmup"] == 0
    assert stats["compiler_runs_after_warmup"] == 0
    assert stats["rows"] == int(sizes.sum())
    assert 0.0 < stats["batch_occupancy"] <= 1.0
    assert stats["flush_causes"]["size"] >= 1


def test_single_row_and_empty_and_validation(net):
    async def main():
        async with serve.ServingTier(net) as tier:
            row = np.zeros((net.n_in,), np.int32)
            single = await tier.infer(row)
            empty = await tier.infer(np.zeros((0, net.n_in), np.int32))
            with pytest.raises(ValueError, match="expected"):
                await tier.infer(np.zeros((2, net.n_in + 1), np.int32))
            return single, empty

    single, empty = asyncio.run(main())
    assert single.shape == (net.n_out,)
    np.testing.assert_array_equal(
        single, np.asarray(net(np.zeros((1, net.n_in), np.int32)))[0])
    assert empty.shape == (0, net.n_out) and empty.dtype == np.int32


def test_deadline_flush_under_light_load(net):
    """A partial batch (3 rows, max 64) must flush on the deadline, not
    wait for the size threshold."""
    req = _requests(net, [3], seed=3)[0]

    async def main():
        cfg = serve.TierConfig(max_batch_rows=64, flush_deadline_s=0.05)
        async with serve.ServingTier(net, cfg) as tier:
            t0 = time.perf_counter()
            out = await tier.infer(req)
            dt = time.perf_counter() - t0
            return out, dt, tier.stats()

    out, dt, stats = asyncio.run(main())
    np.testing.assert_array_equal(out, np.asarray(net(req)))
    assert dt >= 0.04, "flushed before the deadline window"
    assert stats["flush_causes"]["deadline"] == 1
    assert stats["flush_causes"]["size"] == 0


def _slow_net(net, delay_s):
    """Wrap the artifact so every batch takes at least ``delay_s``."""

    class Slow:
        n_in, n_out, block_b = net.n_in, net.n_out, net.block_b

        def __call__(self, codes):
            time.sleep(delay_s)
            return net(codes)

        def jit_cache_size(self):
            return net.jit_cache_size()

    return Slow()


def test_backpressure_rejects_when_queue_full(net):
    """With the batcher stuck in a slow batch, the bounded queue must
    reject the overflowing request immediately."""
    slow = _slow_net(net, 0.2)

    async def main():
        cfg = serve.TierConfig(max_batch_rows=4, flush_deadline_s=0.0,
                               max_queue_rows=8, warmup=False)
        async with serve.ServingTier(slow, cfg) as tier:
            first = asyncio.ensure_future(
                tier.infer(np.zeros((4, net.n_in), np.int32)))
            await asyncio.sleep(0.05)       # batcher now inside the batch
            q1 = asyncio.ensure_future(
                tier.infer(np.zeros((8, net.n_in), np.int32)))
            await asyncio.sleep(0)
            with pytest.raises(serve.TierOverloaded):
                await tier.infer(np.zeros((1, net.n_in), np.int32))
            stats_mid = tier.stats()
            out0, out1 = await first, await q1
            return out0, out1, stats_mid, tier.stats()

    out0, out1, stats_mid, stats = asyncio.run(main())
    assert stats_mid["rejected"] == 1
    assert out0.shape == (4, net.n_out) and out1.shape == (8, net.n_out)
    assert stats["queued_rows"] == 0


def test_request_timeout_before_launch(net):
    """A request stuck behind a long-running batch past its timeout fails
    with RequestTimeout; one already inside a batch still resolves."""
    slow = _slow_net(net, 0.25)

    async def main():
        cfg = serve.TierConfig(max_batch_rows=2, flush_deadline_s=0.0,
                               request_timeout_s=0.1, warmup=False)
        async with serve.ServingTier(slow, cfg) as tier:
            first = asyncio.ensure_future(
                tier.infer(np.zeros((2, net.n_in), np.int32)))
            await asyncio.sleep(0.05)       # first batch is computing
            with pytest.raises(serve.RequestTimeout):
                await tier.infer(np.zeros((1, net.n_in), np.int32))
            out0 = await first
            return out0, tier.stats()

    out0, stats = asyncio.run(main())
    assert out0.shape == (2, net.n_out)
    assert stats["timed_out"] == 1


def test_empty_queue_shutdown_is_prompt(net):
    """stop() on an idle tier returns quickly and later submits raise."""

    async def main():
        tier = serve.ServingTier(net, serve.TierConfig(warmup=False))
        await tier.start()
        t0 = time.perf_counter()
        await tier.stop()
        dt = time.perf_counter() - t0
        with pytest.raises(serve.TierClosed):
            await tier.infer(np.zeros((1, net.n_in), np.int32))
        return dt

    assert asyncio.run(main()) < 1.0


def test_drain_flush_on_shutdown(net):
    """Requests still queued when stop() is called are served (drain
    flush), not dropped."""
    req = _requests(net, [5], seed=4)[0]

    async def main():
        cfg = serve.TierConfig(max_batch_rows=64, flush_deadline_s=5.0)
        tier = await serve.ServingTier(net, cfg).start()
        fut = asyncio.ensure_future(tier.infer(req))
        await asyncio.sleep(0.02)           # queued, deadline far away
        await tier.stop()
        out = await fut
        return out, tier.stats()

    out, stats = asyncio.run(main())
    np.testing.assert_array_equal(out, np.asarray(net(req)))
    assert stats["flush_causes"]["drain"] == 1


def test_double_start_rejected_and_serve_once_helper(net):
    reqs = _requests(net, [2, 3, 1], seed=5)
    outs = serve.run_requests(net, reqs)
    for r, o in zip(reqs, outs):
        np.testing.assert_array_equal(o, np.asarray(net(r)))

    async def main():
        tier = await serve.ServingTier(net).start()
        with pytest.raises(serve.TierError, match="already started"):
            await tier.start()
        await tier.stop()

    asyncio.run(main())


def test_oversized_request_forms_its_own_batch(net):
    """A request larger than max_batch_rows is served whole (its own
    batch) rather than split or rejected."""
    req = _requests(net, [20], seed=6)[0]

    async def main():
        cfg = serve.TierConfig(max_batch_rows=8, flush_deadline_s=0.001)
        async with serve.ServingTier(net, cfg) as tier:
            out = await tier.infer(req)
            return out, tier.stats()

    out, stats = asyncio.run(main())
    np.testing.assert_array_equal(out, np.asarray(net(req)))
    assert stats["batches"] == 1 and stats["rows"] == 20


@pytest.mark.parametrize("n_dev", [4])
def test_multi_device_sharded_serving(n_dev):
    """Data-parallel fan-out over a forced multi-device CPU: the batch
    axis is sharded with jax.sharding, outputs stay bit-exact and the
    steady state stays re-trace free.  Runs in a subprocess because the
    CPU device count is fixed at jax import time."""
    prog = textwrap.dedent(f"""
        import asyncio, numpy as np, jax
        from repro import engine, serve

        assert len(jax.devices()) == {n_dev}
        rng = np.random.default_rng(0)
        layers = []
        for a, b in zip((12, 20, 16), (20, 16, 8)):
            idx = np.stack([np.sort(rng.choice(a, 3, replace=False))
                            for _ in range(b)]).astype(np.int32)
            tab = rng.integers(0, 4, (b, 2 ** 6), dtype=np.int32)
            layers.append((idx, tab, 2))
        net = engine.compile_network(layers, optimize_level=3,
                                     in_features=12, block_b=8)
        reqs = [rng.integers(0, 4, (int(k), 12), dtype=np.int32)
                for k in rng.integers(1, 7, 30)]

        async def main():
            cfg = serve.TierConfig(max_batch_rows=32,
                                   flush_deadline_s=0.002)
            async with serve.ServingTier(net, cfg) as tier:
                st0 = tier.stats()
                assert st0["n_devices"] == {n_dev} and st0["sharded"]
                assert st0["bucket_unit"] % {n_dev} == 0
                outs = await asyncio.gather(*[tier.infer(r) for r in reqs])
                return outs, tier.stats()

        outs, stats = asyncio.run(main())
        for r, o in zip(reqs, outs):
            np.testing.assert_array_equal(o, np.asarray(net(r)))
        assert stats["retraces_after_warmup"] == 0
        assert stats["compiler_runs_after_warmup"] == 0
        assert stats["batches"] < stats["requests"]
        print("SHARDED_OK", stats["batches"])
    """)
    env = dict(os.environ,
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "") +
                          f" --xla_force_host_platform_device_count={n_dev}"),
               JAX_PLATFORMS="cpu",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", prog], env=env,
                          capture_output=True, text=True, timeout=240)
    assert proc.returncode == 0, proc.stderr
    assert "SHARDED_OK" in proc.stdout
