"""SparseConv (paper §4.4): depthwise-separable, sparse, quantized."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layers import (SparseConvCfg, sparse_conv_apply,
                               sparse_conv_init)
from repro.core.lut_cost import sparse_conv_dw_cost, sparse_conv_pw_cost


def test_forward_shapes_first_layer():
    cfg = SparseConvCfg(in_channels=1, out_channels=8, kernel_size=3,
                        stride=2, x_k=5, x_s=4, bw_in=2, bw_mid=2,
                        first_layer=True)
    layer = sparse_conv_init(cfg, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (4, 28, 28, 1))
    y, layer2 = sparse_conv_apply(cfg, layer, x, train=True)
    assert y.shape == (4, 13, 13, 8)
    assert bool(jnp.isfinite(y).all())
    # first-layer rule: depthwise kernel count == out_channels (§4.4)
    assert layer["params"]["w_dw"].shape[-1] == 8


def test_forward_shapes_mid_layer():
    cfg = SparseConvCfg(in_channels=6, out_channels=12, kernel_size=3,
                        stride=1, x_k=4, x_s=3)
    layer = sparse_conv_init(cfg, jax.random.PRNGKey(2))
    x = jax.random.uniform(jax.random.PRNGKey(3), (2, 10, 10, 6))
    y, _ = sparse_conv_apply(cfg, layer, x, train=True)
    assert y.shape == (2, 8, 8, 12)


def test_depthwise_matches_manual():
    """The grouped conv equals an explicit per-channel correlation."""
    from repro.core.layers import _depthwise
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (1, 6, 6, 3))
    w = jax.random.normal(jax.random.PRNGKey(5), (3, 3, 3))
    y = _depthwise(x, w, stride=1, replicate=False)
    for c in range(3):
        manual = jax.scipy.signal.correlate(
            x[0, :, :, c], w[:, :, c], mode="valid")
        np.testing.assert_allclose(np.asarray(y[0, :, :, c]),
                                   np.asarray(manual), atol=1e-4)


def test_mask_sparsity_counts():
    cfg = SparseConvCfg(in_channels=6, out_channels=12, x_k=4, x_s=3)
    layer = sparse_conv_init(cfg, jax.random.PRNGKey(6))
    dw = np.asarray(layer["mask_dw"]).reshape(9, 6)
    np.testing.assert_array_equal(dw.sum(axis=0), 4)    # x_k taps/kernel
    pw = np.asarray(layer["mask_pw"])
    np.testing.assert_array_equal(pw.sum(axis=0), 3)    # x_s inputs/neuron


def test_conv_lut_costs_eq_4_3_4_4():
    # eqs. 4.3/4.4 with LUTcost() the per-bit closed form
    assert sparse_conv_dw_cost(out_pix=169, o_bits=2, n_ofm=16, x_k=5,
                               i_bits=2) == 169 * 2 * 16 * 21
    assert sparse_conv_pw_cost(out_pix=169, o_bits=2, n_ofm=16, x_s=5,
                               i_bits=2) == 169 * 2 * 16 * 21


def test_quantization_bounds_activations():
    cfg = SparseConvCfg(in_channels=1, out_channels=4, bw_in=2,
                        bw_mid=2, max_val_in=1.0, first_layer=True)
    layer = sparse_conv_init(cfg, jax.random.PRNGKey(7))
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 8, 8, 1)) * 10
    y, _ = sparse_conv_apply(cfg, layer, x, train=False)
    assert bool(jnp.isfinite(y).all())
