"""Per-neuron fan-in sparsity invariants (paper §3.1.1, Algorithm 1)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st  # real when installed

from repro.core import sparsity as sp


@given(seed=st.integers(0, 1000), in_f=st.integers(4, 64),
       out_f=st.integers(1, 32), data=st.data())
@settings(max_examples=50, deadline=None)
def test_apriori_mask_exact_fan_in(seed, in_f, out_f, data):
    fan_in = data.draw(st.integers(1, in_f))
    m = np.asarray(sp.apriori_mask(seed, in_f, out_f, fan_in))
    assert m.shape == (in_f, out_f)
    np.testing.assert_array_equal(m.sum(axis=0), fan_in)
    assert set(np.unique(m)) <= {0.0, 1.0}


def test_apriori_mask_deterministic():
    a = np.asarray(sp.apriori_mask(7, 32, 16, 4))
    b = np.asarray(sp.apriori_mask(7, 32, 16, 4))
    np.testing.assert_array_equal(a, b)


def test_mask_to_indices_roundtrip():
    m = sp.apriori_mask(3, 16, 8, 5)
    idx = sp.mask_to_indices(m)
    assert idx.shape == (8, 5)
    rebuilt = np.zeros((16, 8), np.float32)
    for j in range(8):
        rebuilt[idx[j], j] = 1.0
    np.testing.assert_array_equal(rebuilt, np.asarray(m))


@given(seed=st.integers(0, 500), frac=st.floats(0.0, 1.0))
@settings(max_examples=40, deadline=None)
def test_iterative_prune_monotone_and_bounded(seed, frac):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (32, 8))
    mask = jnp.ones_like(w)
    new = sp.iterative_prune_mask(w, mask, target_fan_in=4, frac=frac)
    counts = np.asarray(new.sum(axis=0))
    assert (counts >= 4).all() and (counts <= 32).all()
    # full progress -> exactly the target fan-in
    final = sp.iterative_prune_mask(w, mask, target_fan_in=4, frac=1.0)
    np.testing.assert_array_equal(np.asarray(final.sum(axis=0)), 4)


def test_iterative_prune_keeps_largest_magnitude():
    w = jnp.array([[3.0, 0.1], [1.0, 2.0], [0.5, 0.3], [2.0, 5.0]])
    new = sp.iterative_prune_mask(w, jnp.ones_like(w), 2, frac=1.0)
    np.testing.assert_array_equal(
        np.asarray(new), [[1, 0], [0, 1], [0, 0], [1, 1]])


@given(seed=st.integers(0, 500), prune_rate=st.floats(0.05, 0.9))
@settings(max_examples=40, deadline=None)
def test_sparse_momentum_preserves_fan_in(seed, prune_rate):
    """Algorithm 1: prune P1 + regrow R1 keeps fan-in F exactly."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    fan_in = 6
    w = jax.random.normal(k1, (24, 10))
    mom = jax.random.normal(k2, (24, 10))
    mask = sp.apriori_mask(seed, 24, 10, fan_in)
    new = sp.sparse_momentum_step(w * mask, mom, mask, fan_in, prune_rate)
    np.testing.assert_array_equal(np.asarray(new.sum(axis=0)), fan_in)


def test_sparse_momentum_regrows_by_momentum():
    """The regrown weight is the inactive one with the largest |momentum|."""
    in_f, out_f, fan_in = 6, 1, 2
    mask = jnp.zeros((in_f, out_f)).at[0, 0].set(1.0).at[1, 0].set(1.0)
    w = jnp.zeros((in_f, out_f)).at[0, 0].set(1.0).at[1, 0].set(0.01)
    mom = jnp.zeros((in_f, out_f)).at[4, 0].set(9.0).at[5, 0].set(0.1)
    new = np.asarray(sp.sparse_momentum_step(w, mom, mask, fan_in, 0.5))
    assert new[0, 0] == 1.0   # largest |w| kept
    assert new[4, 0] == 1.0   # largest |momentum| regrown
    assert new.sum() == fan_in


def test_momentum_ema():
    m = sp.momentum_ema(jnp.array(1.0), jnp.array(0.0), alpha=0.9)
    np.testing.assert_allclose(float(m), 0.9)


def test_erdos_renyi_larger_layers_sparser():
    s = sp.erdos_renyi_sparsity([(64, 64), (1024, 1024)])
    assert s[1] > s[0]
    assert all(0.0 <= v <= 1.0 for v in s)


def test_fan_in_from_sparsity():
    assert sp.fan_in_from_sparsity(100, 0.95) == 5
    assert sp.fan_in_from_sparsity(100, 0.999) == 1  # floor at minimum
