"""Two-level synthesis (repro.synth): minimizer, SOP IR, Verilog, cost.

The contract under test: ``minimize_table`` produces a cover that is
bit-exact on every *reachable* table entry (don't-cares are free), the
SOP Verilog backend computes the same function as the case-statement
form on reachable inputs, and the measured ``sop_lut_estimate`` never
exceeds the worst-case ``lut_cost`` bound it claims to beat.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st  # real when installed

from repro.core import logicnet as LN
from repro.core import netlist as NL
from repro.core.lut_cost import (lut_cost, netlist_lut_cost,
                                 netlist_sop_cost, sop_lut_estimate)
from repro.core.table_infer import network_table_forward
from repro.core.verilog import (evaluate_verilog, generate_verilog,
                                neuron_module_sop, _parse_tables)
from repro.synth import (Cube, SopCover, minimize_bit, minimize_table,
                         synthesize_netlist)


def _toy(seed=0):
    cfg = LN.LogicNetCfg(in_features=5, n_classes=3, hidden=(4,),
                         fan_in=3, bw=1, final_dense=False, fan_in_fc=2,
                         bw_fc=1)
    key = jax.random.PRNGKey(seed)
    model = LN.init(cfg, key, mask_seed=seed)
    x = jax.random.normal(key, (32, 5))
    _, model = LN.forward(cfg, model, x, train=True)
    return cfg, model


# ---------------------------------------------------------------------------
# Cube / SopCover IR
# ---------------------------------------------------------------------------

def test_cube_literals_lsb_first():
    c = Cube(mask=0b1011, value=0b0010)
    assert c.n_literals == 3
    assert c.literals() == [(0, False), (1, True), (3, False)]
    assert Cube(0, 0).literals() == []


def test_cover_constant_bits():
    # bit 0 constant 0 (no cubes), bit 1 constant 1 (tautology cube)
    cover = SopCover(n_in=3, out_bits=2, bits=((), (Cube(0, 0),)))
    assert cover.table().tolist() == [2] * 8
    assert cover.bit_support(0) == () and cover.bit_support(1) == ()
    assert cover.n_terms == 1 and cover.n_literals == 0


def test_cover_shape_mismatch_rejected():
    with pytest.raises(ValueError):
        SopCover(n_in=2, out_bits=2, bits=((),))


# ---------------------------------------------------------------------------
# minimize_bit / minimize_table edges
# ---------------------------------------------------------------------------

def test_minimize_bit_constants():
    assert minimize_bit(set(), {1, 2}, 2) == ()
    assert minimize_bit({0, 1}, {2, 3}, 2) == (Cube(0, 0),)
    assert minimize_bit({0, 1, 2, 3}, set(), 2) == (Cube(0, 0),)


def test_minimize_single_input():
    # identity and inverter on one input bit (the k=1 edge)
    ident = minimize_table(np.array([0, 1]), 1, 1)
    assert ident.table().tolist() == [0, 1]
    assert ident.n_literals == 1
    inv = minimize_table(np.array([1, 0]), 1, 1)
    assert inv.table().tolist() == [1, 0]
    assert inv.bits[0] == (Cube(1, 0),)


def test_minimize_or_drops_literals():
    # OR(a, b): 3 on-set minterms at 2 literals each -> 2 cubes, 1 each
    cover = minimize_table(np.array([0, 1, 1, 1]), 2, 1)
    assert cover.table().tolist() == [0, 1, 1, 1]
    assert cover.n_terms == 2 and cover.n_literals == 2


def test_minimize_xor_keeps_full_cubes():
    # parity admits no merging: the cover IS the on-set at full width
    n = 3
    table = np.array([bin(w).count("1") & 1 for w in range(8)])
    cover = minimize_table(table, n, 1)
    assert cover.table().tolist() == table.tolist()
    assert cover.n_terms == 4 and cover.n_literals == 4 * n


def test_dont_cares_shrink_the_cover():
    # same on-set; marking the off-set unreachable frees the minimizer
    # to emit the tautology (constant 1) instead of real logic
    table = np.array([1, 1, 1, 0])
    full = minimize_table(table, 2, 1)
    assert full.n_literals > 0
    reach = np.array([True, True, True, False])
    relaxed = minimize_table(table, 2, 1, reach)
    assert relaxed.bits[0] == (Cube(0, 0),)
    # exact where it must be, free where it may be
    assert relaxed.evaluate(np.arange(3)).tolist() == [1, 1, 1]


def test_minimize_table_validates_length():
    with pytest.raises(ValueError):
        minimize_table(np.array([0, 1, 0]), 2, 1)


def test_budget_fallback_max_bits():
    table = np.zeros(1 << 4, dtype=np.int64)
    assert minimize_table(table, 4, 1, max_bits=3) is None
    assert minimize_table(table, 4, 1, max_bits=4) is not None


def test_budget_fallback_max_cubes():
    # 4-bit parity seeds 8 minterm cubes; a frontier cap below that
    # trips the budget, and minimize_table falls back (returns None)
    table = np.array([bin(w).count("1") & 1 for w in range(16)])
    assert minimize_bit({w for w in range(16) if table[w]}, set(), 4,
                        max_cubes=4) is None
    assert minimize_table(table, 4, 1, max_cubes=4) is None
    assert minimize_table(table, 4, 1, max_cubes=8) is not None


@given(data=st.data())
@settings(max_examples=60, deadline=None)
def test_minimize_roundtrip_with_dont_cares(data):
    """The exactness contract, property-tested.

    For a random table and random reachability mask: the cover equals
    the table on every reachable entry, never exceeds the naive two-
    level cost, and with full reachability reproduces the table verbatim.
    """
    n_in = data.draw(st.integers(1, 5), label="n_in")
    out_bits = data.draw(st.integers(1, 3), label="out_bits")
    n = 1 << n_in
    table = np.array(data.draw(
        st.lists(st.integers(0, (1 << out_bits) - 1),
                 min_size=n, max_size=n), label="table"))
    reach = np.array(data.draw(
        st.lists(st.booleans(), min_size=n, max_size=n), label="reach"))
    cover = minimize_table(table, n_in, out_bits, reach)
    assert cover is not None
    words = np.flatnonzero(reach)
    np.testing.assert_array_equal(cover.evaluate(words), table[words])
    naive = sum(int(np.count_nonzero(table[words] >> b & 1)) * n_in
                for b in range(out_bits))
    assert cover.n_literals <= naive
    full = minimize_table(table, n_in, out_bits)
    np.testing.assert_array_equal(full.table(),
                                  table & ((1 << out_bits) - 1))


# ---------------------------------------------------------------------------
# netlist synthesis + measured cost
# ---------------------------------------------------------------------------

def _toy_netlist(seed=0):
    cfg, model = _toy(seed)
    tables = LN.generate_tables(cfg, model)
    from repro.compile import optimize
    return cfg, tables, optimize(tables, level=3,
                                 in_features=cfg.in_features).netlist


def test_synthesize_netlist_attaches_covers():
    cfg, tables, nl = _toy_netlist(seed=3)
    stats = synthesize_netlist(nl)
    neurons = [n for layer in nl.layers for n in layer]
    assert stats["neurons"] == len(neurons)
    assert stats["covered_neurons"] == len(neurons)
    assert stats["fallback_neurons"] == 0
    assert stats["literals_after"] <= stats["literals_before"]
    for n in neurons:
        assert n.sop is not None
        # cover exact on the neuron's reachable entries
        reach = (np.ones(len(n.table), bool) if n.reachable is None
                 else np.asarray(n.reachable, bool))
        words = np.flatnonzero(reach)
        mask = (1 << n.out_bits) - 1
        np.testing.assert_array_equal(
            n.sop.evaluate(words),
            np.asarray(n.table, dtype=np.int64)[words] & mask)


def test_synthesize_budget_fallback_keeps_table():
    _, _, nl = _toy_netlist(seed=3)
    stats = synthesize_netlist(nl, max_bits=0)
    assert stats["covered_neurons"] == 0
    assert stats["fallback_neurons"] == stats["neurons"]
    assert stats["literals_after"] == stats["literals_before"]
    assert all(n.sop is None for layer in nl.layers for n in layer)


def test_sop_cost_beats_or_matches_bound():
    # seed 7 leaves at least one bit needing real logic, so the measured
    # figure is exercised as nonzero while still under the bound
    _, _, nl = _toy_netlist(seed=7)
    synthesize_netlist(nl)
    bound = netlist_lut_cost(nl)
    measured = netlist_sop_cost(nl)
    assert measured["fallback_neurons"] == 0
    assert 0 < measured["est_kluts"] <= bound
    # per-neuron: the estimate is clamped by the worst-case bound
    for layer in nl.layers:
        for n in layer:
            assert (sop_lut_estimate(n.sop)
                    <= lut_cost(max(len(n.input_bits), 1), n.out_bits))


def test_sop_lut_estimate_edges():
    # constant bits and single-literal bits are free (wiring, not LUTs)
    assert sop_lut_estimate(SopCover(3, 1, ((),))) == 0
    assert sop_lut_estimate(SopCover(3, 1, ((Cube(0, 0),),))) == 0
    assert sop_lut_estimate(SopCover(3, 1, ((Cube(1, 1),),))) == 0
    # support <= k: one k-LUT regardless of term structure
    wide = minimize_table(
        np.array([bin(w).count("1") & 1 for w in range(64)]), 6, 1)
    assert sop_lut_estimate(wide, k=6) == 1
    with pytest.raises(ValueError):
        sop_lut_estimate(wide, k=1)


# ---------------------------------------------------------------------------
# SOP Verilog backend
# ---------------------------------------------------------------------------

def test_neuron_module_sop_structure():
    cover = SopCover(n_in=3, out_bits=2, bits=(
        (Cube(0b011, 0b001), Cube(0b100, 0b100)),   # (a & ~b) | c
        (),                                          # constant 0
    ))
    text = neuron_module_sop("LUT_L0_N0", 3, 2, cover)
    assert "assign M1[0] = (M0[0] & ~M0[1]) | (M0[2]);" in text
    assert "assign M1[1] = 1'b0;" in text
    assert "case" not in text
    # the RTL mini-interpreter parses assigns back to the same table
    parsed = _parse_tables({"LUT_L0_N0.v": text})["LUT_L0_N0"]
    np.testing.assert_array_equal(parsed, cover.table())


def test_sop_verilog_matches_case_form_exhaustive():
    """Toy network: SOP and case-statement RTL agree on every input word."""
    cfg, model = _toy(seed=4)
    tables = LN.generate_tables(cfg, model)
    case_files = LN.to_verilog(cfg, model, optimize_level=4)
    sop_files = LN.to_verilog(cfg, model, optimize_level=4, sop=True)
    assert any("assign M1[" in t for t in sop_files.values())
    n_layers = len(tables)
    for word in range(2 ** (cfg.bw * cfg.in_features)):
        assert (evaluate_verilog(sop_files, word, n_layers=n_layers)
                == evaluate_verilog(case_files, word, n_layers=n_layers)), \
            f"word={word}"


def test_sop_flag_without_covers_is_case_form():
    # generate_verilog(sop=True) on a netlist nobody synthesized falls
    # back to case modules (n.sop is None everywhere)
    cfg, model = _toy(seed=4)
    tables = LN.generate_tables(cfg, model)
    nl = NL.build_netlist(tables, cfg.in_features)
    files = generate_verilog(nl, sop=True)
    assert not any("assign M1[" in t for t in files.values())
    assert any("case (M0)" in t for t in files.values())


@pytest.mark.slow
def test_model_a_sop_verilog_golden():
    """Acceptance criteria on the generated fpga4hep model A at level 3:
    SOP Verilog is bit-exact against the case-statement form and the
    table forward, and the measured literal count beats the worst-case
    ``lut_cost`` bound."""
    from repro.compile import optimize
    from repro.configs import fpga4hep

    cfg = fpga4hep.model_a()
    model = LN.init(cfg, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (256, cfg.in_features),
                           minval=-1, maxval=3)
    _, model = LN.forward(cfg, model, x, train=True)
    tables = LN.generate_tables(cfg, model)
    res = optimize(tables, level=3, in_features=cfg.in_features)
    nl = res.netlist
    stats = synthesize_netlist(nl)
    assert stats["fallback_neurons"] == 0
    assert stats["literals_after"] < stats["literals_before"]
    measured = netlist_sop_cost(nl)
    assert measured["est_kluts"] < netlist_lut_cost(nl)

    sop_files = generate_verilog(nl, sop=True)
    case_files = generate_verilog(nl)
    n_layers = len(res.tables)
    bw_out = res.tables[-1].bw_out
    n_out = res.tables[-1].out_features
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 2 ** cfg.bw, (24, cfg.in_features),
                         dtype=np.int64)
    want = np.asarray(network_table_forward(
        res.tables, jnp.asarray(codes, jnp.int32)))
    for i, row in enumerate(codes):
        word = int(sum(int(c) << (cfg.bw * f) for f, c in enumerate(row)))
        o_sop = evaluate_verilog(sop_files, word, n_layers=n_layers)
        o_case = evaluate_verilog(case_files, word, n_layers=n_layers)
        assert o_sop == o_case
        got = [(o_sop >> (bw_out * j)) & (2 ** bw_out - 1)
               for j in range(n_out)]
        assert got == [int(v) for v in want[i]]
