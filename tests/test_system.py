"""End-to-end system behaviour tests.

* the full LogicNets design flow (train -> tables -> netlist -> Verilog)
  on the JSC stand-in, with bit-exact functional verification;
* LM training with the paper's LogicNet-FFN integrated at LM scale —
  masks hold, loss falls;
* a miniature multi-device dry-run in a subprocess (8 host devices,
  2x4 mesh) exercising the exact lower+compile path of launch/dryrun.py;
* serve loop smoke (continuous batching slots).
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# End-to-end training/serving runs, several 10-30 s each (some flaky on
# bare CPU); excluded from the fast CI lane via -m "not slow".
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_logicnet_design_flow_end_to_end():
    from repro.configs import fpga4hep
    from repro.core import logicnet as LN
    from repro.core.train import train_logicnet
    from repro.data import jet_substructure_data

    x, y = jet_substructure_data(3000, seed=0)
    cfg = fpga4hep.model_c()
    res = train_logicnet(cfg, x[:2500], y[:2500], x[2500:], y[2500:],
                         method="apriori", steps=150)
    assert res.accuracy > 0.6            # synthetic task is learnable
    assert res.losses[-1] < res.losses[0]

    tables = LN.generate_tables(cfg, res.model)
    f_codes, t_codes = LN.verify_tables(cfg, res.model, tables, x[2500:2600])
    np.testing.assert_array_equal(np.asarray(f_codes), np.asarray(t_codes))

    files = LN.to_verilog(cfg, res.model)
    assert "LogicNetModule.v" in files
    assert sum(1 for f in files if f.startswith("LUT_L")) == 64 + 32 + 32


def test_lm_training_with_logicnet_ffn():
    """LogicNet-FFN at LM scale: loss falls, fan-in masks hold.

    Deterministic on CPU by construction (the ROADMAP seed/step sweep):
    training repeatedly on one *fixed* batch is a memorization problem the
    model solves reliably, where a fresh random-token stream per step is
    statistically unlearnable and its loss "drop" was pure noise (the old
    xfail(strict=False) flake).  Across a 5-init x 2-data seed sweep the
    fixed-batch drop after 12 steps was 5.6-6.3%, so the 3% margin below
    has >= 2x headroom on any backend.
    """
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.launch.steps import make_train_state, make_train_step
    from repro.models.config import LogicNetFFNCfg

    cfg = dataclasses.replace(
        get_smoke_config("qwen3-1.7b"),
        logicnet_ffn=LogicNetFFNCfg(fan_in=8, bw=3, max_val=4.0))
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    for _ in range(12):
        state, loss = step(state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.97
    # the fan-in masks survived training: pruned weights exactly zero
    layer0 = jax.tree.map(lambda a: a[0], state["params"]["layers"])
    w = np.asarray(layer0["ffn"]["wi_gate"])
    m = np.asarray(layer0["ffn"]["mask_in"])
    assert (w[m == 0] == 0).all()
    assert (m.sum(axis=0) == 8).all()


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.configs import get_smoke_config, ShapeCell
    from repro.launch import steps as S
    from repro.launch.hlo_stats import collective_bytes
    from repro.parallel import sharding as SH
    from repro.parallel.ctx import activation_sharding

    arch = sys.argv[1]
    cfg = get_smoke_config(arch)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    policy = SH.ShardingPolicy()
    cell = ShapeCell("mini", seq_len=64, global_batch=8, kind="train")
    specs = S.input_specs(cfg, cell)
    with activation_sharding(mesh, SH.activation_rules(policy)):
        state = S.abstract_train_state(cfg)
        state_sh = SH.shardings_for_tree(state, mesh, policy)
        batch_sh = SH.batch_specs(policy, mesh, specs["batch"])
        step = S.make_train_step(cfg)
        lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                          out_shardings=(state_sh, None)).lower(
            state, specs["batch"])
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: list of per-device dicts
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    print(json.dumps({"flops": cost.get("flops", 0.0),
                      "coll": coll["total"],
                      "mem": compiled.memory_analysis() is not None}))
""")


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "olmoe-1b-7b",
                                  "zamba2-2.7b"])
def test_mini_multidevice_dryrun_subprocess(arch):
    """8 fake devices, 2x4 mesh: the dry-run path compiles and produces
    collectives (proves the sharding rules actually shard)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN, arch], env=env,
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["coll"] > 0               # DP grad sync must exist
    assert rec["mem"]


MESH_512 = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.parallel import sharding as SH
    from repro.configs import get_config
    from repro.launch.steps import abstract_params

    for mp in (False, True):
        mesh = make_production_mesh(multi_pod=mp)
        assert mesh.devices.size == (512 if mp else 256)
        policy = SH.multi_pod_policy() if mp else SH.ShardingPolicy()
        params = abstract_params(get_config("qwen3-1.7b"))
        sh = SH.shardings_for_tree(params, mesh, policy)
        specs = [s.spec for s in jax.tree.leaves(sh)]
        flat = [a for s in specs for a in s if a is not None]
        axes = set()
        for a in flat:
            axes |= set(a) if isinstance(a, tuple) else {a}
        assert "model" in axes and "data" in axes
        if mp:
            assert "pod" in axes, "pod axis must shard weights"
    print("mesh512 ok")
""")


def test_production_mesh_512_and_pod_axis_shards():
    """512 fake devices: both production meshes build; the multi-pod rule
    set actually places the 'pod' axis on weight shardings."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", MESH_512], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "mesh512 ok" in out.stdout


ELASTIC_SAVE = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import save_checkpoint, restore_checkpoint
    mesh = jax.make_mesh((%d, 2), ("data", "model"))
    w = jnp.arange(64.0).reshape(8, 8)
    w = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
    if sys.argv[1] == "save":
        save_checkpoint(sys.argv[2], 1, {"w": w})
        print("saved")
    else:
        def sharding_fn(path, arr):
            return NamedSharding(mesh, P("data", "model"))
        got = restore_checkpoint(sys.argv[2], 1, {"w": w}, sharding_fn)
        assert (jax.device_get(got["w"]) ==
                jax.device_get(w)).all()
        print("n_shards", len(got["w"].sharding.device_set))
""")


def test_elastic_restore_across_device_counts(tmp_path):
    """A checkpoint written on a 4-device mesh restores onto an 8-device
    mesh (elastic scale-up): values identical, shard count doubled."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    d = str(tmp_path)
    out = subprocess.run(
        [sys.executable, "-c", ELASTIC_SAVE % (4, 2), "save", d],
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    out = subprocess.run(
        [sys.executable, "-c", ELASTIC_SAVE % (8, 4), "restore", d],
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "n_shards 8" in out.stdout


def test_serve_example_continuous_batching():
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "serve_lm.py"),
         "--arch", "qwen3-1.7b", "--requests", "5", "--slots", "2",
         "--max-new", "6", "--cache-len", "64"],
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")),
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "served 5 requests" in out.stdout
