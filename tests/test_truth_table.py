"""Truth-table generation + functional verification (paper §4.2, §5.1).

The contract: forward-through-tables == quantized float forward, bit-exact,
for every input — tested exhaustively on small nets and statistically on
larger ones.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # real when installed

from repro.core import logicnet as LN
from repro.core import table_infer
from repro.core.quantize import QuantizerCfg, codes
from repro.core.truth_table import (MAX_FAN_IN_BITS,
                                    generate_sparse_linear_table,
                                    minimized_lut_estimate, table_as_listing)
from repro.core import layers as L


def _trained_toy(seed=0, hidden=(6, 5), fan_in=2, bw=2, in_features=8,
                 n_classes=4):
    cfg = LN.LogicNetCfg(in_features=in_features, n_classes=n_classes,
                         hidden=hidden, fan_in=fan_in, bw=bw,
                         final_dense=False, fan_in_fc=fan_in, bw_fc=bw)
    key = jax.random.PRNGKey(seed)
    model = LN.init(cfg, key, mask_seed=seed)
    x = jax.random.uniform(key, (64, in_features), minval=-1.0, maxval=3.0)
    _, model = LN.forward(cfg, model, x, train=True)  # settle BN stats
    return cfg, model, x


@given(seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_table_forward_matches_float_forward(seed):
    cfg, model, x = _trained_toy(seed)
    tables = LN.generate_tables(cfg, model)
    f_codes, t_codes = LN.verify_tables(cfg, model, tables, x)
    np.testing.assert_array_equal(np.asarray(f_codes), np.asarray(t_codes))


def test_table_forward_exhaustive_small():
    """Every possible input word, not just samples."""
    cfg, model, _ = _trained_toy(seed=3, hidden=(4,), fan_in=2, bw=1,
                                 in_features=4, n_classes=3)
    bw = cfg.bw
    n_words = (2 ** bw) ** cfg.in_features
    words = np.arange(n_words)
    digits = np.stack([(words >> (bw * k)) & (2 ** bw - 1)
                       for k in range(cfg.in_features)], axis=1)
    from repro.core.quantize import dequantize_code
    x = dequantize_code(cfg.layer_cfgs()[0].in_quant, jnp.asarray(digits))
    tables = LN.generate_tables(cfg, model)
    f_codes, t_codes = LN.verify_tables(cfg, model, tables, x)
    np.testing.assert_array_equal(np.asarray(f_codes), np.asarray(t_codes))


def test_table_shapes_and_listing():
    cfg, model, _ = _trained_toy()
    tables = LN.generate_tables(cfg, model)
    tt = tables[0]
    assert tt.table.shape == (6, 2 ** (2 * 2))      # (out, 2^(fan_in*bw))
    assert tt.indices.shape == (6, 2)
    listing = table_as_listing(tt, neuron=0)        # Listing 5.1 structure
    assert listing[0] == list(range(tt.n_entries))
    assert len(listing[1]) == tt.n_entries
    assert max(listing[1]) < 2 ** tt.bw_out


def test_enumeration_gate():
    cfg = L.SparseLinearCfg(in_features=64, out_features=4, fan_in=13,
                            bw_in=2)  # 26 bits > gate
    layer = L.sparse_linear_init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="enumeration gate"):
        generate_sparse_linear_table(cfg, layer, QuantizerCfg(2))
    assert MAX_FAN_IN_BITS == 24


def test_chunked_generation_matches_unchunked():
    cfg = L.SparseLinearCfg(in_features=16, out_features=3, fan_in=4,
                            bw_in=2)  # 8-bit fan-in, 256 entries
    layer = L.sparse_linear_init(cfg, jax.random.PRNGKey(1))
    out_q = QuantizerCfg(2)
    a = generate_sparse_linear_table(cfg, layer, out_q, chunk=7)
    b = generate_sparse_linear_table(cfg, layer, out_q, chunk=1 << 16)
    np.testing.assert_array_equal(a.table, b.table)


def test_pack_codes_convention():
    """Element k occupies bits [bw*k, bw*(k+1)) of the table index."""
    codes_in = jnp.array([[3, 1, 2]])                     # features 0..2
    idx = jnp.array([[2, 0]])                             # neuron sees f2, f0
    packed = table_infer.pack_codes(codes_in, idx, bw_in=2)
    # element0=f2 code 2 -> bits0-1; element1=f0 code 3 -> bits2-3
    assert int(packed[0, 0]) == 2 + (3 << 2)


def test_minimized_estimate_leq_analytical():
    cfg, model, _ = _trained_toy(seed=9, hidden=(8, 8), fan_in=3, bw=2,
                                 in_features=12)
    tables = LN.generate_tables(cfg, model)
    from repro.core.lut_cost import lut_cost
    for tt, lcfg in zip(tables, cfg.layer_cfgs()):
        analytical = lcfg.out_features * lut_cost(lcfg.fan_in_bits,
                                                  tt.bw_out)
        assert minimized_lut_estimate(tt) <= analytical


def test_constant_neuron_minimizes_to_zero():
    from repro.core.truth_table import LayerTruthTable
    tt = LayerTruthTable(table=np.zeros((1, 16), np.int32),
                         indices=np.array([[0, 1]], np.int32),
                         bw_in=2, bw_out=2)
    assert minimized_lut_estimate(tt) == 0


def test_table_memory_accounting():
    cfg, model, _ = _trained_toy()
    tables = LN.generate_tables(cfg, model)
    b = table_infer.table_memory_bytes(tables)
    assert b == sum(t.out_features * t.n_entries for t in tables)  # 1B codes
