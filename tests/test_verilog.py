"""Verilog generation (paper §5.2, Listings 5.2–5.6): structure + semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import re

from repro.core import logicnet as LN
from repro.core import netlist as NL
from repro.core.quantize import codes
from repro.core.table_infer import network_table_forward
from repro.core.verilog import evaluate_verilog


def _toy(seed=0):
    cfg = LN.LogicNetCfg(in_features=5, n_classes=3, hidden=(4,),
                         fan_in=3, bw=1, final_dense=False, fan_in_fc=2,
                         bw_fc=1)
    key = jax.random.PRNGKey(seed)
    model = LN.init(cfg, key, mask_seed=seed)
    x = jax.random.normal(key, (32, 5))
    _, model = LN.forward(cfg, model, x, train=True)
    return cfg, model


def test_listing_structure():
    """The emitted files mirror Listings 5.2-5.6."""
    cfg, model = _toy()
    files = LN.to_verilog(cfg, model)
    assert "LogicNetModule.v" in files
    top = files["LogicNetModule.v"]
    assert top.startswith("module LogicNetModule (input [4:0] M0")
    assert "LUTLayer0" in files["LogicNetModule.v"]
    layer0 = files["LUTLayer0.v"]
    # per-neuron input wires: wire [2:0] inpWire0_n = {M0[a], M0[b], M0[c]};
    wires = re.findall(r"wire \[2:0\] inpWire0_\d+ = \{M0\[\d+\], "
                       r"M0\[\d+\], M0\[\d+\]\};", layer0)
    assert len(wires) == 4
    lut = files["LUT_L0_N0.v"]
    # all 2^3 entry arms plus the explicit default: arm (synthesis-safety)
    assert "case (M0)" in lut and lut.count(": M1 =") == 2 ** 3 + 1
    assert lut.count("default: M1 =") == 1
    assert "endmodule" in lut


def test_verilog_semantics_match_tables_exhaustive():
    """Evaluate every input word through the RTL mini-interpreter and compare
    with the table forward."""
    cfg, model = _toy(seed=4)
    tables = LN.generate_tables(cfg, model)
    files = LN.to_verilog(cfg, model)
    bw = cfg.bw
    n_feat = cfg.in_features
    for word in range(2 ** (bw * n_feat)):
        digits = [(word >> (bw * f)) & (2 ** bw - 1) for f in range(n_feat)]
        in_codes = jnp.asarray([digits], dtype=jnp.int32)
        expect = np.asarray(network_table_forward(tables, in_codes))[0]
        out_word = evaluate_verilog(files, word, n_layers=len(tables))
        got = [(out_word >> (tables[-1].bw_out * j))
               & (2 ** tables[-1].bw_out - 1)
               for j in range(tables[-1].out_features)]
        assert got == [int(v) for v in expect], f"word={word}"


def test_multibit_verilog_roundtrip():
    cfg = LN.LogicNetCfg(in_features=6, n_classes=4, hidden=(5,), fan_in=2,
                         bw=2, final_dense=False, fan_in_fc=2, bw_fc=2)
    key = jax.random.PRNGKey(7)
    model = LN.init(cfg, key, mask_seed=7)
    x = jax.random.uniform(key, (64, 6), minval=-1, maxval=3)
    _, model = LN.forward(cfg, model, x, train=True)
    tables = LN.generate_tables(cfg, model)
    files = LN.to_verilog(cfg, model)
    rng = np.random.default_rng(0)
    for _ in range(64):
        word = int(rng.integers(0, 2 ** (cfg.bw * cfg.in_features)))
        digits = [(word >> (cfg.bw * f)) & (2 ** cfg.bw - 1)
                  for f in range(cfg.in_features)]
        expect = np.asarray(network_table_forward(
            tables, jnp.asarray([digits], jnp.int32)))[0]
        out_word = evaluate_verilog(files, word, n_layers=len(tables))
        got = [(out_word >> (tables[-1].bw_out * j))
               & (2 ** tables[-1].bw_out - 1)
               for j in range(tables[-1].out_features)]
        assert got == [int(v) for v in expect]


def test_default_arm_matches_interpreter_semantics():
    """Arms folded into the default: arm evaluate identically to synthesis.

    A reachability mask marks half the entries don't-care; the module must
    emit arms only where needed, and evaluate_verilog must return the
    default value for every omitted entry — the exact case-statement
    semantics a synthesis tool implements (no divergence on don't-cares).
    """
    from repro.core.verilog import _parse_tables, neuron_module

    table = np.array([5, 2, 2, 2, 7, 2, 2, 1], dtype=np.int64)
    reachable = np.array([1, 1, 0, 1, 1, 0, 1, 0], dtype=bool)
    text = neuron_module("LUT_L0_N0", 3, 3, table, reachable)
    # default is the most common reachable value (2); arms only for
    # reachable entries that differ from it
    assert "default: M1 = 3'd2;" in text
    assert text.count(": M1 =") == 3  # entries 0, 4 + default
    parsed = _parse_tables({"LUT_L0_N0.v": text})["LUT_L0_N0"]
    assert parsed.shape == (8,)
    # reachable entries keep their exact value...
    assert [parsed[i] for i in np.flatnonzero(reachable)] == [5, 2, 2, 7, 2]
    # ...and don't-cares all collapse to the default
    assert [parsed[i] for i in np.flatnonzero(~reachable)] == [2, 2, 2]


def test_full_case_still_emits_default():
    """Even a complete case gets a default: arm (no latch inference)."""
    from repro.core.verilog import neuron_module

    text = neuron_module("LUT_L0_N1", 2, 2, np.array([0, 1, 2, 3]))
    assert text.count(": M1 =") == 4 + 1
    assert "default: M1 = 2'd0;" in text


def test_optimized_verilog_matches_raw_tables():
    """to_verilog(optimize_level=2): fewer modules, same function."""
    cfg, model = _toy(seed=4)
    tables = LN.generate_tables(cfg, model)
    raw = LN.to_verilog(cfg, model)
    opt = LN.to_verilog(cfg, model, optimize_level=2)
    n_raw = sum(1 for f in raw if f.startswith("LUT_L"))
    n_opt = sum(1 for f in opt if f.startswith("LUT_L"))
    assert n_opt <= n_raw
    bw = cfg.bw
    n_layers_opt = 1 + max(int(m.group(1)) for m in
                           (re.match(r"LUTLayer(\d+)\.v$", f) for f in opt)
                           if m)
    for word in range(2 ** (bw * cfg.in_features)):
        digits = [(word >> (bw * f)) & (2 ** bw - 1)
                  for f in range(cfg.in_features)]
        expect = np.asarray(network_table_forward(
            tables, jnp.asarray([digits], jnp.int32)))[0]
        out_word = evaluate_verilog(opt, word, n_layers=n_layers_opt)
        got = [(out_word >> (tables[-1].bw_out * j))
               & (2 ** tables[-1].bw_out - 1)
               for j in range(tables[-1].out_features)]
        assert got == [int(v) for v in expect], f"word={word}"


def test_pipeline_variant_has_registers():
    cfg, model = _toy()
    files = LN.to_verilog(cfg, model, pipeline=True)
    top = files["LogicNetModule.v"]
    assert "input clk" in top
    assert "always @ (posedge clk)" in top
    assert "M0_r <= M0;" in top


def test_netlist_counts():
    cfg, model = _toy()
    tables = LN.generate_tables(cfg, model)
    nl = NL.build_netlist(tables, cfg.in_features)
    assert nl.n_hbbs == 4 + 3
    assert nl.in_bits == cfg.in_features * cfg.bw
    assert nl.out_bits == 3 * 1
