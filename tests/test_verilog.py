"""Verilog generation (paper §5.2, Listings 5.2–5.6): structure + semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import re

from repro.core import logicnet as LN
from repro.core import netlist as NL
from repro.core.quantize import codes
from repro.core.table_infer import network_table_forward
from repro.core.verilog import evaluate_verilog


def _toy(seed=0):
    cfg = LN.LogicNetCfg(in_features=5, n_classes=3, hidden=(4,),
                         fan_in=3, bw=1, final_dense=False, fan_in_fc=2,
                         bw_fc=1)
    key = jax.random.PRNGKey(seed)
    model = LN.init(cfg, key, mask_seed=seed)
    x = jax.random.normal(key, (32, 5))
    _, model = LN.forward(cfg, model, x, train=True)
    return cfg, model


def test_listing_structure():
    """The emitted files mirror Listings 5.2-5.6."""
    cfg, model = _toy()
    files = LN.to_verilog(cfg, model)
    assert "LogicNetModule.v" in files
    top = files["LogicNetModule.v"]
    assert top.startswith("module LogicNetModule (input [4:0] M0")
    assert "LUTLayer0" in files["LogicNetModule.v"]
    layer0 = files["LUTLayer0.v"]
    # per-neuron input wires: wire [2:0] inpWire0_n = {M0[a], M0[b], M0[c]};
    wires = re.findall(r"wire \[2:0\] inpWire0_\d+ = \{M0\[\d+\], "
                       r"M0\[\d+\], M0\[\d+\]\};", layer0)
    assert len(wires) == 4
    lut = files["LUT_L0_N0.v"]
    assert "case (M0)" in lut and lut.count(": M1 =") == 2 ** 3
    assert "endmodule" in lut


def test_verilog_semantics_match_tables_exhaustive():
    """Evaluate every input word through the RTL mini-interpreter and compare
    with the table forward."""
    cfg, model = _toy(seed=4)
    tables = LN.generate_tables(cfg, model)
    files = LN.to_verilog(cfg, model)
    bw = cfg.bw
    n_feat = cfg.in_features
    for word in range(2 ** (bw * n_feat)):
        digits = [(word >> (bw * f)) & (2 ** bw - 1) for f in range(n_feat)]
        in_codes = jnp.asarray([digits], dtype=jnp.int32)
        expect = np.asarray(network_table_forward(tables, in_codes))[0]
        out_word = evaluate_verilog(files, word, n_layers=len(tables))
        got = [(out_word >> (tables[-1].bw_out * j))
               & (2 ** tables[-1].bw_out - 1)
               for j in range(tables[-1].out_features)]
        assert got == [int(v) for v in expect], f"word={word}"


def test_multibit_verilog_roundtrip():
    cfg = LN.LogicNetCfg(in_features=6, n_classes=4, hidden=(5,), fan_in=2,
                         bw=2, final_dense=False, fan_in_fc=2, bw_fc=2)
    key = jax.random.PRNGKey(7)
    model = LN.init(cfg, key, mask_seed=7)
    x = jax.random.uniform(key, (64, 6), minval=-1, maxval=3)
    _, model = LN.forward(cfg, model, x, train=True)
    tables = LN.generate_tables(cfg, model)
    files = LN.to_verilog(cfg, model)
    rng = np.random.default_rng(0)
    for _ in range(64):
        word = int(rng.integers(0, 2 ** (cfg.bw * cfg.in_features)))
        digits = [(word >> (cfg.bw * f)) & (2 ** cfg.bw - 1)
                  for f in range(cfg.in_features)]
        expect = np.asarray(network_table_forward(
            tables, jnp.asarray([digits], jnp.int32)))[0]
        out_word = evaluate_verilog(files, word, n_layers=len(tables))
        got = [(out_word >> (tables[-1].bw_out * j))
               & (2 ** tables[-1].bw_out - 1)
               for j in range(tables[-1].out_features)]
        assert got == [int(v) for v in expect]


def test_pipeline_variant_has_registers():
    cfg, model = _toy()
    files = LN.to_verilog(cfg, model, pipeline=True)
    top = files["LogicNetModule.v"]
    assert "input clk" in top
    assert "always @ (posedge clk)" in top
    assert "M0_r <= M0;" in top


def test_netlist_counts():
    cfg, model = _toy()
    tables = LN.generate_tables(cfg, model)
    nl = NL.build_netlist(tables, cfg.in_features)
    assert nl.n_hbbs == 4 + 3
    assert nl.in_bits == cfg.in_features * cfg.bw
    assert nl.out_bits == 3 * 1
