"""Docs checker: markdown link validation + fenced-example execution.

Two checks, no third-party deps, shared by CI's ``docs`` job and
``tests/test_docs.py``:

* ``--links <paths>`` — every *relative* markdown link (``[text](target)``)
  in the given files/directories must resolve to an existing file, and a
  ``#anchor`` on a markdown target must match a heading slug in that file
  (GitHub's slug rules).  External ``http(s)``/``mailto`` links are not
  fetched — CI must stay hermetic — so keep load-bearing references
  in-repo.
* ``--doctest <paths>`` — every fenced ```` ```python ```` block in the
  given markdown files is executed, blocks within one file sharing a
  namespace (so examples can build on each other).  A fence that should
  not run is simply not tagged ``python`` (use ``text``/``bash``).
* ``--pydoctest <modules>`` — run stdlib ``doctest`` over the named
  importable modules, so the ``>>>`` examples in API docstrings
  (``ServingTier.infer``, ``run_closed_loop``, ``run_open_loop``) stay
  runnable alongside the markdown tree.

Usage (what CI runs)::

    python tools/check_docs.py --links docs ROADMAP.md CHANGES.md \
                               --doctest docs \
                               --pydoctest repro.serve.tier \
                                           repro.serve.loadgen
"""

from __future__ import annotations

import argparse
import pathlib
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\S*)\s*$")
EXTERNAL = ("http://", "https://", "mailto:")


def md_files(paths: list[str]) -> list[pathlib.Path]:
    out: list[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.md")))
        else:
            out.append(path)
    return out


def strip_fences(text: str) -> str:
    """Drop fenced code blocks so code is never link-scanned."""
    out, in_fence = [], False
    for line in text.splitlines():
        if FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def heading_slugs(md_path: pathlib.Path) -> set[str]:
    """GitHub-style anchor slugs for every heading in ``md_path``."""
    slugs: set[str] = set()
    for line in strip_fences(md_path.read_text()).splitlines():
        m = re.match(r"^#{1,6}\s+(.*)$", line)
        if not m:
            continue
        text = re.sub(r"[`*_]", "", m.group(1)).strip().lower()
        text = re.sub(r"[^\w\s-]", "", text)
        slug = re.sub(r"\s+", "-", text)
        # duplicate headings get -1, -2, ... suffixes on GitHub
        n, base = 0, slug
        while slug in slugs:
            n += 1
            slug = f"{base}-{n}"
        slugs.add(slug)
    return slugs


def check_links(paths: list[str]) -> list[str]:
    errors: list[str] = []
    for md in md_files(paths):
        body = strip_fences(md.read_text())
        for target in LINK_RE.findall(body):
            if target.startswith(EXTERNAL):
                continue
            ref, _, anchor = target.partition("#")
            dest = md if not ref else (md.parent / ref).resolve()
            if not dest.exists():
                errors.append(f"{md}: broken link -> {target}")
                continue
            if anchor and dest.suffix == ".md":
                if anchor not in heading_slugs(dest):
                    errors.append(
                        f"{md}: anchor #{anchor} not found in {dest.name}")
    return errors


def python_fences(md_path: pathlib.Path) -> list[tuple[int, str]]:
    """(first line number, source) of every ```python fence."""
    blocks: list[tuple[int, str]] = []
    lines = md_path.read_text().splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m and m.group(1) == "python":
            start = i + 1
            j = start
            while j < len(lines) and not FENCE_RE.match(lines[j]):
                j += 1
            blocks.append((start + 1, "\n".join(lines[start:j])))
            i = j + 1
        elif m:  # non-python fence: skip to its close
            j = i + 1
            while j < len(lines) and not FENCE_RE.match(lines[j]):
                j += 1
            i = j + 1
        else:
            i += 1
    return blocks


def run_doctests(paths: list[str]) -> list[str]:
    errors: list[str] = []
    for md in md_files(paths):
        blocks = python_fences(md)
        if not blocks:
            continue
        ns: dict = {"__name__": f"docs_doctest_{md.stem}"}
        for lineno, src in blocks:
            try:
                exec(compile(src, f"{md}:{lineno}", "exec"), ns)  # noqa: S102
            except Exception as exc:  # pragma: no cover - failure reporting
                errors.append(f"{md}:{lineno}: example raised {exc!r}")
                break
        else:
            print(f"[check_docs] {md}: {len(blocks)} python example(s) OK")
    return errors


def run_pydoctests(modules: list[str]) -> list[str]:
    """Stdlib ``doctest`` over importable modules' ``>>>`` examples."""
    import doctest
    import importlib

    errors: list[str] = []
    for name in modules:
        try:
            mod = importlib.import_module(name)
        except Exception as exc:
            errors.append(f"{name}: import failed: {exc!r}")
            continue
        res = doctest.testmod(mod)
        if res.failed:
            errors.append(
                f"{name}: {res.failed}/{res.attempted} doctest(s) failed")
        else:
            print(f"[check_docs] {name}: {res.attempted} doctest "
                  "example(s) OK")
    return errors


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--links", nargs="+", default=[], metavar="PATH",
                    help="markdown files/dirs to link-check")
    ap.add_argument("--doctest", nargs="+", default=[], metavar="PATH",
                    help="markdown files/dirs whose ```python fences run")
    ap.add_argument("--pydoctest", nargs="+", default=[], metavar="MODULE",
                    help="importable modules whose >>> docstring examples "
                    "run under stdlib doctest")
    args = ap.parse_args(argv)
    errors = check_links(args.links)
    if not errors:  # broken docs would make the examples misleading anyway
        errors += run_doctests(args.doctest)
    if not errors:
        errors += run_pydoctests(args.pydoctest)
    for err in errors:
        print(f"[check_docs] FAIL {err}", file=sys.stderr)
    if not errors:
        n = len(md_files(args.links))
        print(f"[check_docs] {n} markdown file(s): links OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
