"""Promote a bench run to the committed perf-regression baseline.

``benchmarks/kernel_bench.py --update-baseline`` overwrites the committed
baseline wholesale, which makes refreshes easy to rubber-stamp: a diff
that quietly flips a *sharp* contract field (a compile-once counter, an
obs delta, the enumerated variant count) looks exactly like routine
timing drift in review.  This tool makes the refresh reviewable instead:

* it derives the candidate baseline from a bench payload JSON (the
  ``--json`` output of a kernel_bench run) with the same
  ``baseline_from_payload`` the bench itself uses,
* diffs it against the committed baseline **per gated key**, printing
  old/new/delta and classifying every change as ``sharp`` (equality or
  byte-exact gates: mode/backend, retrace and compiler-run counters,
  obs deltas, ``n_variants``, slab/table byte figures) or ``wide``
  (timing ratios the gates already tolerate drifting),
* **refuses** to proceed when any sharp key changed unless ``--allow``
  is passed — wide-only drift promotes freely,
* is a dry run by default; ``--write`` actually rewrites the committed
  file.  CI's bench-smoke job runs the dry-run form against the fresh
  payload, so a PR that moves a sharp quantity fails the promotion
  check with a per-key diff even before anyone tries to refresh.

Usage::

    python benchmarks/kernel_bench.py --smoke --json /tmp/bench.json
    python tools/promote_baseline.py /tmp/bench.json            # dry run
    python tools/promote_baseline.py /tmp/bench.json --write    # promote
    python tools/promote_baseline.py /tmp/bench.json --write --allow
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)

from benchmarks.kernel_bench import (BASELINE_PATH,  # noqa: E402
                                     baseline_from_payload)

# leaf keys whose gates are sharp (equality / byte-exact ceilings): a
# changed value here is a behavior change, not runner noise, so
# promotion stops without --allow.  Keys under an "obs" mapping are
# sharp wholesale (registry-observed counter deltas are deterministic).
SHARP_LEAVES = frozenset({
    "mode", "backend",
    "retraces_after_warmup", "compiler_runs_after_warmup",
    "n_variants",
    "table_bytes_after", "artifact_table_slab_bytes",
    "mixed_slab_bytes", "bits_saved",
    # slab row-dedup and two-level synthesis: deterministic structure
    # counts on the generated stack, gated by equality
    "dedup_entries_saved", "covered_neurons", "fallback_neurons",
})


def _flatten(d: dict, prefix: str = "") -> dict:
    """Nested dict -> {dotted.path: leaf} (leaves are non-dict values)."""
    out = {}
    for k, v in d.items():
        path = f"{prefix}.{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(_flatten(v, path))
        else:
            out[path] = v
    return out


def _is_sharp(path: str) -> bool:
    parts = path.split(".")
    return parts[-1] in SHARP_LEAVES or "obs" in parts[:-1]


def diff_baselines(committed: dict | None, candidate: dict) -> list[dict]:
    """Per-key diff of two baseline dicts.

    Returns a list of ``{"path", "kind", "old", "new", "sharp"}`` rows,
    ``kind`` in {"added", "removed", "changed"}.  A missing committed
    baseline makes every candidate key ``added`` (all promotion-worthy).
    Added/removed keys are always sharp: they change the *shape* the gate
    checks, which review must see regardless of which quantity moved.
    """
    old = _flatten(committed or {})
    new = _flatten(candidate)
    rows = []
    for path in sorted(old.keys() | new.keys()):
        if path not in new:
            rows.append({"path": path, "kind": "removed",
                         "old": old[path], "new": None, "sharp": True})
        elif path not in old:
            rows.append({"path": path, "kind": "added",
                         "old": None, "new": new[path], "sharp": True})
        elif old[path] != new[path]:
            rows.append({"path": path, "kind": "changed",
                         "old": old[path], "new": new[path],
                         "sharp": _is_sharp(path)})
    return rows


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return repr(v)


def _describe(row: dict) -> str:
    tag = "sharp" if row["sharp"] else "wide"
    if row["kind"] == "changed":
        extra = ""
        old, new = row["old"], row["new"]
        if (isinstance(old, (int, float)) and isinstance(new, (int, float))
                and not isinstance(old, bool) and old):
            extra = f" ({(new - old) / abs(old):+.1%})"
        return (f"[{tag}] {row['path']}: {_fmt(old)} -> "
                f"{_fmt(new)}{extra}")
    if row["kind"] == "added":
        return f"[{tag}] {row['path']}: (absent) -> {_fmt(row['new'])}"
    return f"[{tag}] {row['path']}: {_fmt(row['old'])} -> (removed)"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="diff a bench payload's derived baseline against the "
                    "committed one and (optionally) promote it")
    ap.add_argument("payload", help="bench payload JSON "
                    "(kernel_bench --json output)")
    ap.add_argument("--baseline", default=BASELINE_PATH, metavar="PATH",
                    help="committed baseline to diff against and, with "
                    f"--write, rewrite (default: {BASELINE_PATH})")
    ap.add_argument("--write", action="store_true",
                    help="rewrite the committed baseline on success "
                    "(default: dry run, print the diff only)")
    ap.add_argument("--allow", action="store_true",
                    help="permit promotion even when sharp-gated keys "
                    "changed (contract fields: compile-once counters, obs "
                    "deltas, variant counts, byte figures, mode/backend)")
    args = ap.parse_args(argv)

    with open(args.payload) as f:
        payload = json.load(f)
    candidate = baseline_from_payload(payload)

    committed = None
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            committed = json.load(f)
    else:
        print(f"# no committed baseline at {args.baseline} — every key "
              "is new (sharp)")

    rows = diff_baselines(committed, candidate)
    if not rows:
        print(f"# baseline unchanged ({args.baseline})")
    for row in rows:
        print(_describe(row))
    sharp = [r for r in rows if r["sharp"]]
    wide = [r for r in rows if not r["sharp"]]
    print(f"# {len(rows)} key(s) differ: {len(sharp)} sharp, "
          f"{len(wide)} wide")

    if sharp and not args.allow:
        print("# REFUSED: sharp-gated keys changed; these are contract "
              "fields, not timing drift. Re-run with --allow after "
              "reviewing each one above.")
        return 1
    if args.write:
        base_dir = os.path.dirname(args.baseline)
        if base_dir:
            os.makedirs(base_dir, exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(candidate, f, indent=2)
            f.write("\n")
        print(f"# wrote baseline {args.baseline}")
    else:
        print("# dry run (no --write): committed baseline untouched")
    return 0


if __name__ == "__main__":
    sys.exit(main())
